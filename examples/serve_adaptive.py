"""Serving demo: continuous batching with ARCAS adaptive replica layout.

Two phases of load hit the engine:
  1. many small requests  -> compact layout (many replicas) serves best;
  2. long-context requests -> KV pressure + steals push the controller
     toward spread (fewer, larger replica groups).

    PYTHONPATH=src python examples/serve_adaptive.py
"""
import numpy as np

from repro.configs import REGISTRY, reduced_config
from repro.core.topology import ChipletTopology
from repro.serving.engine import EngineConfig, ServeEngine


def main():
    cfg = reduced_config(REGISTRY["mixtral-8x22b"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=4, chips_per_group=2)
    eng = ServeEngine(cfg, topo, EngineConfig(max_batch=2, max_len=96),
                      spread_rate=1)
    rng = np.random.default_rng(0)

    print(f"groups={len(eng.groups)} (spread_rate="
          f"{eng.controller.spread_rate})")
    # phase 1: short interactive requests
    short = [eng.submit(rng.integers(2, cfg.vocab, size=6), max_new=4)
             for _ in range(10)]
    eng.run_until_done()
    print("phase1 (short):", ServeEngine.stats(short))

    # phase 2: long-context analytical requests
    long = [eng.submit(rng.integers(2, cfg.vocab, size=48), max_new=8)
            for _ in range(6)]
    eng.run_until_done()
    print("phase2 (long):", ServeEngine.stats(long))
    print("controller decisions:",
          [(d.step, d.old_spread, "->", d.new_spread, d.reason)
           for d in eng.controller.decisions])
    print("live relayouts (mid-run group rebuilds):")
    for r in eng.relayouts:
        print(f"  step {r['step']}: {r['old_groups']} -> {r['new_groups']} "
              f"groups, {r['moved_slots']} KV slots migrated, "
              f"{r['requeued']} requests requeued")
    print("counters:", {k: round(v, 1) for k, v in
                        eng.counters.snapshot().items()
                        if "steal" in k or k in ("prefills", "decode_steps",
                                                 "remote_bytes")})


if __name__ == "__main__":
    main()
