"""Serving demo: continuous batching with ARCAS adaptive replica layout and
the paged chiplet-aware KV allocator.

Two phases of load hit the engine:
  1. many small requests  -> compact layout (many replicas) serves best;
  2. long-context requests -> KV pressure + steals push the controller
     toward spread (fewer, larger replica groups).

KV lives in a block pool partitioned per chiplet-group domain: requests
hold block tables, relayouts move tables (not cache slices), and admission
parks on pool exhaustion instead of queueing blindly.

    PYTHONPATH=src python examples/serve_adaptive.py
"""
import numpy as np

from repro.configs import REGISTRY, reduced_config
from repro.core.topology import ChipletTopology
from repro.serving.engine import EngineConfig, ServeEngine


def main():
    cfg = reduced_config(REGISTRY["mixtral-8x22b"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=4, chips_per_group=2)
    eng = ServeEngine(cfg, topo, EngineConfig(max_batch=2, max_len=96,
                                              pool_streams=2),
                      spread_rate=1)
    rng = np.random.default_rng(0)

    print(f"groups={len(eng.groups)} (spread_rate="
          f"{eng.controller.spread_rate}), KV pool: "
          f"{eng.pool.total_blocks()} blocks of "
          f"{eng.pool.block_tokens} tokens over "
          f"{eng.pool.n_domains} chiplet-group domains")
    # phase 1: short interactive requests
    short = [eng.submit(rng.integers(2, cfg.vocab, size=6), max_new=4)
             for _ in range(10)]
    eng.run_until_done()
    print("phase1 (short):", ServeEngine.stats(short))

    # phase 2: long-context analytical requests, arriving over time
    # (open-loop client on the shared task runtime)
    sched = [(2, rng.integers(2, cfg.vocab, size=48), 8) for _ in range(6)]
    eng.open_loop_client(sched)
    eng.run_until_done()
    long = eng.submitted[len(short):]
    print("phase2 (long, open-loop):", ServeEngine.stats(long))
    print("controller decisions:",
          [(d.step, d.old_spread, "->", d.new_spread, d.reason)
           for d in eng.controller.decisions])
    print("live relayouts (mid-run group rebuilds):")
    for r in eng.relayouts:
        print(f"  step {r['step']}: {r['old_groups']} -> {r['new_groups']} "
              f"groups, {r['moved_slots']} streams re-pointed, "
              f"{r['blocks_migrated']:.0f} KV blocks copied, "
              f"{r['requeued']} requests requeued")
    print("kv pool:", {k: round(v, 3) if isinstance(v, (int, float)) else v
                       for k, v in eng.kv_stats().items()
                       if not isinstance(v, list)})
    print("counters:", {k: round(v, 1) for k, v in
                        eng.counters.snapshot().items()
                        if "steal" in k or k in ("prefills", "decode_steps",
                                                 "kv_alloc_failures",
                                                 "tasks_unblocked")})


if __name__ == "__main__":
    main()
