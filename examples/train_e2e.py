"""End-to-end driver: train a ~100M-parameter llama-family model for a few
hundred steps with the full stack — sharded data pipeline, AdamW, atomic
checkpoints, ARCAS controller, straggler detection.

    PYTHONPATH=src python examples/train_e2e.py --steps 300
    PYTHONPATH=src python examples/train_e2e.py --steps 300 --resume

~100M config: 12L x d768 (12H, kv=4) x ff2048, vocab 32768 ->
  params = 32768*768*2 + 12*(768*12*64*2 + 768*4*64*2 + 3*768*2048) = ~116M
"""
import argparse
import dataclasses
import shutil

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import REGISTRY
from repro.core.topology import ChipletTopology
from repro.data.pipeline import (ShardedLoader, SyntheticCorpus,
                                 write_corpus_shards)
from repro.models.params import n_params
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def model_100m():
    return dataclasses.replace(
        REGISTRY["llama3-8b"],
        name="llama-100m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32768,
        param_dtype="float32", compute_dtype="float32",
        attn_block_q=128, attn_block_kv=128)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--workdir", default="/tmp/repro_train_e2e")
    args = ap.parse_args()

    cfg = model_100m()
    print(f"model: {cfg.name}, {n_params(cfg)/1e6:.0f}M params")

    if not args.resume:
        shutil.rmtree(args.workdir, ignore_errors=True)
    corpus = SyntheticCorpus(cfg.vocab, seed=1234)
    files = write_corpus_shards(f"{args.workdir}/data", corpus,
                                n_shards=8, tokens_per_shard=2_000_000)
    loader = ShardedLoader(files, seq_len=args.seq, batch=args.batch)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    topo = ChipletTopology()
    tcfg = TrainerConfig(
        steps=args.steps, ckpt_every=50, ckpt_dir=f"{args.workdir}/ckpt",
        log_every=10, async_ckpt=True,
        opt=AdamWConfig(peak_lr=3e-4, warmup_steps=30,
                        total_steps=args.steps))
    trainer = Trainer(cfg, mesh, loader, tcfg, topology=topo)
    if args.resume:
        trainer.resume_if_possible()
    out = trainer.run()
    lo = np.mean(out["losses"][:10])
    hi = np.mean(out["losses"][-10:])
    tput = args.batch * args.seq * len(out["losses"]) / out["wall"]
    print(f"done: steps={out['steps']} loss {lo:.3f} -> {hi:.3f} "
          f"({tput:.0f} tok/s, stragglers={len(out['straggler_events'])})")
    assert hi < lo, "loss must decrease over a few hundred steps"


if __name__ == "__main__":
    main()
