"""Fault-tolerance demo: a host dies mid-training; the job checkpoint-
restarts on a degraded mesh with a re-fitted batch, resuming bit-exact
from the last atomic checkpoint.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import shutil

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import REGISTRY, reduced_config
from repro.core.topology import ChipletTopology
from repro.data.pipeline import (ShardedLoader, SyntheticCorpus,
                                 write_corpus_shards)
from repro.runtime.elastic import rebatch_for
from repro.runtime.failure import FailureInjector, SimulatedFailure
from repro.runtime.trainer import Trainer, TrainerConfig

WORKDIR = "/tmp/repro_elastic"


def build(loader_batch, failure=None):
    cfg = reduced_config(REGISTRY["llama3-8b"])
    corpus = SyntheticCorpus(cfg.vocab, seed=7)
    files = write_corpus_shards(f"{WORKDIR}/data", corpus, n_shards=4,
                                tokens_per_shard=100_000)
    loader = ShardedLoader(files, seq_len=64, batch=loader_batch)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    topo = ChipletTopology(n_pods=1, groups_per_pod=4, chips_per_group=4)
    tcfg = TrainerConfig(steps=30, ckpt_every=10, log_every=10,
                         ckpt_dir=f"{WORKDIR}/ckpt")
    return Trainer(cfg, mesh, loader, tcfg, topology=topo, failure=failure)


def main():
    shutil.rmtree(WORKDIR, ignore_errors=True)

    # --- run 1: dies at step 17 (after the step-10 checkpoint) ------------
    t1 = build(loader_batch=8, failure=FailureInjector(fail_at_step=17))
    try:
        t1.run()
    except SimulatedFailure as e:
        print(f"!! {e}")

    # --- run 2: restart on a DEGRADED fleet (one group lost) --------------
    # survivors re-fit the global batch to the remaining data shards
    new_batch = rebatch_for(8, 4)   # e.g. 4 surviving data shards
    print(f"restarting with batch {new_batch} on the degraded fleet")
    t2 = build(loader_batch=new_batch)
    assert t2.resume_if_possible(), "checkpoint must exist"
    assert t2.step == 10
    out = t2.run()
    print(f"recovered: resumed@10 -> finished step {out['steps']}, "
          f"final loss {out['losses'][-1]:.3f}")


if __name__ == "__main__":
    main()
