"""Quickstart: train a tiny llama-family model for 20 steps with the ARCAS
runtime (counters + Algorithm-1 controller) and generate a few tokens.

    PYTHONPATH=src python examples/quickstart.py
"""
import shutil

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import REGISTRY, reduced_config
from repro.core.topology import ChipletTopology
from repro.data.pipeline import (ShardedLoader, SyntheticCorpus,
                                 write_corpus_shards)
from repro.launch.steps import make_generate, make_prefill
from repro.models.params import init_params
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    cfg = reduced_config(REGISTRY["llama3-8b"])
    print(f"model: {cfg.name} ({cfg.n_layers}L d={cfg.d_model})")

    # --- data + trainer ---------------------------------------------------
    shutil.rmtree("/tmp/repro_quickstart", ignore_errors=True)
    corpus = SyntheticCorpus(cfg.vocab, seed=0)
    files = write_corpus_shards("/tmp/repro_quickstart/data", corpus,
                                n_shards=2, tokens_per_shard=50_000)
    loader = ShardedLoader(files, seq_len=64, batch=4)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    topo = ChipletTopology(n_pods=1, groups_per_pod=4, chips_per_group=4)
    trainer = Trainer(cfg, mesh, loader,
                      TrainerConfig(steps=20, ckpt_every=10, log_every=5,
                                    ckpt_dir="/tmp/repro_quickstart/ckpt"),
                      topology=topo)
    out = trainer.run()
    print(f"trained {out['steps']} steps; "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")

    # --- generate ----------------------------------------------------------
    prompt = np.array([[5, 17, 42, 99]], np.int32)
    prefill = jax.jit(make_prefill(cfg, max_len=64))
    logits, cache = prefill(trainer.params, {"tokens": prompt})
    gen = jax.jit(make_generate(cfg, steps=12))
    first = np.argmax(np.asarray(logits), -1)[:, None].astype(np.int32)
    pos = np.full((1,), prompt.shape[1], np.int32)
    toks, _, _ = gen(trainer.params, cache, first, pos, jax.random.PRNGKey(0))
    print("generated tokens:", np.asarray(toks)[0].tolist())
    print("ARCAS counters:", {k: round(v, 1) for k, v in
                              trainer.counters.snapshot().items()
                              if not k.startswith("segment")})


if __name__ == "__main__":
    main()
