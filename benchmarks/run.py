# One function per paper table/figure. Prints ``name,us_per_call,derived``.
from __future__ import annotations

import sys
import traceback

from benchmarks.common import emit, row


MODULES = [
    "fig3_latency_cdf",
    "fig5_local_vs_distributed",
    "fig7_scalability",
    "tab1_access_counts",
    "tab2_memory_hierarchy",
    "fig10_sgd",
    "fig11_concurrency",
    "fig12_olap",
    "fig13_oltp",
    "roofline",
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    only = sys.argv[1:] or None
    for mod_name in MODULES:
        if only and mod_name not in only:
            continue
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            emit(mod.run())
        except Exception as e:   # noqa: BLE001
            traceback.print_exc()
            emit([row(f"{mod_name}/FAILED", 0.0, repr(e)[:80])])
            failures += 1
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
