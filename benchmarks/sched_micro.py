"""Scheduler microbenchmark: precomputed-tier steal vs the seed's
scan-based steal (ISSUE 1 acceptance).

Workload: a 4-pod x 16-group fleet (64 workers) with all tasks pinned to
chiplet group 0 and far fewer tasks than workers — the idle-heavy regime
where nearly every worker attempts a steal every round.  The seed's
``_steal`` rebuilt group/pod/fleet victim lists with three full worker
scans per attempt (O(W) per idle worker, O(W^2) per round); the tiered
path keeps occupancy indexes so a failed steal costs a few small set ops.

    PYTHONPATH=src python benchmarks/sched_micro.py

Emits ``name,us_per_call,derived`` rows (see benchmarks/common.py) where
``us_per_call`` is microseconds per scheduling round and ``derived`` is
rounds/sec, plus a final speedup row.
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from common import emit, row                       # noqa: E402
from repro.core.tasks import TaskRuntime           # noqa: E402


def bench(steal_impl: str, *, n_pods: int = 4, groups_per_pod: int = 16,
          tasks: int = 8, yields: int = 400, repeats: int = 3) -> float:
    """Best-of-``repeats`` rounds/sec for one steal implementation."""

    def work():
        for _ in range(yields):
            yield

    best = 0.0
    for rep in range(repeats):
        rt = TaskRuntime(n_pods=n_pods, groups_per_pod=groups_per_pod,
                         seed=rep, steal_impl=steal_impl)
        for _ in range(tasks):
            rt.spawn(work(), group=0)   # all work on one group: idle-heavy
        t0 = time.perf_counter()
        rounds = rt.run()
        dt = time.perf_counter() - t0
        best = max(best, rounds / dt)
    return best


def main():
    rows = []
    results = {}
    for impl in ("scan", "tiered"):
        rps = bench(impl)
        results[impl] = rps
        rows.append(row(f"steal_{impl}", 1e6 / rps, f"{rps:.0f} rounds/s"))
    speedup = results["tiered"] / results["scan"]
    rows.append(row("tiered_vs_scan", 0.0, f"{speedup:.2f}x rounds/s"))
    emit(rows)
    if speedup <= 1.0:
        raise SystemExit("tiered steal did not beat the scan baseline")


if __name__ == "__main__":
    main()
