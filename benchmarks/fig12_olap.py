"""Fig. 12 analogue (TPC-H on DuckDB): analytics-style serving under
adaptive vs static policies.

Paper: every TPC-H query speeds up under ARCAS (1.24x-1.51x on join-heavy
queries): join-heavy -> spread for aggregate cache, small queries ->
compact.  Here: 22 "queries" = batched long-prompt/short-decode requests of
mixed sizes served (REAL tiny-model execution) under three policies:
adaptive controller vs always-compact vs always-spread; derived = mean
latency per policy + adaptive-vs-best-static ratio.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.configs import REGISTRY, reduced_config
from repro.core.topology import ChipletTopology
from repro.serving.engine import EngineConfig, ServeEngine


def _serve(policy: str, queries):
    cfg = reduced_config(REGISTRY["llama3-8b"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=4, chips_per_group=1)
    spread = {"compact": 1, "spread": 4, "adaptive": 1}[policy]
    replicas = topo.groups_per_pod // spread
    ecfg = EngineConfig(max_batch=8 // replicas, max_len=64,
                        adaptive=policy == "adaptive")
    eng = ServeEngine(cfg, topo, ecfg, spread_rate=spread)
    reqs = [eng.submit(q, max_new=4) for q in queries]
    eng.run_until_done()
    lat = [r.t_done - r.arrived for r in reqs if r.done]
    return float(np.mean(lat)), eng


def run():
    rng = np.random.default_rng(4)
    cfg = reduced_config(REGISTRY["llama3-8b"])
    # 22 mixed "queries": big scans (long prompts) + small lookups
    queries = [rng.integers(2, cfg.vocab, size=int(s))
               for s in rng.choice([8, 16, 32], size=22, p=[0.4, 0.3, 0.3])]
    rows = []
    lats = {}
    for policy in ("compact", "spread", "adaptive"):
        lat, eng = _serve(policy, queries)
        lats[policy] = lat
        rows.append(row(f"fig12_olap/{policy}", lat * 1e6,
                        f"mean_latency_s={lat:.3f};"
                        f"decisions={len(eng.controller.decisions)}"))
    best_static = min(lats["compact"], lats["spread"])
    rows.append(row("fig12_olap/adaptive_vs_best_static", 0.0,
                    f"ratio={lats['adaptive']/best_static:.2f} "
                    f"(<=1.1 means adaptive ~ matches best static per-query)"))
    return rows
