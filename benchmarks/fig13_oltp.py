"""Fig. 13 analogue (YCSB/TPC-C on ERMIA): transaction-style serving.

Paper's hypothesis CONFIRMED there: short transactions with constant
synchronization are insensitive to LocalCache vs DistributedCache — the
curves coincide.  Here: very short prompts + 2-token decodes (commit-
latency-bound): compact and spread throughput should be within ~15%.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.configs import REGISTRY, reduced_config
from repro.core.topology import ChipletTopology
from repro.serving.engine import EngineConfig, ServeEngine


def _run_policy(spread, n=24):
    cfg = reduced_config(REGISTRY["mamba2-780m"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=4, chips_per_group=1)
    replicas = topo.groups_per_pod // spread
    eng = ServeEngine(cfg, topo,
                      EngineConfig(max_batch=8 // replicas, max_len=16,
                                   adaptive=False),
                      spread_rate=spread)
    rng = np.random.default_rng(7)
    import time
    t0 = time.perf_counter()
    reqs = [eng.submit(rng.integers(2, cfg.vocab, size=4), max_new=2)
            for _ in range(n)]
    eng.run_until_done()
    dt = time.perf_counter() - t0
    commits = sum(1 for r in reqs if r.done)
    return commits / dt


def run():
    tput = {s: _run_policy(s) for s in (1, 4)}
    ratio = tput[1] / tput[4]
    return [row("fig13_oltp/local_vs_distributed", 0.0,
                f"compact_commits_per_s={tput[1]:.1f};"
                f"spread_commits_per_s={tput[4]:.1f};ratio={ratio:.2f} "
                f"(paper: curves coincide; expect ~1.0)")]
