"""§Roofline table generator: reads experiments/dryrun/*.json.

Per (arch x shape) single-pod cell: the three terms in seconds, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs ratio, roofline fraction, memory fit.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")


def load_records(pod: str = "pod1"):
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN, f"*__{pod}.json"))):
        recs.append(json.load(open(f)))
    return recs


def run():
    rows = []
    recs = load_records()
    if not recs:
        return [row("roofline/missing", 0.0,
                    "run: PYTHONPATH=src python -m repro.launch.dryrun --all")]
    n_ok = n_skip = n_err = 0
    worst = (None, 1e9)
    for rec in recs:
        name = f"roofline/{rec['arch']}__{rec['shape']}"
        if rec["status"] == "skipped":
            n_skip += 1
            rows.append(row(name, 0.0, f"SKIP:{rec['reason'][:60]}"))
            continue
        if rec["status"] != "ok":
            n_err += 1
            rows.append(row(name, 0.0, f"ERROR:{rec.get('error','')[:60]}"))
            continue
        n_ok += 1
        if "roofline" not in rec:
            continue
        r = rec["roofline"]
        frac = r["roofline_fraction"]
        if frac < worst[1]:
            worst = (name, frac)
        rows.append(row(
            name, r["bound_s"] * 1e6 if "bound_s" in r else 0.0,
            f"comp_s={r['compute_s']:.3g};mem_s={r['memory_s']:.3g};"
            f"coll_s={r['collective_s']:.3g};dom={r['dominant']};"
            f"useful={r['useful_ratio']:.2f};frac={frac:.3f};"
            f"fits16GB={rec['memory'].get('fits_hbm_16gb')}"))
    rows.append(row("roofline/summary", 0.0,
                    f"ok={n_ok};skipped={n_skip};errors={n_err};"
                    f"worst={worst[0]}@{worst[1]:.3f}"))
    return rows
