"""Tab. 1 analogue: local vs remote chiplet traffic, ARCAS vs baseline.

Paper: ARCAS turns ~1e8 remote accesses into ~1e3-1e5 while local accesses
grow (SSSP: remote 2.3e8 -> 6e3).  Here: per-step bytes classified
local-group vs cross-group for the ARCAS layout vs a chiplet-agnostic
layout that stripes every replica ACROSS groups (round-robin device order —
the worst-case the paper attributes to NUMA-only placement).
Dry-run-derived numbers (HLO collectives) are appended when available.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row, time_call
from repro.configs import SHAPES, get_config
from repro.core.costmodel import best_layout, estimate
from repro.core.layout import layout_family
from repro.core.topology import production_topology

WORKLOADS = [("llama3-8b", "train_4k"), ("mixtral-8x22b", "train_4k"),
             ("mamba2-780m", "train_4k"), ("seamless-m4t-large-v2", "train_4k"),
             ("grok-1-314b", "decode_32k"), ("recurrentgemma-9b", "prefill_32k")]


def run():
    topo = production_topology()
    fam = layout_family(topo)
    rows = []
    us = None
    for arch, shape_name in WORKLOADS:
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        f = lambda: estimate(cfg, shape, best_layout(cfg, shape, fam))
        if us is None:
            us = time_call(f)
        arcas = f()
        # chiplet-agnostic baseline: the fully-spread layout (every
        # replica's TP ring crosses all group boundaries, as when placement
        # ignores the sub-NUMA hierarchy)
        agnostic = estimate(cfg, shape, fam[-1])
        agnostic_remote = agnostic.remote_bytes + agnostic.local_bytes * 0.0 \
            + agnostic.remote_bytes
        rows.append(row(
            f"tab1_access/{arch}_{shape_name}", us,
            f"arcas_local_GB={arcas.local_bytes/1e9:.2f};"
            f"arcas_remote_GB={arcas.remote_bytes/1e9:.3f};"
            f"agnostic_remote_GB={agnostic.remote_bytes/1e9:.2f};"
            f"reduction={(agnostic.remote_bytes+1)/(arcas.remote_bytes+1):.0f}x"))
    # dry-run-derived (single-pod records)
    dr = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")
    for f in sorted(glob.glob(os.path.join(dr, "*pod1.json")))[:40]:
        rec = json.load(open(f))
        if rec.get("status") != "ok":
            continue
        c = rec["collectives"]["per_class_bytes"]
        rows.append(row(
            f"tab1_access_hlo/{rec['arch']}_{rec['shape']}", 0.0,
            f"intra_group_GB={c.get('intra_group', 0)/1e9:.2f};"
            f"cross_group_GB={c.get('intra_pod', 0)/1e9:.2f}"))
    return rows
