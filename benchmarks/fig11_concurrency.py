"""Fig. 11 analogue: task concurrency during serving.

Paper: DimmWitted fluctuates around 16.23 threads (641 spawned) while
ARCAS holds a stable 31.16 with 34 coroutines.  Here: the serving engine's
active-task trace per scheduler round — stability measured as CV
(std/mean) of concurrency.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, time_call
from repro.configs import REGISTRY, reduced_config
from repro.core.topology import ChipletTopology
from repro.serving.engine import EngineConfig, ServeEngine


def run():
    cfg = reduced_config(REGISTRY["mamba2-780m"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=4, chips_per_group=1)
    eng = ServeEngine(cfg, topo, EngineConfig(max_batch=2, max_len=40),
                      spread_rate=1)
    rng = np.random.default_rng(1)
    reqs = [eng.submit(rng.integers(2, cfg.vocab, size=6), 4)
            for _ in range(16)]
    res = eng.run_until_done()
    trace = np.array([t for t in res["concurrency"] if t > 0])
    spawned = int(eng.counters.totals.get("tasks_spawned", 0))
    cv = float(trace.std() / max(trace.mean(), 1e-9))
    return [row("fig11_concurrency/arcas", 0.0,
                f"mean_active={trace.mean():.2f};cv={cv:.2f};"
                f"coroutines_spawned={spawned};requests={len(reqs)} "
                f"(paper: stable 31.16 w/ 34 coroutines)")]
