"""Fig. 7 analogue: throughput scalability, ARCAS vs a NUMA-aware baseline.

Paper: six workloads, ARCAS ~linear scaling vs RING, up to 2.3x (SSSP).
Here: six (arch x shape) workloads; ARCAS = cost-model-guided layout per
fleet size; RING analogue = NUMA(pod)-aware but chiplet-agnostic static
layout (always compact TP inside one group, pure DP elsewhere, and no
capacity-driven re-spreading).  Throughput = tokens/s from modeled step
time at each fleet size.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import row, time_call
from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.core.costmodel import best_layout, estimate
from repro.core.layout import Layout, layout_family
from repro.core.topology import ChipletTopology

WORKLOADS = [
    ("llama3-8b", "train_4k"),
    ("mixtral-8x22b", "train_4k"),
    ("mamba2-780m", "train_4k"),
    ("recurrentgemma-9b", "train_4k"),
    ("grok-1-314b", "decode_32k"),
    ("qwen2-vl-2b", "decode_32k"),
]


def _throughput(cfg, shape, cost) -> float:
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    t = cost.overlap_s
    if not cost.fits:
        t *= 10.0   # offload-penalized (doesn't fit resident)
    return tokens / t


def run():
    rows = []
    us = None
    for arch, shape_name in WORKLOADS:
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        speedups = []
        for groups in (2, 4, 8, 16):
            topo = ChipletTopology(n_pods=1, groups_per_pod=groups)
            fam = layout_family(topo)
            f = lambda: best_layout(cfg, shape, fam)
            if us is None:
                us = time_call(f)
            arcas_layout = f()
            arcas = _throughput(cfg, shape,
                                estimate(cfg, shape, arcas_layout))
            # RING analogue: NUMA-aware (same factorization) but the
            # device order stripes TP across chiplet groups, and no
            # capacity-driven layout moves (stuck at its static choice)
            ring = _throughput(cfg, shape,
                               estimate(cfg, shape, Layout(topo, 1),
                                        chiplet_agnostic=True))
            speedups.append(arcas / max(ring, 1e-9))
        chips = [g * 16 for g in (2, 4, 8, 16)]
        rows.append(row(
            f"fig7_scalability/{arch}_{shape_name}", us,
            "speedup_vs_ring=" + ";".join(
                f"{c}c:{s:.2f}x" for c, s in zip(chips, speedups))))
    return rows
