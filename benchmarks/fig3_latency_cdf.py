"""Fig. 3 analogue: CDF of chip-to-chip link latency over the fleet.

Paper: stepped CDF (~25ns intra-chiplet / 80-90ns intra-CCX / >150ns
cross-CCX).  Here: intra-group ICI / intra-pod ICI / cross-pod DCN.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, time_call
from repro.core.topology import production_topology


def run():
    topo = production_topology(multi_pod=True)
    us = time_call(lambda: topo.latency_cdf(4096))
    lats, cls = topo.latency_cdf(8192)
    rows = []
    for c in ("intra_group", "intra_pod", "cross_pod"):
        sel = np.array([x == c for x in cls])
        frac = float(sel.mean())
        med = float(np.median(lats[sel]) * 1e9) if sel.any() else 0.0
        rows.append(row(f"fig3_latency_cdf/{c}", us,
                        f"median_ns={med:.0f};frac={frac:.3f}"))
    steps = len(set(np.round(lats * 1e9).tolist()))
    rows.append(row("fig3_latency_cdf/stepped", us,
                    f"distinct_latency_classes={steps} (paper: 3-step CDF)"))
    return rows
