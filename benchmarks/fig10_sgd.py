"""Fig. 10 analogue: SGD training throughput under three schedulers.

Paper: DimmWitted+ARCAS coroutines hit 165 GB/s vs 50 (NUMA-node) vs 28
(std::async) — the win comes from (i) placement and (ii) coroutines
replacing thread-per-task.  Here (REAL execution, tiny LM on CPU):

  arcas      — coroutine prefetch + scheduler (TaskRuntime)
  threads    — thread-per-batch loader (the std::async analogue)
  static     — no prefetch, synchronous loader
"""
from __future__ import annotations

import shutil
import threading
import time

import jax
import numpy as np

from benchmarks.common import row
from repro.configs import REGISTRY, reduced_config
from repro.core.tasks import TaskRuntime
from repro.data.pipeline import (ShardedLoader, SyntheticCorpus, make_batch,
                                 write_corpus_shards)
from repro.launch.steps import make_train_step
from repro.models.params import init_params
from repro.optim.adamw import AdamWConfig, init_opt_state

STEPS = 12


def _setup():
    cfg = reduced_config(REGISTRY["llama3-8b"])
    corpus = SyntheticCorpus(cfg.vocab, seed=9)
    files = write_corpus_shards("/tmp/repro_bench_data", corpus,
                                n_shards=2, tokens_per_shard=60000)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig()))
    return cfg, files, params, opt, step


def _train(cfg, loader, params, opt, step, fetch):
    # warmup compile
    b = make_batch(cfg, fetch(loader))
    params, opt, _ = step(params, opt, b)
    jax.block_until_ready(jax.tree.leaves(params)[0])
    t0 = time.perf_counter()
    for _ in range(STEPS):
        b = make_batch(cfg, fetch(loader))
        params, opt, m = step(params, opt, b)
    jax.block_until_ready(jax.tree.leaves(params)[0])
    dt = time.perf_counter() - t0
    tokens = STEPS * 4 * 64
    return tokens / dt, float(m["loss"])


def run():
    cfg, files, params, opt, step = _setup()
    results = {}

    # static: synchronous reads
    loader = ShardedLoader(files, seq_len=64, batch=4)
    results["static"] = _train(cfg, loader, params, opt, step,
                               lambda l: l._read_block())

    # arcas: coroutine prefetch through the task runtime
    rt = TaskRuntime(n_pods=1, groups_per_pod=4)
    loader = ShardedLoader(files, seq_len=64, batch=4, runtime=rt,
                           prefetch=2)
    results["arcas"] = _train(cfg, loader, params, opt, step,
                              lambda l: l.next())

    # threads: one OS thread per fetch (std::async analogue)
    loader = ShardedLoader(files, seq_len=64, batch=4)
    spawned = [0]

    def thread_fetch(l):
        out = {}
        def work():
            out["b"] = l._read_block()
        th = threading.Thread(target=work)
        spawned[0] += 1
        th.start()
        th.join()
        return out["b"]

    results["threads"] = _train(cfg, loader, params, opt, step, thread_fetch)

    rows = []
    base = results["static"][0]
    for name, (tps, loss) in results.items():
        us = 1e6 / tps * (4 * 64)
        rows.append(row(f"fig10_sgd/{name}", us,
                        f"tokens_per_s={tps:.0f};rel={tps/base:.2f}x;"
                        f"loss={loss:.3f}"))
    rows.append(row("fig10_sgd/threads_spawned", 0.0,
                    f"os_threads_spawned={spawned[0]} vs arcas_coroutines="
                    f"{int(rt.counters.totals.get('tasks_spawned', 0))} "
                    f"(paper: 641 threads vs 34)"))
    shutil.rmtree("/tmp/repro_bench_data", ignore_errors=True)
    return rows
