"""Fig. 5 analogue: LocalCache vs DistributedCache as the working set grows.

Paper: write-op microbenchmark at fixed 8 cores, sweeping the array 38 B ->
38 GB: LocalCache (one chiplet, 32 MB L3) wins below the L3 capacity;
DistributedCache wins beyond, peaking at 2.50x; range 0.59x-2.50x.

TPU translation: decode service at fixed fleet, sweeping the replica
working set (params + KV) across the assigned model families:
  compact (spread=1): replica confined to ONE chiplet group -> 1-hop ICI
      collectives (fast) but only 256 GB of HBM ("local L3");
  spread (spread=16): replica spans the pod -> 4 TB aggregate HBM
      ("distributed cache") but cross-group collectives.
Crossover exactly at the group-HBM capacity, as in the paper.
"""
from __future__ import annotations

from benchmarks.common import row, time_call
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.costmodel import estimate
from repro.core.layout import Layout
from repro.core.topology import production_topology

MODELS = ["qwen2-vl-2b", "llama3.2-3b", "llama3-8b", "starcoder2-15b",
          "mixtral-8x22b", "grok-1-314b"]


def run():
    topo = production_topology()
    compact = Layout(topo, 1)
    spread = Layout(topo, 16)
    shape = ShapeConfig("decode_8k", "decode", 8192, 32)
    rows = []
    ratios = []
    us = None

    def t(cost, layout):
        base = cost.overlap_s
        if not cost.fits:   # spill to remote HBM / host over DCN-class links
            spill = max(0.0, cost.working_set - layout.replica_hbm())
            base += spill / topo.bandwidth("cross_pod") / layout.model_degree
        return base

    for name in MODELS:
        cfg = get_config(name)
        f = lambda: (estimate(cfg, shape, compact),
                     estimate(cfg, shape, spread))
        if us is None:
            us = time_call(f)
        c_cost, s_cost = f()
        tc, ts = t(c_cost, compact), t(s_cost, spread)
        speedup = tc / ts
        ratios.append(speedup)
        rows.append(row(
            f"fig5_local_vs_distributed/{name}", us,
            f"ws_GB={c_cost.working_set/1e9:.0f};compact_ms={tc*1e3:.3f};"
            f"spread_ms={ts*1e3:.3f};dist_speedup={speedup:.2f};"
            f"compact_fits={c_cost.fits}"))
    rows.append(row(
        "fig5_local_vs_distributed/range", us,
        f"dist_speedup_range={min(ratios):.2f}x..{max(ratios):.2f}x; "
        f"crossover at group HBM (256GB) "
        f"(paper: 0.59x..2.50x, crossover at 32MB L3)"))
    return rows
