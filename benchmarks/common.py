"""Benchmark helpers: timing + the ``name,us_per_call,derived`` CSV row."""
from __future__ import annotations

import time
from typing import Callable, List


def time_call(fn: Callable, *, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall time of fn() in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def emit(rows: List[str]):
    for r in rows:
        print(r, flush=True)
