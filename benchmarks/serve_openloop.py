"""Open-loop serving benchmark: Poisson-ish arrivals against the paged
chiplet-aware KV allocator, comparing LAZY (chunked prefill + elastic page
growth) against EAGER (full capped reservation at admission) for the same
byte budget — and, within lazy mode, SWAP-tier eviction (spill parked
pages to host, resume mid-decode) against RESTART eviction (recompute from
scratch, the PR-3 policy).

A client coroutine on the engine's shared TaskRuntime submits requests over
time from a seeded schedule (exponential inter-arrival gaps measured in
engine rounds) with a LONG-TAIL ``max_new`` mix — most requests are short,
a minority run to a large token budget.  That is exactly the workload where
eager reservation wastes memory: every long-tail request pins its worst-
case page count at admission, while the lazy allocator commits one chunk's
pages and grows as ``pos`` crosses page boundaries, parking mid-decode on
exhaustion.  The benchmark reports the *admitted concurrency* (peak
simultaneously-reserved streams) both ways, plus TTFT/TPOT tails, park /
lazy-growth counts, spill/restore/eviction counts with the WASTED-
RECOMPUTE metric (``recompute_tokens`` — the tokens restart eviction
throws away, driven to 0 by the swap tier), and the per-chunk prefill
footprint from ``costmodel.prefill_chunk_bytes``.

The default run compares all three (lazy-swap / lazy-restart / eager) on
one schedule and asserts token identity across them, ``recompute_tokens
== 0`` in swap mode, and that every restart-mode eviction became a
spill/restore cycle instead of recompute.

Chunk ticks run on one of TWO COMPILED PATHS (``--prefill-mode``):
"parallel" (default) fuses a whole C-token chunk into ONE model forward —
intra-chunk causal attention over the gathered ring prefix plus chunk
scans for rgLRU/SSD state — while "scan" keeps the per-token reference (C
sequential model steps per chunk tick).  Whenever the lazy run uses the
parallel path, a scan twin runs on the same schedule and the benchmark
asserts token identity plus the model-step claim (1 step per chunk tick
vs C).  The parallel path's attention runs on one of two kernels
(``--chunk-kernel``): "blocked" (default) streams the ring + chunk KV
through a Pallas online-softmax kernel in (block_q, block_kv) tiles,
"dense" materializes the full (C, W + C) einsum score block.  Mixed ticks
(prefill chunks and decoders in one batch) split into two compiled steps
by default (``--no-split-ticks`` pads decoders into the chunk forward
instead, paying C-1 masked query rows each).  The default parallel run
adds a kernel twin and a split twin on the same schedule and asserts
token identity, the blocked < dense transient claim, and zero masked
decode rows under splitting.  ``--chunk-sweep`` sweeps chunk sizes x
{path, kernel, split} at equal byte budget (``--prefill-chunk`` pins a
single size).

``--spec-decode ngram`` runs the SPECULATIVE DECODING comparison
instead: the prompt-lookup (n-gram) drafter proposes up to ``--spec-k``
tokens per decode tick from the stream's own committed history, one
all-position-logits fused forward verifies them, and greedy acceptance
keeps the longest matching prefix — token-identical to the spec-off
engine by construction, asserted on every run.  The schedule is
lookup-friendly (short prompts, long generations, params doctored so
greedy decode is self-repetitive — see ``lookup_friendly``); the run
asserts measured acceptance > 0, accepted-tokens-per-model-step > 1.0
with the per-path step costs counted from optimized HLO (spec-off pins
this metric at exactly 1.0), and a tpot_p50 strictly below the spec-off
twin on the same schedule.

``--prefix-share`` runs the SHARED-PREFIX TENANT workload instead: T
tenants, each with a fixed multi-page preamble (per-tenant lengths), one
warm request per tenant publishing the preamble pages into the prefix
index, then a burst of identical-prompt requests per tenant that must
ATTACH those pages.  Two cells per mode: page-sized chunks (the prefill-
skip measurement — every cache-hit request may run only its 1-chunk
unshared tail, >=80% of prefill chunks skipped) and a whole-prompt first
chunk (admission charges the full prompt, so the peak admitted
concurrency at the same per-domain byte budget is the gate — sharing
must admit STRICTLY more streams).  Token identity sharing-on vs
sharing-off is asserted across both cells; ``--no-prefix-share`` reports
the unshared baseline only.

    PYTHONPATH=src python benchmarks/serve_openloop.py                  # all 3
    PYTHONPATH=src python benchmarks/serve_openloop.py --prefill-chunked
    PYTHONPATH=src python benchmarks/serve_openloop.py --eager
    PYTHONPATH=src python benchmarks/serve_openloop.py --chunk-sweep
    PYTHONPATH=src python benchmarks/serve_openloop.py --prefix-share --smoke
    PYTHONPATH=src python benchmarks/serve_openloop.py --smoke          # CI
    PYTHONPATH=src python benchmarks/serve_openloop.py --prefill-chunked \
        --evict-mode swap --smoke                                       # CI
    PYTHONPATH=src python benchmarks/serve_openloop.py --prefill-chunked \
        --prefill-mode parallel --smoke                                 # CI
    PYTHONPATH=src python benchmarks/serve_openloop.py --prefill-chunked \
        --chunk-kernel dense --no-split-ticks --smoke
    PYTHONPATH=src python benchmarks/serve_openloop.py --spec-decode \
        ngram --smoke                                                   # CI
    PYTHONPATH=src python benchmarks/serve_openloop.py --async-swap \
        --smoke                                                         # CI

``--async-swap`` runs the ASYNC TWO-TIER MEMORY comparison instead: the
transfer engine issues each victim's D2H spill and keeps decoding —
pages re-grant only when the per-round poll (or a fence) lands the copy
— against the synchronous swap twin on the same oversubscription
schedule.  Gates, all asserted in-run: token identity, zero recomputed
tokens, spill cycles actually happened, ``pool.audit()`` exact while
transfers were in flight, and no added tpot_p50 vs the sync twin.  The
report adds the overlap-efficiency surface: decode ticks run with bytes
on the wire, overlap rounds per spill, fence-wait count, peak in-flight
footprint and the costmodel-priced host-link seconds.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).parent))

from common import emit, row

from repro.configs import REGISTRY, reduced_config
from repro.core.controller import ControllerConfig
from repro.core.costmodel import (fwd_flops_per_token, kv_cache_bytes,
                                  prefill_chunk_bytes)
from repro.configs.base import ShapeConfig
from repro.core.topology import ChipletTopology
from repro.serving.engine import EngineConfig, ServeEngine


def longtail_schedule(seed: int, n: int, mean_gap: float,
                      vocab: int, max_len: int):
    """Seeded (gap_rounds, prompt, max_new) arrivals; exponential gaps and
    a long-tail ``max_new`` mix: ~3/4 short generations, ~1/4 that run
    close to the ring width (the requests whose eager reservations pin
    whole domains)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        gap = int(rng.exponential(mean_gap))
        # prompts up to half the ring: long ones span several prefill chunks
        plen = int(rng.integers(4, max(5, max_len // 2)))
        tail_lo = min(max_len // 2, max_len - plen - 1)
        if tail_lo > 4 and rng.random() < 0.25:
            max_new = int(rng.integers(tail_lo, max_len - plen))
        else:
            max_new = int(rng.integers(4, max(5, max_len // 8)))
        out.append((gap, rng.integers(2, vocab, size=plen), max_new))
    return out


SLO_MAX_LEN = 48                        # page geometry the schedule lengths
SLO_GROUPS = 4                          # below are tuned against


def slo_schedule(seed: int, n_batch: int, n_interactive: int, vocab: int):
    """Mixed-tenant arrivals for the SLO-class cells, as TWO waves.

    The ``batch`` wave arrives at tight gaps: 2-page prompts whose
    chunked prefill parks mid-prefill fast under oversubscription, so
    the wait line grows a PARKED head holding pages.  The
    ``interactive`` wave is 1-page requests released only once that
    congestion exists (``run_slo_mode``'s trigger client): arrivals
    whose charged pages fit the bypass-safety bound while FIFO would
    hold them behind the parked head."""
    rng = np.random.default_rng(seed)
    bigs, inter = [], []
    for i in range(n_batch):
        gap = 0 if i == 0 else int(rng.integers(0, 2))
        plen = int(rng.integers(17, 21))
        max_new = int(rng.integers(10, 13))
        bigs.append((gap, rng.integers(2, vocab, size=plen), max_new,
                     "batch"))
    for _ in range(n_interactive):
        gap = int(rng.integers(0, 3))
        plen = int(rng.integers(4, 8))
        max_new = int(rng.integers(2, 5))
        inter.append((gap, rng.integers(2, vocab, size=plen), max_new,
                      "interactive"))
    return bigs, inter


def spec_schedule(seed: int, n: int, mean_gap: float,
                  vocab: int, max_len: int):
    """Seeded arrivals for the speculative-decoding cells: SHORT prompts,
    LONG generations — tpot-dominated streams where the drafter gets a
    history to look up and the verify width amortizes.

    Arrivals are SERIALIZED (gap = max_len rounds, so each stream decodes
    alone): the gate metric is tpot_p50, a per-stream latency, and under
    oversubscription the park/queue share of tpot swamps the per-token
    signal with admission noise that has nothing to do with speculation.
    The admission-pressure cells (default mode, --prefix-share) measure
    contention; these cells measure the decode loop."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        gap = 0 if i == 0 else max_len
        plen = int(rng.integers(4, 9))
        prompt = rng.integers(2, vocab, size=plen)
        # near-full-ring generations: the lookup drafter only starts once
        # the stream's token orbit closes (~sqrt(V) tokens for a random
        # map), so the drafted fraction — and the measured win — scales
        # with how far past that onset each stream decodes
        max_new = int(rng.integers(2 * max_len // 3, max_len - plen))
        out.append((gap, prompt, max_new))
    return out


def lookup_friendly(params):
    """Make the reduced model PREDICTABLE: zero every residual-branch
    output projection ('wo'), so each block passes the residual through
    and the logits become a fixed function of the LAST token alone.
    Greedy decode then walks a deterministic token map, which enters a
    short cycle — the self-repetitive regime prompt-lookup drafting
    exploits on real models (grounded / repetitive text).  Random-weight
    reduced models are incompressible token sources (their greedy output
    never repeats), so without this the n-gram drafter measures only the
    reject path.  Both spec cells share the SAME doctored params, so the
    token-identity gate is unweakened."""
    import jax

    def z(path, leaf):
        if "'wo'" in jax.tree_util.keystr(path):
            return leaf * 0
        return leaf
    return jax.tree_util.tree_map_with_path(z, params)


def run_mode(args, cfg, *, lazy: bool, evict_mode: str = "swap",
             prefill_mode: str = None, prefill_chunk: int = None,
             chunk_kernel: str = None, split_ticks: bool = None,
             spec_decode: str = "off", spec_k: int = None,
             schedule=None, params_fn=None, warm: bool = False):
    topo = ChipletTopology(n_pods=1, groups_per_pod=4, chips_per_group=1)
    # max_batch is 2x the memory budget's stream count: the paged pool
    # admits by pages actually reserved, not worst-case slots
    max_batch = 2 * args.pool_streams
    ecfg = EngineConfig(
        max_batch=max_batch, max_len=args.max_len, adaptive=True, lazy=lazy,
        pool_streams=args.pool_streams, evict_mode=evict_mode,
        headroom=args.headroom,
        prefill_mode=prefill_mode or args.prefill_mode,
        prefill_chunk=(prefill_chunk if prefill_chunk is not None
                       else args.prefill_chunk),
        chunk_kernel=chunk_kernel or args.chunk_kernel,
        split_ticks=(args.split_ticks if split_ticks is None
                     else split_ticks),
        spec_decode=spec_decode,
        spec_k=(spec_k if spec_k is not None else args.spec_k),
        spec_ngram=args.spec_ngram,
        slo_bypass=args.slo_bypass,
        controller=ControllerConfig(scheduler_timer=8, threshold=64.0,
                                    min_dwell=2))
    eng = ServeEngine(cfg, topo, ecfg, spread_rate=1, seed=args.seed)
    if params_fn is not None:
        eng.params = params_fn(eng.params)
    n_warm = 0
    if warm:
        # compile every (path, pow-2 bucket) combo the timed run can
        # touch, then zero the counters so the cells measure steady-state
        # serving, not XLA backend compiles mid-request.  warm_steps
        # drives the engine's REAL dispatch partials over the full
        # (kind, width, batch-bucket) grid with null rows; the traffic
        # phases then warm the host-side tails (commit bookkeeping,
        # eager jnp ops) the step grid can't reach: one solo request,
        # then a staggered pair for the mixed (split chunk+decode) tick.
        eng.warm_steps()
        eng.submit(np.arange(2, 6), 24)
        eng.run_until_done()
        eng.open_loop_client([(0, np.arange(2, 10), 20),
                              (3, np.arange(3, 8), 16)])
        eng.run_until_done()
        eng.counters.reset()
        n_warm = 3
    sched = (schedule if schedule is not None
             else longtail_schedule(args.seed, args.requests, args.mean_gap,
                                    cfg.vocab, args.max_len))
    eng.open_loop_client(sched)
    res = eng.run_until_done()
    reqs = eng.submitted[n_warm:]
    assert len(reqs) == args.requests
    assert all(r.done for r in reqs), \
        f"{sum(not r.done for r in reqs)} requests unfinished"
    return eng, res


def report(mode: str, args, eng, res):
    st = ServeEngine.stats(eng.submitted)
    kv = eng.kv_stats()
    c = res["counters"]
    emit([
        row(f"openloop_ttft_p50[{mode}]", st["ttft_p50"] * 1e6,
            f"p99={st['ttft_p99']*1e6:.0f}us n={st['n']}"),
        row(f"openloop_tpot_p50[{mode}]", st["tpot_p50"] * 1e6,
            f"p99={st['tpot_p99']*1e6:.0f}us tokens={st['tokens']}"),
        row(f"openloop_admitted[{mode}]", kv["peak_active_tables"],
            f"peak concurrent reservations (budget="
            f"{args.pool_streams} streams/domain), peak_blocks="
            f"{kv['peak_used_blocks']:.0f}/{kv['total_blocks']:.0f}"),
        row(f"openloop_backpressure[{mode}]", kv["alloc_failures"],
            f"park_rate={kv['park_rate']:.2f} "
            f"mid_decode_parks={kv['mid_decode_parks']:.0f} "
            f"lazy_grows={kv['lazy_grows']:.0f} "
            f"evictions={kv['evictions']:.0f} "
            f"unblocked={c.get('tasks_unblocked', 0):.0f}"),
        row(f"openloop_recompute[{mode}]", kv["recompute_tokens"],
            f"tokens thrown away by restart evictions; spills="
            f"{kv['spills']:.0f} spilled_pages={kv['spilled_pages']:.0f} "
            f"restores={kv['restores']:.0f} "
            f"peak_spilled_bytes={kv['peak_spilled_bytes']:.0f}"),
        row(f"openloop_migration[{mode}]", kv["blocks_migrated"],
            f"tables_migrated={kv['tables_migrated']:.0f} "
            f"spill_repoints={kv['spill_repoints']:.0f} "
            f"relayouts={len(res['relayouts'])}"),
    ])
    if mode == "lazy":
        max_prompt = max(len(r.prompt) for r in eng.submitted)
        whole = kv_cache_bytes(
            eng.cfg, ShapeConfig("kv", "decode", max_prompt, 1), 1)
        emit([row("openloop_prefill_chunk_bytes",
                  kv["prefill_chunk_bytes"],
                  f"chunks={kv['prefill_chunks']:.0f} "
                  f"score_transient={kv['prefill_score_bytes']:.0f}B "
                  f"vs whole-prompt buffer {whole:.0f}B at S={max_prompt}")])
    if eng._lazy:
        emit([row(f"openloop_prefill_model_steps[{mode}]",
                  kv["prefill_model_steps"],
                  f"chunk_ticks={kv['chunk_ticks']:.0f} "
                  f"({eng._prefill_mode}: "
                  f"{kv['prefill_model_steps'] / max(1, kv['chunk_ticks']):.1f}"
                  f" model steps per chunk tick, chunk={eng._chunk}, "
                  f"kernel={kv['chunk_kernel']})")])
    if eng._lazy and eng._prefill_mode == "parallel":
        # masked decode-query rows a mixed tick would have paid in the
        # fused chunk forward, priced as forward FLOPs at ring depth
        saved_rows = kv["mixed_tick_decode_rows_saved"]
        n_split = res["counters"].get("split_ticks", 0)
        flops_per_row = fwd_flops_per_token(eng.cfg, args.max_len,
                                            decode=True)
        emit([row(f"openloop_split_ticks[{mode}]", n_split,
                  f"decode_rows_saved={saved_rows:.0f} "
                  f"(~{saved_rows * flops_per_row / 1e6:.1f} MFLOP, "
                  f"{saved_rows * flops_per_row / max(1, n_split) / 1e6:.1f}"
                  f" MFLOP/split-tick); residual masked rows="
                  f"{kv['decode_masked_query_rows']:.0f}")])
    moves = [(r["old_groups"], r["new_groups"], r["blocks_migrated"])
             for r in res["relayouts"]]
    print(f"[{mode}] relayouts (old_groups, new_groups, blocks_migrated): "
          f"{moves}")


def prefix_tenant_prompts(seed: int, tenant_pages, bt: int, vocab: int):
    """One FIXED prompt per tenant: a preamble spanning ``tenant_pages[i]``
    full KV pages plus one trailing token — the fully-shared-prefix case
    (the final prompt token always recomputes to seed generation, so the
    shareable prefix is exactly the full pages)."""
    rng = np.random.default_rng(seed)
    return [rng.integers(2, vocab, size=p * bt + 1) for p in tenant_pages]


def run_prefix_mode(args, cfg, *, share: bool, prefill_chunk,
                    max_len: int, pool_streams: int, per_tenant: int,
                    tenant_pages, max_new: int):
    """Warmed tenant workload on ONE chiplet-group domain: a warm wave
    (one request per tenant) publishes the preamble pages, then a burst
    of ``per_tenant`` identical-prompt requests per tenant measures
    cache-hit prefill and admission.  Returns the engine, its kv stats,
    the burst wave's prefill-chunk count, the peak shared-page gauge and
    all generated tokens."""
    topo = ChipletTopology(n_pods=1, groups_per_pod=1, chips_per_group=1)
    ecfg = EngineConfig(
        max_batch=2 * per_tenant * len(tenant_pages), max_len=max_len,
        adaptive=False, lazy=True, pool_streams=pool_streams,
        evict_mode="swap", prefill_chunk=prefill_chunk,
        prefill_mode=args.prefill_mode, chunk_kernel=args.chunk_kernel,
        split_ticks=args.split_ticks, prefix_share=share,
        cached_retention=args.cached_retention,
        slo_bypass=args.slo_bypass)
    eng = ServeEngine(cfg, topo, ecfg, spread_rate=1, seed=args.seed)
    prompts = prefix_tenant_prompts(args.seed, tenant_pages,
                                    eng.pool.block_tokens, cfg.vocab)
    # prompts must stay inside the ring: a wrap would (correctly)
    # invalidate the published pages and the bench would measure nothing
    assert all(len(p) + max_new <= eng.pool.pages_per_stream
               * eng.pool.block_tokens for p in prompts)
    for p in prompts:                    # warm wave: publish the pages
        eng.submit(p, max_new)
    eng.run_until_done()
    warm_chunks = eng.counters.totals.get("prefill_chunks", 0.0)
    for p in prompts:                    # measurement burst: cache hits
        for _ in range(per_tenant):
            eng.submit(p, max_new)
    eng.run_until_done()
    assert all(r.done for r in eng.submitted), "prefix bench deadlock"
    eng.pool.audit([])
    assert eng.pool.occupancy() == 0.0
    burst_chunks = (eng.counters.totals.get("prefill_chunks", 0.0)
                    - warm_chunks)
    peak_shared = (max((s.kv_shared_pages for s in eng.counters.samples),
                       default=0.0),
                   max((s.kv_shared_bytes for s in eng.counters.samples),
                       default=0.0))
    return (eng, eng.kv_stats(), burst_chunks, peak_shared,
            [r.generated for r in eng.submitted])


def run_prefix_bench(args, cfg, *, compare: bool):
    """The shared-prefix tenant workload (``--prefix-share`` /
    ``--no-prefix-share``).  With ``compare`` (sharing requested) runs
    every cell sharing-on AND sharing-off and asserts the ISSUE-7 gates:
    token identity, >=80% of prefill chunks skipped for a fully-shared
    prefix, and strictly more admitted concurrency at the same
    per-domain byte budget."""
    per_tenant = 2 if args.smoke else 4
    tenant_pages = (5, 4)          # per-tenant preamble lengths, in pages
    common = dict(max_len=96, pool_streams=3, per_tenant=per_tenant,
                  tenant_pages=tenant_pages, max_new=8)
    n_burst = per_tenant * len(tenant_pages)
    cells = {}
    for share in ((True, False) if compare else (False,)):
        tag = "share" if share else "no-share"
        # cell A — page-sized chunks: the prefill-skip measurement
        eng_a, kv_a, chunks_a, shared_a, toks_a = run_prefix_mode(
            args, cfg, share=share, prefill_chunk=None, **common)
        # cell B — whole-prompt first chunk: admission charges the full
        # prompt up front, so concurrency is admission-limited and the
        # cached-prefix discount (charge only the unshared tail) is
        # exactly what admits more streams
        eng_b, kv_b, chunks_b, shared_b, toks_b = run_prefix_mode(
            args, cfg, share=share, prefill_chunk=common["max_len"],
            **common)
        burst_a = eng_a.submitted[len(tenant_pages):]
        emit([
            row(f"prefix_burst_chunks[{tag}]", chunks_a,
                f"{n_burst} cache-burst requests x tenants "
                f"pages={tenant_pages}; hits={kv_a['prefix_hits']:.0f} "
                f"tokens_skipped={kv_a['prefill_tokens_skipped']:.0f} "
                f"pages_attached={kv_a['prefix_pages']:.0f}"),
            row(f"prefix_burst_ttft_p50[{tag}]",
                ServeEngine.stats(burst_a)["ttft_p50"] * 1e6,
                f"burst wave only; cow_forks={kv_a['cow_forks']:.0f} "
                f"peak_shared_pages={shared_a[0]:.0f} "
                f"peak_dedup_bytes_saved={shared_a[1]:.0f}"),
            row(f"prefix_admitted[{tag}]", kv_b["peak_active_tables"],
                f"whole-prompt admission cell (budget="
                f"{common['pool_streams']} streams/domain), peak_blocks="
                f"{kv_b['peak_used_blocks']:.0f}/"
                f"{kv_b['total_blocks']:.0f} "
                f"alloc_failures={kv_b['alloc_failures']:.0f}"),
            row(f"prefix_cached_pages[{tag}]", kv_a["cached_page_hits"],
                f"free-but-cached pages re-attached without any copy "
                f"({kv_a['retention']} retention: reclaims="
                f"{kv_a['cached_reclaims']:.0f} of the coldest-touched "
                f"free pages first)"),
        ])
        cells[share] = (kv_a, chunks_a, toks_a, kv_b, toks_b)
    if not compare:
        return
    kv_a, on_a, toks_a, kv_b, toks_b = cells[True]
    kv_a0, off_a, toks_a0, kv_b0, toks_b0 = cells[False]
    # gate 1: token identity, sharing on vs off, both cells (and across
    # cells — the chunking policy must not change tokens either)
    assert toks_a == toks_a0, "prefix sharing changed tokens (chunk cell)"
    assert toks_b == toks_b0, \
        "prefix sharing changed tokens (admission cell)"
    assert toks_a == toks_b, "chunk-size cells diverged"
    assert kv_a0["prefix_hits"] == 0 and kv_b0["prefix_hits"] == 0
    # gate 2: every cache-burst request ran ONLY its 1-chunk unshared
    # tail — >=80% of the prefill chunks the unshared run pays are
    # skipped outright
    skip = 1.0 - on_a / max(1.0, off_a)
    assert on_a == n_burst, \
        f"cache-hit burst ran {on_a:.0f} chunks, wanted {n_burst} tails"
    assert skip >= 0.80, \
        f"prefill-chunk skip {skip:.1%} below the 80% gate " \
        f"({on_a:.0f} vs {off_a:.0f} chunks)"
    # gate 3: strictly more admitted concurrency at the same byte budget
    assert kv_b["peak_active_tables"] > kv_b0["peak_active_tables"], \
        f"sharing admitted {kv_b['peak_active_tables']:.0f} streams, " \
        f"unshared {kv_b0['peak_active_tables']:.0f} — not strictly more"
    assert kv_a["prefix_hits"] >= n_burst
    print(f"prefix sharing token-identical: True "
          f"(chunk skip={skip:.1%}, admitted "
          f"{kv_b['peak_active_tables']:.0f} vs "
          f"{kv_b0['peak_active_tables']:.0f} streams at "
          f"{common['pool_streams']} streams/domain)")


def run_slo_mode(args, cfg, sched, *, bypass: bool):
    """One SLO-class cell.  The regime is PINNED (not taken from the
    generic args): four single-chip chiplet-group domains each sized for
    ONE max-length stream (``pool_streams=1`` — two batch tables
    oversubscribe a domain), swap-tier eviction, chunked-lazy growth,
    and the size-aware bypass toggled by ``bypass``.  adaptive=False
    keeps the twin runs deterministic (no controller relayouts).

    The interactive wave is submitted by a TRIGGER client that waits for
    the first mid-flight park: the wave lands exactly when the wait line
    has a parked head.  Twin dynamics are identical up to the first
    bypass grant (the no-bypass engine still WAKES bypass-class waiters,
    it just never grants them), so the trigger fires at the same round
    in both cells and the head-starvation gate compares like for like."""
    bigs, inter = sched
    topo = ChipletTopology(n_pods=1, groups_per_pod=SLO_GROUPS,
                           chips_per_group=1)
    ecfg = EngineConfig(
        max_batch=4, max_len=SLO_MAX_LEN, adaptive=False, lazy=True,
        pool_streams=1, evict_mode="swap", slo_bypass=bypass)
    eng = ServeEngine(cfg, topo, ecfg, spread_rate=1, seed=args.slo_seed)
    eng.open_loop_client(bigs)
    eng._clients += 1

    def iclient():
        try:
            while not eng._parked:
                yield
            for gap, prompt, max_new, cls in inter:
                for _ in range(int(gap)):
                    yield
                eng.submit(prompt, max_new, cls=cls)
        finally:
            eng._clients -= 1

    eng.sched.spawn(iclient(), name="slo-interactive", priority=2)
    eng.run_until_done()
    assert all(r.done for r in eng.submitted), "slo bench deadlock"
    return eng


def admission_delay_rounds(eng, cls: str):
    """Deterministic TTFT proxy: engine rounds from submit to the first
    page grant, per request of ``cls`` — round-counted, so the bypass-on
    vs bypass-off comparison is seed-exact (no wall-clock noise)."""
    return [r.grant_rounds[0] - r.arrive_round
            for r in eng.submitted if r.cls == cls and r.grant_rounds]


def run_slo_bench(args, cfg):
    """The mixed-tenant SLO-class workload (``--slo-classes``): the SAME
    seeded schedule through the size-aware bypass engine and a FIFO-only
    twin.  Gates, all asserted in-run:

      1. token identity per rid (the bypass must be invisible in output);
      2. the bypass actually fired (and the twin never did);
      3. strictly more peak concurrent reservations with bypass;
      4. ZERO head starvation — the head the FIRST bypass jumped is
         re-granted at the same round or EARLIER than in the FIFO twin
         (dynamics are twin-identical up to that round, so the comparison
         is exact);
      5. interactive admission delay (round-counted TTFT proxy) p99
         strictly improves, with per-class wall-clock TTFT/TPOT p50/p99
         reported from ``kv_stats()['per_class']``.
    """
    sched = slo_schedule(args.slo_seed, 8, 8, cfg.vocab)
    cells = {}
    for bypass in (True, False):
        tag = "bypass" if bypass else "fifo"
        eng = run_slo_mode(args, cfg, sched, bypass=bypass)
        kv = eng.kv_stats()
        for c, st in sorted(kv["per_class"].items()):
            if not st.get("n"):
                continue
            emit([row(f"slo_ttft_p50[{tag},{c}]", st["ttft_p50"] * 1e6,
                      f"p99={st['ttft_p99']*1e6:.0f}us n={st['n']:.0f} "
                      f"admit_delay_p99="
                      f"{np.percentile(admission_delay_rounds(eng, c), 99):.0f}"
                      f" rounds"),
                  row(f"slo_tpot_p50[{tag},{c}]", st["tpot_p50"] * 1e6,
                      f"p99={st['tpot_p99']*1e6:.0f}us "
                      f"tokens={st['tokens']:.0f}")])
        emit([row(f"slo_admitted[{tag}]", kv["peak_active_tables"],
                  f"peak concurrent reservations; bypass_grants="
                  f"{kv['bypass_grants']:.0f} "
                  f"floor_pages={kv['bypass_floor_pages']:.0f} "
                  f"head_wait_ticks={kv['head_wait_ticks']:.0f} "
                  f"spills={kv['spills']:.0f} "
                  f"(watchdog={kv['watchdog_spills']:.0f})")])
        cells[bypass] = (eng, kv)
    on, kv_on = cells[True]
    off, kv_off = cells[False]
    toks = {b: [r.generated for r in sorted(cells[b][0].submitted,
                                            key=lambda r: r.rid)]
            for b in cells}
    # gate 1 — the CI divergence gate
    assert toks[True] == toks[False], "slo bypass changed tokens"
    # gate 2 — the mechanism fired, and only when enabled
    assert kv_on["bypass_grants"] > 0, \
        "bypass never fired — the schedule stopped congesting the line"
    assert kv_off["bypass_grants"] == 0, "FIFO twin granted a bypass"
    # gate 3 — strictly more admitted concurrency on the same schedule
    assert kv_on["peak_active_tables"] > kv_off["peak_active_tables"], \
        f"bypass admitted {kv_on['peak_active_tables']:.0f} concurrent " \
        f"streams, FIFO {kv_off['peak_active_tables']:.0f} — not " \
        f"strictly more"
    # gate 4 — zero head starvation: the first jumped head's re-grant
    r0, _, head_rid = on.bypass_log[0]
    grant_on = next((t for t in on.submitted[head_rid].grant_rounds
                     if t >= r0), None)
    grant_off = next((t for t in off.submitted[head_rid].grant_rounds
                      if t >= r0), None)
    assert grant_on is not None and grant_off is not None, \
        f"jumped head rid={head_rid} has no re-grant after round {r0}"
    delay = grant_on - grant_off
    assert delay <= 0, \
        f"bypass delayed the jumped head rid={head_rid}: granted at " \
        f"round {grant_on} vs {grant_off} in the FIFO twin"
    # gate 5 — the interactive win, round-counted (seed-exact)
    d_on = admission_delay_rounds(on, "interactive")
    d_off = admission_delay_rounds(off, "interactive")
    p99_on, p99_off = np.percentile(d_on, 99), np.percentile(d_off, 99)
    assert p99_on < p99_off, \
        f"interactive admission-delay p99 {p99_on:.0f} rounds not below " \
        f"FIFO's {p99_off:.0f}"
    print(f"slo bypass token-identical: True "
          f"(bypass_grants={kv_on['bypass_grants']:.0f}, admitted "
          f"{kv_on['peak_active_tables']:.0f} vs "
          f"{kv_off['peak_active_tables']:.0f} streams, head delay="
          f"{delay} rounds, interactive admit-delay p99 "
          f"{p99_on:.0f} vs {p99_off:.0f} rounds)")


def accepted_per_model_step(eng, kv) -> float:
    """Committed decode tokens per sequential MODEL STEP, with the steps
    each compiled path costs counted from its optimized HLO
    (``ServeEngine.measured_model_steps``), not assumed: plain decode
    rows pay steps(decode) each, drafted rows steps(spec) per verify and
    steps(chunk) per rollback re-apply.  A spec-off engine scores exactly
    1.0 on this metric (every committed token is one decode-row forward),
    so > 1.0 is the speculation win."""
    den = kv["decode_row_forwards"] * eng.measured_model_steps("decode")
    if kv["spec_row_forwards"]:         # spec-off engines build no verify
        den += kv["spec_row_forwards"] * eng.measured_model_steps("spec")
    if kv["spec_row_reapplies"]:
        den += (kv["spec_row_reapplies"]
                * eng.measured_model_steps("chunk"))
    return kv["decode_committed_tokens"] / max(1.0, den)


def run_spec_bench(args, cfg):
    """The speculative-decoding headline (``--spec-decode ngram``): the
    n-gram drafter + verify path against the spec-off engine on the same
    lookup-friendly schedule and SAME (predictable) params.  Gates, all
    asserted in-run: token identity, measured acceptance > 0,
    HLO-counted accepted-tokens-per-model-step > 1.0 (spec-off pins the
    metric at exactly 1.0), and tpot_p50 strictly below spec-off.

    Both cells run the DENSE chunk kernel: the interpret-mode Pallas
    kernel prices each extra query row at a full kernel pass, which is a
    CPU-emulation artifact the kernel twin gate already covers — kernel
    choice is orthogonal to (and identity-asserted against) the
    speculation machinery."""
    # Speculation amortizes over DECODE length: the drafter needs one
    # cycle lap of history before it starts proposing, so short smoke
    # generations spend most tokens in the undrafted warmup.  Give the
    # spec cells a longer ring than the admission-pressure cells
    # (--max-len above the floor is honored).
    args = argparse.Namespace(**{**vars(args),
                                 "max_len": max(args.max_len, 144)})
    sched = spec_schedule(args.seed, args.requests, args.mean_gap,
                          cfg.vocab, args.max_len)
    cells = {}
    for spec in (args.spec_decode, "off"):
        tag = f"spec-{spec}"
        eng, res = run_mode(args, cfg, lazy=True,
                            evict_mode=args.evict_mode,
                            chunk_kernel="dense", spec_decode=spec,
                            schedule=sched, params_fn=lookup_friendly,
                            warm=True)
        reqs = eng.submitted[3:]                   # drop the warm requests
        st = ServeEngine.stats(reqs)
        kv = eng.kv_stats()
        toks = [r.generated for r in sorted(reqs, key=lambda r: r.rid)]
        ratio = accepted_per_model_step(eng, kv)
        emit([
            row(f"openloop_tpot_p50[{tag}]", st["tpot_p50"] * 1e6,
                f"p99={st['tpot_p99']*1e6:.0f}us tokens={st['tokens']}"),
            row(f"spec_accepted_per_model_step[{tag}]", ratio,
                f"committed={kv['decode_committed_tokens']:.0f} over "
                f"decode_rows={kv['decode_row_forwards']:.0f} "
                f"verify_rows={kv['spec_row_forwards']:.0f} "
                f"reapply_rows={kv['spec_row_reapplies']:.0f} "
                f"(HLO steps: decode="
                f"{eng.measured_model_steps('decode'):.0f}"
                + (f" chunk={eng.measured_model_steps('chunk'):.0f}"
                   f" spec={eng.measured_model_steps('spec'):.0f})"
                   if spec != "off" else ")")),
        ])
        if spec != "off":
            emit([
                row(f"spec_accept_rate[{tag}]", kv["spec_accept_rate"],
                    f"drafted={kv['spec_tokens_drafted']:.0f} "
                    f"accepted={kv['spec_tokens_accepted']:.0f} "
                    f"rollbacks={kv['spec_rollbacks']:.0f} "
                    f"full_rejects={kv['spec_full_rejects']:.0f} "
                    f"k={args.spec_k}"),
                row(f"spec_wasted_bytes[{tag}]", kv["spec_rejected_bytes"],
                    f"rejected-draft compute+KV bytes; rollback traffic="
                    f"{kv['spec_rollback_bytes']:.0f}B "
                    f"(ckpts={kv['spec_ckpts']:.0f} "
                    f"ckpt_pages={kv['spec_ckpt_pages']:.0f} "
                    f"restored={kv['spec_rollback_pages']:.0f})"),
            ])
        cells[spec] = (st, kv, toks, ratio)
    st_on, kv_on, toks_on, ratio_on = cells[args.spec_decode]
    st_off, kv_off, toks_off, ratio_off = cells["off"]
    # gate 1 — the CI divergence gate: greedy acceptance must make the
    # speculative engine TOKEN-IDENTICAL to the plain one
    assert toks_on == toks_off, "speculative decode changed tokens"
    # gate 2: the drafter must actually land accepts on this schedule (a
    # 0-acceptance run measures only the reject path)
    assert kv_on["spec_tokens_accepted"] > 0, \
        "acceptance rate is exactly 0 — the lookup-friendly schedule " \
        "stopped being lookup-friendly"
    # gate 3: the measured win — strictly more than one committed token
    # per HLO-counted model step, against the off-cell's exact 1.0
    assert ratio_off == 1.0, \
        f"spec-off accepted/model-step {ratio_off:.3f} != 1.0 — the " \
        f"denominator accounting drifted"
    assert ratio_on > 1.0, \
        f"accepted tokens per model step {ratio_on:.3f} not > 1.0"
    # gate 4: the wall-clock win, same schedule, both cells steady-state
    assert st_on["tpot_p50"] < st_off["tpot_p50"], \
        f"spec tpot_p50 {st_on['tpot_p50']*1e6:.0f}us not below " \
        f"spec-off {st_off['tpot_p50']*1e6:.0f}us"
    print(f"speculative decode token-identical: True "
          f"(accept_rate={kv_on['spec_accept_rate']:.2f}, "
          f"{ratio_on:.2f} accepted tokens/model step vs 1.00 off, "
          f"tpot_p50 {st_on['tpot_p50']*1e6:.0f}us vs "
          f"{st_off['tpot_p50']*1e6:.0f}us off)")


def oversub_schedule(seed: int, n: int, vocab: int, max_len: int):
    """Dense arrivals at short gaps with generations sized to thrash a
    1-stream/domain budget: the schedule that deterministically forces
    spill/restore cycles (the PR-4 acceptance workload)."""
    rng = np.random.default_rng(seed)
    return [(int(rng.integers(0, 2)),
             rng.integers(2, vocab, size=4),
             max(8, int(max_len * 0.55))) for _ in range(n)]


def run_async_mode(args, cfg, *, async_swap: bool):
    """One async-swap bench cell: a single replica group at a
    1-stream/domain budget on the oversubscription schedule, with the
    pool audited at EVERY transfer transition (issue / poll / fence) —
    including while bytes are in flight."""
    topo = ChipletTopology(n_pods=1, groups_per_pod=1, chips_per_group=1)
    ecfg = EngineConfig(
        max_batch=4, max_len=args.max_len, adaptive=False, lazy=True,
        pool_streams=args.pool_streams, evict_mode="swap",
        headroom=args.headroom, async_swap=async_swap,
        spill_watermarks=(0.5, 0.25),
        controller=ControllerConfig(scheduler_timer=8, threshold=64.0,
                                    min_dwell=2))
    eng = ServeEngine(cfg, topo, ecfg, spread_rate=1, seed=args.seed)
    pool = eng.pool
    audits = {"calls": 0, "inflight": 0}

    def live():
        return [r.table for r in eng.submitted if r.table is not None]

    for name in ("spill_issue", "spill_poll", "spill_fence", "spill"):
        orig = getattr(pool, name)

        def wrapped(*a, _orig=orig, **kw):
            out = _orig(*a, **kw)
            if pool.inflight_tables():
                audits["inflight"] += 1
            pool.audit(live())
            audits["calls"] += 1
            return out

        setattr(pool, name, wrapped)
    sched = oversub_schedule(args.seed, max(6, args.requests // 2),
                             cfg.vocab, args.max_len)
    eng.open_loop_client(sched)
    res = eng.run_until_done()
    assert all(r.done for r in eng.submitted), "async bench deadlock"
    assert eng.pool.inflight_tables() == 0, "transfer outlived the run"
    return eng, res, audits


def run_async_bench(args, cfg):
    """The async two-tier memory headline (``--async-swap``): overlap the
    swap tier's D2H/H2D transfers behind the token loop and charge them
    nothing.  Gates, all asserted in-run: token identity vs the
    synchronous twin on the same schedule, ``recompute_tokens == 0``,
    spill cycles actually happened, ``pool.audit()`` exact WHILE
    transfers were in flight, and a tpot_p50 no worse than the sync twin
    (generous 1.5x factor — interpret-mode CPU timings are noisy)."""
    cells = {}
    for is_async in (True, False):
        tag = "async" if is_async else "sync"
        eng, res, audits = run_async_mode(args, cfg, async_swap=is_async)
        st = ServeEngine.stats(eng.submitted)
        kv = eng.kv_stats()
        toks = [r.generated for r in
                sorted(eng.submitted, key=lambda r: r.rid)]
        cells[tag] = (st, kv, toks, audits, eng)
        emit([row(f"openloop_tpot_p50[{tag}-swap]", st["tpot_p50"] * 1e6,
                  f"p99={st['tpot_p99']*1e6:.0f}us spills={kv['spills']:.0f}"
                  f" restores={kv['restores']:.0f} "
                  f"recompute={kv['recompute_tokens']:.0f}")])
    st_a, kv_a, toks_a, audits_a, eng_a = cells["async"]
    st_s, kv_s, toks_s, _, _ = cells["sync"]
    # overlap efficiency: decode ticks that ran with bytes on the wire,
    # rounds each landed spill hid behind, fences that actually waited,
    # peak in-flight footprint, and the priced host-link time
    peak_pages = max((s.kv_spill_inflight_pages
                      for s in eng_a.counters.samples), default=0.0)
    peak_bytes = max((s.kv_spill_inflight_bytes
                      for s in eng_a.counters.samples), default=0.0)
    emit([
        # NB on CPU CI the D2H gather is ready instantly, so every issue
        # lands at the NEXT round's poll: the engine advances exactly one
        # full round per spill without blocking (overlap_rounds/spill =
        # 1.0) and decode ticks rarely land inside that one-round window.
        # On hardware where the copy takes many rounds, ticks_while_
        # inflight counts the decode work the transfer actually hid behind.
        row("async_swap_overlap_ticks", kv_a["ticks_while_inflight"],
            f"decode ticks with a transfer in flight; "
            f"overlap_rounds/spill={kv_a['overlap_rounds_per_spill']:.1f} "
            f"fence_waits={kv_a['fence_waits']:.0f} "
            f"issues={kv_a['spill_issues']:.0f}"),
        row("async_swap_inflight_peak_bytes", peak_bytes,
            f"peak_pages={peak_pages:.0f} "
            f"prefetches={kv_a['restore_prefetches']:.0f} "
            f"pinned_host={kv_a['swap_tier']['pinned_host']} "
            f"tier_overflows={kv_a['swap_tier']['overflow_allocs']:.0f}"),
        row("async_swap_link_us", (kv_a["d2h_seconds"]
                                   + kv_a["h2d_seconds"]) * 1e6,
            f"d2h={kv_a['d2h_bytes']:.0f}B h2d={kv_a['h2d_bytes']:.0f}B "
            f"priced at the host-link bw (overlapped behind the loop)"),
    ])
    # gate 1: token identity against the synchronous twin
    assert toks_a == toks_s, "async/sync swap token divergence"
    # gate 2: the swap tier still never recomputes
    assert kv_a["recompute_tokens"] == 0 and kv_s["recompute_tokens"] == 0
    # gate 3: the schedule actually exercised spill cycles, and every
    # issue landed exactly once
    assert kv_a["spills"] >= 1, "oversubscription never spilled"
    assert kv_a["spill_issues"] == kv_a["spills"], \
        "issued transfers did not all land"
    # gate 4: accounting stayed exact WITH transfers in flight (the
    # audit wrapper runs at every issue/poll/fence)
    assert audits_a["calls"] > 0 and audits_a["inflight"] > 0, \
        "audit never observed an in-flight transfer"
    # gate 5: overlap must not add decode latency vs the sync twin
    assert st_a["tpot_p50"] <= st_s["tpot_p50"] * 1.5, \
        f"async tpot_p50 {st_a['tpot_p50']*1e6:.0f}us regressed vs " \
        f"sync {st_s['tpot_p50']*1e6:.0f}us"
    print(f"async swap token-identical: True (spills={kv_a['spills']:.0f} "
          f"overlapped ticks={kv_a['ticks_while_inflight']:.0f}, "
          f"fence_waits={kv_a['fence_waits']:.0f}, tpot_p50 "
          f"async={st_a['tpot_p50']*1e6:.0f}us "
          f"sync={st_s['tpot_p50']*1e6:.0f}us)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--mean-gap", type=float, default=1.0,
                    help="mean inter-arrival gap in engine rounds")
    ap.add_argument("--pool-streams", type=int, default=1,
                    help="KV budget per domain, in full-length streams "
                         "(the old slot-monolith limit)")
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefill-chunked", action="store_true",
                    help="run ONLY the lazy mode (chunked prefill + "
                         "elastic page growth)")
    ap.add_argument("--eager", action="store_true",
                    help="run ONLY the eager-reservation mode")
    ap.add_argument("--evict-mode", choices=("swap", "restart"),
                    default="swap",
                    help="stall-watchdog policy for the lazy run: spill "
                         "parked pages to the host tier (swap) or "
                         "recompute from scratch (restart)")
    ap.add_argument("--prefill-mode", choices=("parallel", "scan"),
                    default="parallel",
                    help="chunk-tick compiled path: fuse the whole chunk "
                         "into ONE model forward (parallel) or scan "
                         "decode_step per token (scan, the reference)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="prompt tokens per prefill chunk (default: one "
                         "KV page)")
    ap.add_argument("--chunk-kernel", choices=("blocked", "dense"),
                    default="blocked",
                    help="fused-path attention kernel: the Pallas "
                         "online-softmax ring kernel (blocked, one "
                         "(block_q, block_kv) tile live) or the einsum "
                         "reference (dense, a full (C, W+C) score block)")
    ap.add_argument("--split-ticks", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="run mixed ticks as TWO compiled steps — a fused "
                         "chunk step for prefilling streams plus a "
                         "single-token step for decoders — instead of one "
                         "padded chunk forward where every decode stream "
                         "pays C-1 masked query rows")
    ap.add_argument("--prefix-share", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="run ONLY the shared-prefix tenant workload: a "
                         "warm wave publishes per-tenant preamble pages, "
                         "then an identical-prompt burst must attach them. "
                         "--prefix-share compares sharing on vs off and "
                         "asserts token identity, >=80%% prefill-chunk "
                         "skip and strictly higher admitted concurrency; "
                         "--no-prefix-share reports the unshared baseline")
    ap.add_argument("--chunk-sweep", action="store_true",
                    help="sweep chunk sizes x {parallel, scan}: TTFT + "
                         "model steps per chunk tick + honest per-chunk "
                         "bytes, token identity asserted across every "
                         "cell")
    ap.add_argument("--spec-decode", choices=("off", "ngram"),
                    default="off",
                    help="run ONLY the speculative-decoding comparison: "
                         "the n-gram/prompt-lookup drafter + fused verify "
                         "path vs the spec-off engine on one lookup-"
                         "friendly schedule.  Asserts token identity, "
                         "acceptance > 0, HLO-measured accepted-tokens-"
                         "per-model-step > 1.0 and a strictly lower "
                         "tpot_p50")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens verified per decode tick")
    ap.add_argument("--spec-ngram", type=int, default=3,
                    help="longest n-gram the prompt-lookup drafter "
                         "matches against the stream's own history")
    ap.add_argument("--slo-classes", action="store_true",
                    help="run ONLY the mixed-tenant SLO-class workload: "
                         "long batch requests congest the wait line while "
                         "1-page interactive requests arrive behind the "
                         "parked head, bypass-on vs the FIFO-only twin on "
                         "the same seed.  Asserts token identity, strictly "
                         "higher admitted concurrency, ZERO head delay and "
                         "a strictly better interactive admission-delay "
                         "p99")
    ap.add_argument("--slo-seed", type=int, default=10,
                    help="seed for the mixed-tenant SLO schedule (pinned "
                         "separately from --seed: the SLO cells run their "
                         "own tuned regime)")
    ap.add_argument("--slo-bypass", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="size-aware SLO bypass in the engine under test; "
                         "--no-slo-bypass pins the strict-FIFO grant rule "
                         "(the baseline the spec/prefix smoke cells run "
                         "against)")
    ap.add_argument("--cached-retention", choices=("access", "blind"),
                    default="access",
                    help="free-but-cached page reclaim order for the "
                         "prefix workload: coldest-access-first (access) "
                         "or FIFO (blind)")
    ap.add_argument("--headroom", type=int, default=0,
                    help="admission headroom k: grant only when the "
                         "domain keeps k free blocks past the first chunk")
    ap.add_argument("--async-swap", action="store_true",
                    help="async two-tier memory comparison: spill/restore "
                         "issued behind the token loop (issue/poll/fence) "
                         "vs the synchronous swap twin on the same "
                         "oversubscription schedule")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: few requests, fast")
    args = ap.parse_args()
    if args.smoke:
        args.requests = 8
        args.mean_gap = 1.0

    cfg = reduced_config(REGISTRY["llama3-8b"])
    if args.async_swap:
        run_async_bench(args, cfg)
        return
    if args.slo_classes:
        run_slo_bench(args, cfg)
        return
    if args.spec_decode != "off":
        run_spec_bench(args, cfg)
        return
    if args.prefix_share is not None:
        run_prefix_bench(args, cfg, compare=args.prefix_share)
        return
    if args.chunk_sweep:
        # chunk-size sweep at equal byte budget: every
        # (C, path, kernel, split) cell must generate identical tokens; the
        # fused path must hold 1 model step per chunk tick (scan pays C);
        # the blocked kernel must price a strictly smaller score transient
        # than dense once the (C, W+C) block outgrows one tile
        cells = (("parallel", "blocked", True),
                 ("parallel", "blocked", False),
                 ("parallel", "dense", True),
                 ("scan", "dense", True))
        base = None
        for C in (4, 8, 16, 24):
            score = {}
            for pm, kern, split in cells:
                eng, res = run_mode(args, cfg, lazy=True,
                                    evict_mode=args.evict_mode,
                                    prefill_mode=pm, prefill_chunk=C,
                                    chunk_kernel=kern, split_ticks=split)
                st = ServeEngine.stats(eng.submitted)
                kv = eng.kv_stats()
                toks = [r.generated for r in
                        sorted(eng.submitted, key=lambda r: r.rid)]
                if base is None:
                    base = toks
                assert toks == base, \
                    f"chunk-sweep divergence at C={C} {pm}/{kern}/{split}"
                per_tick = (kv["prefill_model_steps"]
                            / max(1, kv["chunk_ticks"]))
                assert per_tick == (1 if pm == "parallel" else eng._chunk)
                if pm == "parallel" and split:
                    score[kern] = kv["prefill_score_bytes"]
                emit([row(f"sweep_ttft_p50[{pm},{kern},"
                          f"{'split' if split else 'unsplit'},"
                          f"C={eng._chunk}]",
                          st["ttft_p50"] * 1e6,
                          f"model_steps/chunk_tick={per_tick:.0f} "
                          f"chunk_bytes={kv['prefill_chunk_bytes']:.0f} "
                          f"(score={kv['prefill_score_bytes']:.0f}B)")])
            if C >= 16:
                # at C=16 the dense (C, W+C) block exceeds one (32, 32)
                # tile, so blocked must be strictly cheaper
                assert score["blocked"] < score["dense"], \
                    f"C={C}: blocked transient {score['blocked']:.0f}B " \
                    f"not below dense {score['dense']:.0f}B"
        print("chunk sweep token-identical across sizes, paths, kernels "
              "and tick splitting: True")
        return
    # (label, lazy, evict_mode): the default run compares swap-evict lazy
    # against restart-evict lazy AND eager on the same schedule/budget
    modes = []
    if args.prefill_chunked or not args.eager:
        modes.append(("lazy", True, args.evict_mode))
    if not (args.prefill_chunked or args.eager):
        other = "restart" if args.evict_mode == "swap" else "swap"
        modes.append((f"{other}-evict", True, other))
    if args.eager or not args.prefill_chunked:
        modes.append(("eager", False, "swap"))
    runs = {}
    kvs = {}
    for mode, lazy, evict in modes:
        eng, res = run_mode(args, cfg, lazy=lazy, evict_mode=evict)
        report(mode, args, eng, res)
        runs[mode] = eng
        kvs[mode] = eng.kv_stats()
        if evict == "swap" and lazy:
            # the CI gate: the swap tier must NEVER recompute a token
            assert kvs[mode]["recompute_tokens"] == 0, \
                f"[{mode}] swap mode recomputed " \
                f"{kvs[mode]['recompute_tokens']:.0f} tokens"
    toks = {m: [e.generated for e in sorted(runs[m].submitted,
                                            key=lambda r: r.rid)]
            for m in runs}
    if "lazy" in runs and args.prefill_mode == "parallel":
        # parallel-vs-scan divergence gate: the fused one-forward-per-tick
        # path must generate the per-token reference's exact tokens, and a
        # C-token chunk must cost 1 model step (vs C in scan mode)
        eng_s, res_s = run_mode(args, cfg, lazy=True,
                                evict_mode=args.evict_mode,
                                prefill_mode="scan")
        report("scan-prefill", args, eng_s, res_s)
        toks_s = [r.generated for r in
                  sorted(eng_s.submitted, key=lambda r: r.rid)]
        assert toks["lazy"] == toks_s, \
            "parallel/scan prefill token divergence"
        # (the steps metric is structural — derived from which compiled
        # path ran — so the token-identity assert above is the real gate)
        kp, ks = kvs["lazy"], eng_s.kv_stats()
        C = runs["lazy"]._chunk
        assert kp["prefill_model_steps"] == kp["chunk_ticks"], \
            "parallel chunk tick took more than one model step"
        assert ks["prefill_model_steps"] == C * ks["chunk_ticks"], \
            "scan chunk tick did not pay C model steps"
        print(f"prefill model steps per chunk tick: parallel=1 scan={C} "
              f"(chunk={C}); token-identical: True")
        # kernel gate: the other fused kernel on the same schedule must be
        # token-identical, and blocked must price the smaller transient
        other_k = "dense" if args.chunk_kernel == "blocked" else "blocked"
        eng_k, res_k = run_mode(args, cfg, lazy=True,
                                evict_mode=args.evict_mode,
                                chunk_kernel=other_k)
        toks_k = [r.generated for r in
                  sorted(eng_k.submitted, key=lambda r: r.rid)]
        assert toks["lazy"] == toks_k, \
            f"{args.chunk_kernel}/{other_k} kernel token divergence"
        score = {args.chunk_kernel: kp["prefill_score_bytes"],
                 other_k: eng_k.kv_stats()["prefill_score_bytes"]}
        if C >= 16:
            assert score["blocked"] < score["dense"], \
                f"blocked transient {score['blocked']:.0f}B not below " \
                f"dense {score['dense']:.0f}B at C={C}"
        print(f"chunk kernels token-identical: True (score transient: "
              f"blocked={score['blocked']:.0f}B "
              f"dense={score['dense']:.0f}B at C={C})")
        # split gate: the other tick-splitting mode must be
        # token-identical; the split run must leave decode streams with
        # ZERO masked prefill-query rows and a tpot tail no worse than
        # the padded mixed ticks (generous factor — interpret-mode CPU
        # timings are noisy)
        eng_u, res_u = run_mode(args, cfg, lazy=True,
                                evict_mode=args.evict_mode,
                                split_ticks=not args.split_ticks)
        report("unsplit" if args.split_ticks else "split", args,
               eng_u, res_u)
        toks_u = [r.generated for r in
                  sorted(eng_u.submitted, key=lambda r: r.rid)]
        assert toks["lazy"] == toks_u, "split/unsplit token divergence"
        e_split = runs["lazy"] if args.split_ticks else eng_u
        e_pad = eng_u if args.split_ticks else runs["lazy"]
        kv_s, kv_p = e_split.kv_stats(), e_pad.kv_stats()
        assert kv_s["decode_masked_query_rows"] == 0, \
            "split mode still paid masked decode-query rows"
        if kv_p["decode_masked_query_rows"]:
            assert kv_s["mixed_tick_decode_rows_saved"] > 0, \
                "mixed ticks occurred but split saved no rows"
        tp_s = ServeEngine.stats(e_split.submitted)["tpot_p50"]
        tp_p = ServeEngine.stats(e_pad.submitted)["tpot_p50"]
        assert tp_s <= tp_p * 1.5, \
            f"split tpot_p50 {tp_s*1e6:.0f}us regressed vs " \
            f"unsplit {tp_p*1e6:.0f}us"
        print(f"tick splitting token-identical: True (decode rows saved="
              f"{kv_s['mixed_tick_decode_rows_saved']:.0f}, unsplit "
              f"masked rows={kv_p['decode_masked_query_rows']:.0f}, "
              f"tpot_p50 split={tp_s*1e6:.0f}us "
              f"unsplit={tp_p*1e6:.0f}us)")
    swap_mode = "lazy" if args.evict_mode == "swap" else "swap-evict"
    restart_mode = "restart-evict" if args.evict_mode == "swap" else "lazy"
    if swap_mode in runs and restart_mode in runs:
        # same schedule, same budget: every restart eviction must become a
        # spill/restore cycle — identical tokens, zero recompute
        assert toks[swap_mode] == toks[restart_mode], \
            "swap/restart token divergence"
        sw, rs = kvs[swap_mode], kvs[restart_mode]
        print(f"eviction thrash: restart={rs['evictions']:.0f} evictions "
              f"({rs['recompute_tokens']:.0f} recomputed tokens) vs "
              f"swap={sw['spills']:.0f} spills / {sw['restores']:.0f} "
              f"restores ({sw['recompute_tokens']:.0f} recomputed); "
              f"token-identical: True")
        assert sw["evictions"] == 0, "swap mode fell back to restart"
        if rs["evictions"]:
            assert sw["spills"] > 0, \
                "restart thrashed but swap mode never spilled"
    if "lazy" in runs and "eager" in runs:
        # lazy must admit at least as much concurrency as eager and
        # generate identical tokens
        assert toks["lazy"] == toks["eager"], \
            "lazy/eager token divergence"
        lz = runs["lazy"].pool.peak_active_tables
        eg = runs["eager"].pool.peak_active_tables
        print(f"admitted concurrency: lazy={lz} eager={eg} "
              f"(same {args.pool_streams} streams/domain budget); "
              f"token-identical: True")
        assert lz >= eg, "lazy admitted less concurrency than eager"


if __name__ == "__main__":
    main()
