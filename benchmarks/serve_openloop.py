"""Open-loop serving benchmark: Poisson-ish arrivals against the paged
chiplet-aware KV allocator.

A client coroutine on the engine's shared TaskRuntime submits requests over
time from a seeded schedule (exponential inter-arrival gaps measured in
engine rounds), so the adaptive controller sees steady-state load — not an
up-front queue — and TTFT / TPOT tails are real.

The run is deliberately oversubscribed to show the paged allocator's
capacity win: the KV pool is budgeted for ``--pool-streams`` full-length
streams per chiplet-group domain (exactly the bytes the old slot-monolith
allocator reserved), while ``max_batch`` is set to **2x** that.  Short
requests reserve only the pages they need, so the run completes at twice
the old concurrency for the same memory budget; when the pool does fill,
admissions park via ``yield BLOCK`` and resume on frees instead of sitting
in a dumb queue.

    PYTHONPATH=src python benchmarks/serve_openloop.py
    PYTHONPATH=src python benchmarks/serve_openloop.py --smoke   # CI
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).parent))

from common import emit, row

from repro.configs import REGISTRY, reduced_config
from repro.core.controller import ControllerConfig
from repro.core.topology import ChipletTopology
from repro.serving.engine import EngineConfig, ServeEngine


def poisson_schedule(seed: int, n: int, mean_gap: float,
                     vocab: int, max_len: int):
    """Seeded (gap_rounds, prompt, max_new) arrivals; exponential gaps."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        gap = int(rng.exponential(mean_gap))
        plen = int(rng.integers(4, max(5, max_len // 4)))
        max_new = int(rng.integers(4, max(5, max_len // 4)))
        out.append((gap, rng.integers(2, vocab, size=plen), max_new))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--mean-gap", type=float, default=1.0,
                    help="mean inter-arrival gap in engine rounds")
    ap.add_argument("--pool-streams", type=int, default=1,
                    help="KV budget per domain, in full-length streams "
                         "(the old slot-monolith limit)")
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: few requests, fast")
    args = ap.parse_args()
    if args.smoke:
        args.requests = 8
        args.mean_gap = 1.0

    cfg = reduced_config(REGISTRY["llama3-8b"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=4, chips_per_group=1)
    # max_batch is 2x the memory budget's stream count: the paged pool
    # admits by pages actually needed, not worst-case slots
    max_batch = 2 * args.pool_streams
    ecfg = EngineConfig(
        max_batch=max_batch, max_len=args.max_len, adaptive=True,
        pool_streams=args.pool_streams,
        controller=ControllerConfig(scheduler_timer=8, threshold=64.0,
                                    min_dwell=2))
    eng = ServeEngine(cfg, topo, ecfg, spread_rate=1, seed=args.seed)
    sched = poisson_schedule(args.seed, args.requests, args.mean_gap,
                             cfg.vocab, args.max_len)
    eng.open_loop_client(sched)
    res = eng.run_until_done()

    reqs = eng.submitted
    assert len(reqs) == args.requests
    assert all(r.done for r in reqs), \
        f"{sum(not r.done for r in reqs)} requests unfinished"
    st = ServeEngine.stats(reqs)
    kv = eng.kv_stats()
    c = res["counters"]
    emit([
        row("openloop_ttft_p50", st["ttft_p50"] * 1e6,
            f"p99={st['ttft_p99']*1e6:.0f}us n={st['n']}"),
        row("openloop_tpot_p50", st["tpot_p50"] * 1e6,
            f"p99={st['tpot_p99']*1e6:.0f}us tokens={st['tokens']}"),
        row("openloop_capacity", float(max_batch),
            f"max_batch=2x pool budget ({args.pool_streams} streams/domain),"
            f" peak_blocks={kv['peak_used_blocks']:.0f}"
            f"/{kv['total_blocks']:.0f}"),
        row("openloop_backpressure", kv["alloc_failures"],
            f"park_rate={kv['park_rate']:.2f} "
            f"unblocked={c.get('tasks_unblocked', 0):.0f}"),
        row("openloop_migration", kv["blocks_migrated"],
            f"tables_migrated={kv['tables_migrated']:.0f} "
            f"relayouts={len(res['relayouts'])}"),
    ])
    moves = [(r["old_groups"], r["new_groups"], r["blocks_migrated"])
             for r in res["relayouts"]]
    print(f"relayouts (old_groups, new_groups, blocks_migrated): {moves}")


if __name__ == "__main__":
    main()
