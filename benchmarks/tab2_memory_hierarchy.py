"""Tab. 2 / Fig. 8 analogue (StreamCluster vs Shoal): contention on the
shared per-group resource vs core count.

Paper: Shoal's sequential task-to-core fill packs 16 cores into 2 chiplets
(2x32 MB L3, heavy main-memory traffic) while ARCAS spreads them over all
8 chiplets (8x32 MB).  On TPU the shared-per-group resource is the
group's intra-row ICI bandwidth: packing k active chips into few groups
concentrates their collective traffic on those rows' links, while ARCAS's
spread placement balances per-link load.  Reported: per-link load ratio
and the modeled collective-time gap, closing as chips -> full pod (the
paper's convergence at 64 cores).
"""
from __future__ import annotations

from benchmarks.common import row, time_call
from repro.core.topology import ChipletTopology

BYTES_PER_CHIP = 1e9     # collective bytes each active chip moves per step


def run():
    topo = ChipletTopology(n_pods=1, groups_per_pod=16, chips_per_group=16)
    us = time_call(lambda: ChipletTopology())
    rows = []
    for chips in (16, 32, 64, 128, 256):
        # Shoal-analogue: sequential fill -> ceil(chips/16) groups fully packed
        groups_shoal = max(1, chips // topo.chips_per_group)
        load_shoal = (chips / groups_shoal) * BYTES_PER_CHIP   # per-row load
        # ARCAS: round-robin across all 16 groups
        groups_arcas = min(16, chips)
        load_arcas = (chips / groups_arcas) * BYTES_PER_CHIP
        t_shoal = load_shoal / topo.bandwidth("intra_group")
        t_arcas = load_arcas / topo.bandwidth("intra_group")
        rows.append(row(
            f"tab2_memory_hierarchy/{chips}chips", us,
            f"shoal_row_load_GB={load_shoal/1e9:.1f};"
            f"arcas_row_load_GB={load_arcas/1e9:.1f};"
            f"gap={t_shoal/t_arcas:.1f}x"))
    rows.append(row(
        "tab2_memory_hierarchy/converges", us,
        "gap 16x@16chips -> 1x@256chips (paper: Shoal==ARCAS at 64 cores)"))
    return rows
