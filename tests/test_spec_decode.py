"""Speculative decoding (ISSUE 8): n-gram drafting + fused verify.

The acceptance property: a spec-enabled engine is TOKEN-IDENTICAL to the
spec-off engine — greedy acceptance keeps exactly the prefix a plain
decode would have produced — no matter how good or hostile the drafter
is, across model families, page-boundary and ring-wrap rollbacks,
copy-on-write shared pages, and park/spill mid-draft.  Identity is the
gate everywhere; counters then pin which machinery (accepts, rollbacks,
checkpoints) actually ran, so a vacuous pass cannot hide.

Injected drafters make the edge cases deterministic: an ORACLE replays
the spec-off baseline (full accepts), an ANTI-ORACLE proposes baseline+1
(guaranteed full rejects), a PARTIAL drafter prepends a correct prefix to
garbage (guaranteed mid-window rollback).
"""
from typing import Dict, List, Tuple

import numpy as np
import pytest

from repro.configs import REGISTRY, reduced_config
from repro.core.topology import ChipletTopology
from repro.serving.engine import EngineConfig, ServeEngine
from repro.serving.kvpool import KVBlockPool
from repro.serving.spec import NGramDrafter, make_drafter

CFG = reduced_config(REGISTRY["llama3-8b"])
HYB = reduced_config(REGISTRY["recurrentgemma-9b"])


def _engine(cfg=CFG, *, spec="ngram", spec_k=3, groups=1, max_batch=2,
            max_len=48, pool_streams=2, share=False, evict_mode="swap",
            **ecfg_kw):
    topo = ChipletTopology(n_pods=1, groups_per_pod=groups,
                           chips_per_group=1)
    ecfg = EngineConfig(max_batch=max_batch, max_len=max_len, paged=True,
                        lazy=True, pool_streams=pool_streams,
                        adaptive=False, evict_mode=evict_mode,
                        prefix_share=share, spec_decode=spec,
                        spec_k=spec_k, **ecfg_kw)
    return ServeEngine(cfg, topo, ecfg, spread_rate=1, seed=0)


def _serve(eng, prompts, max_new) -> List[List[int]]:
    for p, m in zip(prompts, max_new):
        eng.submit(p, m)
    eng.run_until_done()
    assert all(r.done for r in eng.submitted), "deadlock"
    return [r.generated for r in eng.submitted]


def _baseline(cfg, prompts, max_new, **kw) -> List[List[int]]:
    return _serve(_engine(cfg, spec="off", **kw), prompts, max_new)


class OracleDrafter:
    """Replays the spec-off baseline: every draft token is exactly what
    greedy decode will produce, so every verify is a FULL accept."""

    def __init__(self, prompts, baselines):
        self._by_prompt = {tuple(int(t) for t in p): list(b)
                           for p, b in zip(prompts, baselines)}

    def draft(self, req, k: int) -> List[int]:
        base = self._by_prompt[tuple(int(t) for t in req.prompt)]
        done = len(req.generated)
        return base[done:done + k]


class AntiOracleDrafter(OracleDrafter):
    """Baseline+1 mod vocab: every draft token is provably WRONG, so
    every verify is a FULL reject (m=0) and only the bonus token
    commits — the k=0-accept edge, every tick."""

    def __init__(self, prompts, baselines, vocab):
        super().__init__(prompts, baselines)
        self._vocab = vocab

    def draft(self, req, k: int) -> List[int]:
        return [(t + 1) % self._vocab
                for t in super().draft(req, k)]


class PartialDrafter(OracleDrafter):
    """``good`` correct tokens followed by provably-wrong ones: every
    full-width verify accepts a strict prefix and rolls back the rest."""

    def __init__(self, prompts, baselines, vocab, good=1):
        super().__init__(prompts, baselines)
        self._vocab = vocab
        self._good = good

    def draft(self, req, k: int) -> List[int]:
        toks = super().draft(req, k)
        return (toks[:self._good]
                + [(t + 1) % self._vocab for t in toks[self._good:]])


def _prompts(rng, n, lens, vocab=None):
    v = vocab or CFG.vocab
    return [rng.integers(2, v, size=int(s)) for s, _ in zip(lens, range(n))]


# ---------------------------------------------------------------------------
# identity across families (the tentpole gate)
# ---------------------------------------------------------------------------

ENGINE_FAMILIES = ("llama3-8b", "mixtral-8x22b", "mamba2-780m",
                   "recurrentgemma-9b")


@pytest.mark.parametrize("arch", ENGINE_FAMILIES)
def test_spec_identity_across_families(arch):
    """Speculative decode is token-identical to plain decode for dense /
    MoE / SSM / hybrid engines.  The injected partial drafter (one right
    token, then garbage) guarantees every family exercises drafting,
    acceptance AND rollback — the n-gram drafter can go quiet when the
    generated tokens never recur, which would let the gate pass vacuously."""
    cfg = reduced_config(REGISTRY[arch])
    rng = np.random.default_rng(3)
    prompts = [rng.integers(2, cfg.vocab, size=s) for s in (7, 5)]
    max_new = [14, 11]
    base = _baseline(cfg, prompts, max_new)
    eng = _engine(cfg, spec="ngram")
    eng.drafter = PartialDrafter(prompts, base, cfg.vocab, good=1)
    toks = _serve(eng, prompts, max_new)
    assert toks == base
    kv = eng.kv_stats()
    assert kv["spec_tokens_drafted"] > 0
    assert kv["spec_tokens_accepted"] > 0
    assert kv["spec_rollbacks"] > 0
    assert kv["spec_verify_forwards"] > 0


def test_ngram_drafting_end_to_end():
    """The real prompt-lookup drafter on a repetition-heavy prompt: the
    engine drafts from its own committed history (no injection) and stays
    token-identical with a non-trivial amount actually drafted."""
    rng = np.random.default_rng(3)
    prompts = [np.tile(rng.integers(2, CFG.vocab, size=4), 4)
               for _ in range(2)]
    max_new = [14, 11]
    base = _baseline(CFG, prompts, max_new)
    eng = _engine(CFG, spec="ngram")
    assert _serve(eng, prompts, max_new) == base
    kv = eng.kv_stats()
    assert kv["spec_tokens_drafted"] > 0
    assert kv["spec_verify_forwards"] > 0


def test_spec_verify_matches_sequential_decode_encdec():
    """The enc-dec family has no engine serving path (model-level only,
    as in test_continuous_batching): the all-logits verify forward must
    agree with per-token sequential decode on every position's argmax —
    the model-level statement of greedy-acceptance identity."""
    import jax
    import jax.numpy as jnp
    from repro.models import decode as dec
    from repro.models.params import init_params
    cfg = reduced_config(REGISTRY["seamless-m4t-large-v2"])
    max_len, src, B, W = 16, 6, 1, 4
    params = init_params(cfg, jax.random.PRNGKey(0))
    spec = dec.cache_view_specs(cfg, max_len, src)
    key = jax.random.PRNGKey(1)
    rng = np.random.default_rng(2)

    def fresh_cache():
        cache = dec.init_cache(cfg, B, max_len, src)
        for leaf in ("cross_k", "cross_v"):
            cache[leaf] = 0.1 * jax.random.normal(
                key, cache[leaf].shape, cache[leaf].dtype)
        return cache

    toks = jnp.asarray(rng.integers(2, cfg.vocab, size=(B, W)), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    n = jnp.full((B,), W, jnp.int32)
    lg_v, _ = dec.chunk_decode_step(params, cfg, spec, fresh_cache(), toks,
                                    pos, n, all_logits=True)
    cache = fresh_cache()
    seq = []
    for i in range(W):
        lg, cache = dec.chunk_decode_step(
            params, cfg, spec, cache, toks[:, i:i + 1],
            jnp.full((B,), i, jnp.int32), jnp.ones((B,), jnp.int32))
        seq.append(np.asarray(lg))
    verify = np.asarray(lg_v)
    for i in range(W):
        assert np.argmax(verify[0, i]) == np.argmax(seq[i][0]), i


# ---------------------------------------------------------------------------
# accept / rollback edges, pinned with injected drafters
# ---------------------------------------------------------------------------

def test_full_reject_anti_oracle():
    """Every draft token wrong: m=0 full rejects every spec tick, only
    the bonus token commits — yet output is identical, and (the refined
    rollback design) a pure-attention unwrapped ring takes NO page
    checkpoints: the rejected writes are dead bytes behind the cursor
    mask, overwritten before any read."""
    rng = np.random.default_rng(5)
    prompts = _prompts(rng, 2, (6, 9))
    max_new = [16, 12]
    base = _baseline(CFG, prompts, max_new)
    eng = _engine(CFG, spec="ngram")
    eng.drafter = AntiOracleDrafter(prompts, base, CFG.vocab)
    assert _serve(eng, prompts, max_new) == base
    kv = eng.kv_stats()
    assert kv["spec_tokens_accepted"] == 0
    assert kv["spec_full_rejects"] > 0
    assert kv["spec_rollbacks"] > 0
    assert kv["spec_ckpts"] == 0            # no state, no wrap: no snapshot
    assert kv["spec_rollback_pages"] == 0
    assert kv["spec_rejected_bytes"] > 0


def test_full_accept_oracle():
    """Every draft token right: acceptance is total, no rollback runs,
    and decode finishes in strictly fewer model forwards than tokens."""
    rng = np.random.default_rng(6)
    prompts = _prompts(rng, 2, (5, 8))
    max_new = [18, 15]
    base = _baseline(CFG, prompts, max_new)
    eng = _engine(CFG, spec="ngram")
    eng.drafter = OracleDrafter(prompts, base)
    assert _serve(eng, prompts, max_new) == base
    kv = eng.kv_stats()
    assert kv["spec_tokens_drafted"] > 0
    assert kv["spec_tokens_accepted"] == kv["spec_tokens_drafted"]
    assert kv["spec_rollbacks"] == 0
    assert kv["spec_accept_rate"] == 1.0
    forwards = (kv["decode_row_forwards"] + kv["spec_row_forwards"]
                + kv["spec_row_reapplies"])
    assert forwards < kv["decode_committed_tokens"]


def test_page_boundary_rollback():
    """A verify window that straddles a page boundary rolls back its
    rejected suffix without corrupting either page: prompt length 14 with
    k=3 puts the first window at positions 14..17 across the 16-token
    page seam."""
    rng = np.random.default_rng(7)
    prompts = [rng.integers(2, CFG.vocab, size=14)]
    max_new = [15]
    base = _baseline(CFG, prompts, max_new)
    eng = _engine(CFG, spec="ngram")
    assert eng.pool.block_tokens == 16
    eng.drafter = PartialDrafter(prompts, base, CFG.vocab, good=1)
    assert _serve(eng, prompts, max_new) == base
    kv = eng.kv_stats()
    assert kv["spec_rollbacks"] > 0
    assert kv["spec_tokens_accepted"] > 0      # partial, not full, rejects


def test_cow_shared_page_bits_unchanged_across_rollbacks():
    """Prefix-shared pages under speculative rollback: a published page
    attached by a drafting stream keeps its exact bytes through full
    rejects — speculation must never write (or roll back) through a
    refcount>1 page.  The published blocks are byte-compared before and
    after the speculative burst."""
    from repro.models import decode as dec
    rng = np.random.default_rng(8)
    pre = rng.integers(2, CFG.vocab, size=32)       # two full pages
    prompts = [np.concatenate([pre, rng.integers(2, CFG.vocab, size=3)])
               for _ in range(2)]
    max_new = [10, 10]

    base = _baseline(CFG, prompts, max_new, share=True, max_len=64,
                     pool_streams=3)
    warm = _engine(CFG, spec="ngram", share=True, max_len=64,
                   pool_streams=3)
    warm.drafter = AntiOracleDrafter(prompts, base, CFG.vocab)
    # warm request publishes the preamble pages into the prefix index
    assert _serve(warm, prompts[:1], max_new[:1]) == base[:1]
    shared = [b for b in warm.pool._entry_of_block]
    assert len(shared) >= 2
    before = [x for x in dec.extract_pool_entries(
        warm.pool.storage, warm.pool.spec, shared) if x is not None]
    # burst: the second stream attaches the published pages, then drafts
    # hostile tokens every tick
    warm.submit(prompts[1], max_new[1])
    warm.run_until_done()
    assert [r.generated for r in warm.submitted] == base
    kv = warm.kv_stats()
    assert kv["spec_full_rejects"] > 0
    assert kv["prefix_hits"] > 0 or kv["cached_page_hits"] > 0
    after = [x for x in dec.extract_pool_entries(
        warm.pool.storage, warm.pool.spec, shared) if x is not None]
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a, b)
    warm.pool.audit([r.table for r in warm.submitted
                     if r.table is not None])


def test_hybrid_state_rollback_past_ring_wrap():
    """recurrentgemma: rgLRU state slots must snapshot on EVERY spec tick
    (the reduction over fed tokens is not recomputable from pages) and
    ring-WRAPPING windows must also snapshot pages (a rejected write at p
    past the ring width destroys live position p-W).  Identity through
    both, with the wrap checkpoints observed."""
    rng = np.random.default_rng(9)
    prompts = _prompts(rng, 1, (5,), HYB.vocab)
    max_new = [52]                  # ring is 32 < 5 + 52: wraps for sure
    base = _baseline(HYB, prompts, max_new, max_len=64)
    eng = _engine(HYB, spec="ngram", max_len=64)
    assert eng.pool.spec.width < 5 + 52
    eng.drafter = PartialDrafter(prompts, base, HYB.vocab, good=1)
    assert _serve(eng, prompts, max_new) == base
    kv = eng.kv_stats()
    assert kv["spec_rollbacks"] > 0
    assert kv["spec_ckpts"] > 0                 # state slots every tick
    assert kv["spec_ckpt_pages"] > 0            # wrapped windows: pages too
    assert kv["spec_rollback_pages"] > 0
    assert kv["spec_rollback_bytes"] > 0


def test_park_spill_mid_draft():
    """Oversubscription parks a stream BETWEEN spec ticks: the saved
    cursor is the last accepted position, so the restored stream resumes
    token-identically with zero recomputation (swap tier, not restart)."""
    rng = np.random.default_rng(10)
    prompts = [np.tile(rng.integers(2, CFG.vocab, size=4), 5)
               for _ in range(3)]
    max_new = [20, 18, 16]
    kw = dict(pool_streams=1, max_batch=3, max_len=32, evict_mode="swap")
    base = _baseline(CFG, prompts, max_new, **kw)
    eng = _engine(CFG, spec="ngram", **kw)
    assert _serve(eng, prompts, max_new) == base
    kv = eng.kv_stats()
    assert kv["spec_tokens_drafted"] > 0
    assert kv["recompute_tokens"] == 0


# ---------------------------------------------------------------------------
# cached-page retention order (satellite)
# ---------------------------------------------------------------------------

def _retention_pool(retention):
    pool = KVBlockPool(CFG, n_domains=1, max_len=32, blocks_per_domain=4,
                       states_per_domain=4, block_tokens=16,
                       retention=retention)
    bt = pool.block_tokens
    rng = np.random.default_rng(11)
    tables = []
    for i in range(2):
        prompt = rng.integers(2, CFG.vocab, size=bt + 3)
        keys = pool.prefix_keys(prompt)
        t = pool.reserve(0, len(prompt) + 4, first_tokens=len(prompt))
        pool.register_prefix(t, keys, 0, bt, len(prompt))
        tables.append((t, keys, prompt))
    return pool, tables


@pytest.mark.parametrize("retention", ("access", "blind"))
def test_cached_page_retention_order(retention):
    """With every free block caching a published page, "access" reclaims
    the COLDEST page (the one never re-matched) and keeps the re-touched
    one resident; "blind" reclaims in plain free order regardless of the
    touch.  Both count the reclaim."""
    pool, tables = _retention_pool(retention)
    (t1, keys1, p1), (t2, keys2, p2) = tables
    b1, b2 = t1.blocks[0], t2.blocks[0]
    pool.free(t1)
    pool.free(t2)
    # re-touch the FIRST published page only
    hit, _ = pool.match_prefix(0, keys1, prompt_len=len(p1))
    assert hit == [b1]
    # drain every uncached free block, then force one cached reclaim
    grabbed = []
    while True:
        t = pool.reserve(0, 8)
        grabbed.append(t)
        if pool.counters.totals.get("kv_cached_reclaims", 0.0):
            break
    reclaimed_b1 = any(b1 in t.blocks for t in grabbed)
    reclaimed_b2 = any(b2 in t.blocks for t in grabbed)
    if retention == "access":
        # the touched page survives; the cold one was reclaimed
        assert reclaimed_b2 and not reclaimed_b1
        assert pool.match_prefix(0, keys1, prompt_len=len(p1))[0] == [b1]
    else:
        assert reclaimed_b1 or reclaimed_b2
    assert pool.counters.totals["kv_cached_reclaims"] >= 1


# ---------------------------------------------------------------------------
# measured steps-per-token + costmodel (satellites)
# ---------------------------------------------------------------------------

def test_measured_model_steps_parallel_and_scan():
    """HLO-counted sequential model steps per compiled call: the parallel
    path runs ONE fused step for decode, chunk and verify alike; the scan
    reference pays one step per fed token (C for a chunk, spec_w for the
    verify window) — measured from the optimized while loops, not assumed."""
    eng = _engine(CFG, spec="ngram", spec_k=3)
    assert eng.measured_model_steps("decode") == 1.0
    assert eng.measured_model_steps("chunk") == 1.0
    assert eng.measured_model_steps("spec") == 1.0
    scan = _engine(CFG, spec="ngram", spec_k=3, prefill_mode="scan")
    assert scan.measured_model_steps("chunk", C=8) == 8.0
    assert scan.measured_model_steps("spec") == scan._spec_w
    off = _engine(CFG, spec="off")
    with pytest.raises(ValueError):
        off.measured_model_steps("spec")


def test_warm_steps_compiles_and_stays_identical():
    """warm_steps pre-compiles the dispatch grid by writing only null
    rows: serving after a warm-up produces the same tokens as a cold
    engine."""
    rng = np.random.default_rng(12)
    prompts = _prompts(rng, 2, (5, 7))
    max_new = [8, 6]
    base = _baseline(CFG, prompts, max_new)
    eng = _engine(CFG, spec="ngram")
    assert eng.warm_steps() > 0
    assert _serve(eng, prompts, max_new) == base


def test_costmodel_spec_bytes_hand_computed():
    from repro.core.costmodel import (kv_spill_bytes, kv_state_bytes,
                                      kv_token_bytes, spec_rejected_bytes,
                                      spec_rollback_bytes)
    act = 2.0 * CFG.d_model * len(CFG.layer_types()) * 2.0
    assert spec_rejected_bytes(CFG, 0) == 0.0
    assert spec_rejected_bytes(CFG, 3) == pytest.approx(
        3 * (act + kv_token_bytes(CFG)))
    got = spec_rollback_bytes(CFG, 2, 1, 16, ckpts=2, rollbacks=1)
    want = (kv_spill_bytes(CFG, 2, 16, with_state=False)
            + 2 * kv_state_bytes(CFG)
            + kv_spill_bytes(CFG, 1, 16, with_state=False)
            + 1 * kv_state_bytes(CFG))
    assert got == pytest.approx(want)


def test_ngram_drafter_lookup():
    """The prompt-lookup rule itself: most recent prior occurrence of the
    trailing n-gram wins, longest n-gram first, no match -> no draft."""
    d = NGramDrafter(max_ngram=3)

    class R:
        prompt = [1, 2, 3, 9, 1, 2, 3]
        generated = []

    assert d.draft(R(), 2) == [9, 1]          # trigram 1,2,3 matched
    r2 = R()
    r2.prompt = [4, 5, 6, 7]
    assert d.draft(r2, 2) == []               # nothing recurs
    r3 = R()
    r3.prompt = [4, 5, 8, 5]                  # only the 1-gram recurs
    assert d.draft(r3, 3) == [8, 5]
    with pytest.raises(ValueError):
        make_drafter("model")
