"""Per-architecture smoke tests: reduced config, one fwd + one train step on
CPU, asserting output shapes and finiteness (deliverable f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_inputs
from repro.configs import REGISTRY, SHAPES, get_config, reduced_config, \
    shape_applicable
from repro.models import decode as Dec
from repro.models import params as P
from repro.models import transformer as T
from repro.launch.steps import make_train_step
from repro.optim.adamw import AdamWConfig, init_opt_state


def test_registry_complete():
    assert sorted(REGISTRY) == sorted([
        "mixtral-8x22b", "grok-1-314b", "llama3-8b", "llama3.2-3b",
        "starcoder2-15b", "nemotron-4-15b", "qwen2-vl-2b",
        "recurrentgemma-9b", "mamba2-780m", "seamless-m4t-large-v2"])


@pytest.mark.parametrize("arch,expected_b", [
    ("mixtral-8x22b", 141e9), ("grok-1-314b", 314e9), ("llama3-8b", 8e9),
    ("llama3.2-3b", 3e9), ("starcoder2-15b", 15e9),
    ("nemotron-4-15b", 15e9), ("qwen2-vl-2b", 1.5e9),
    ("recurrentgemma-9b", 9e9), ("mamba2-780m", 0.78e9),
    ("seamless-m4t-large-v2", 1.4e9)])
def test_param_counts_in_band(arch, expected_b):
    """Full-config parameter counts are in the right ballpark (0.5x-2x)."""
    n = P.n_params(get_config(arch))
    assert 0.4 * expected_b < n < 2.4 * expected_b, (arch, n / 1e9)


def test_forward_shapes_and_finite(arch_cfg, key):
    cfg = arch_cfg
    B, S = 2, 32
    batch = make_inputs(cfg, key, B, S)
    if cfg.family == "encdec":
        x, aux = T.encdec_forward(
            P.init_params(cfg, key), cfg, batch["tokens"],
            {"frame_embeds": batch["frame_embeds"]})
        assert x.shape == (B, S // 2, cfg.d_model)
    else:
        extras = {k: v for k, v in batch.items()
                  if k not in ("tokens", "targets", "mask")}
        x, aux = T.forward(P.init_params(cfg, key), cfg, batch["tokens"],
                           extras)
        assert x.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))


def test_one_train_step_no_nans(arch_cfg, key):
    cfg = arch_cfg
    params = P.init_params(cfg, key)
    opt = init_opt_state(params)
    batch = make_inputs(cfg, key)
    step = make_train_step(cfg, AdamWConfig(peak_lr=1e-3, warmup_steps=1))
    new_p, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(new_p):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


def test_microbatched_step_matches_plain(key):
    """Gradient accumulation (m=2) == single batch step (same loss)."""
    cfg = reduced_config(REGISTRY["llama3-8b"])
    params = P.init_params(cfg, key)
    batch = make_inputs(cfg, key, B=4, S=32)
    opt = init_opt_state(params)
    s1 = make_train_step(cfg, AdamWConfig())
    s2 = make_train_step(cfg, AdamWConfig(), microbatches=2)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p2, _, m2 = jax.jit(s2)(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_shape_applicability_matrix():
    """long_500k runs iff the arch is sub-quadratic; 33 runnable cells."""
    runnable = 0
    for arch in REGISTRY:
        for sname, shape in SHAPES.items():
            ok, why = shape_applicable(get_config(arch), shape)
            if sname == "long_500k":
                sub = get_config(arch).is_subquadratic
                assert ok == sub, (arch, sname)
            else:
                assert ok, (arch, sname, why)
            runnable += ok
    assert runnable == 33


def test_decode_matches_forward(arch_cfg, key):
    """Prefill + one decode step == teacher-forced forward (all families)."""
    cfg = arch_cfg
    B, S = 2, 32
    prm = P.init_params(cfg, key)
    batch = make_inputs(cfg, key, B, S)
    extras = {k: v for k, v in batch.items()
              if k not in ("tokens", "targets", "mask")}
    text = batch["tokens"]
    if cfg.family == "encdec":
        fwd = lambda t: T.encdec_forward(prm, cfg, t, extras)[0]
    else:
        fwd = lambda t: T.forward(prm, cfg, t, extras)[0]

    ref_last = T.head_logits(prm, cfg, fwd(text)[:, -1])
    # processed length = vision + text for VLM; the cache must cover it all
    # plus decode headroom, or the ring evicts vision tokens the teacher-
    # forced reference still attends to
    seq_done = S if cfg.family == "vlm" else text.shape[1]
    lp, cache = Dec.prefill(prm, cfg, text, extras, max_len=seq_done + 8)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(ref_last),
                               rtol=3e-4, atol=3e-4)

    nxt = jnp.argmax(lp, -1)[:, None].astype(jnp.int32)
    pos = jnp.full((B,), seq_done, jnp.int32)
    dext = None
    if cfg.family == "vlm":
        full_S = S + 1
        extras2 = dict(extras)
        extras2["position_ids"] = jnp.broadcast_to(
            jnp.arange(full_S)[None, None], (3, B, full_S)).astype(jnp.int32)
        ref = T.head_logits(
            prm, cfg, T.forward(prm, cfg, jnp.concatenate([text, nxt], 1),
                                extras2)[0][:, -1])
        dext = {"position_ids": jnp.broadcast_to(
            pos[None, :, None], (3, B, 1)).astype(jnp.int32)}
    else:
        ref = T.head_logits(prm, cfg, fwd(jnp.concatenate([text, nxt], 1))[:, -1])
    got, _ = Dec.decode_step(prm, cfg, cache, nxt, pos, dext)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-3, atol=3e-3)


def test_sliding_window_cache_bounded():
    """SWA archs serve contexts far beyond the window with a fixed cache."""
    cfg = dataclasses.replace(reduced_config(REGISTRY["mixtral-8x22b"]),
                              window=16)
    cache = Dec.init_cache(cfg, batch=2, max_len=500_000)
    k = cache["layers"]["k"]
    assert k.shape[2] == 16  # ring buffer == window, not 500k


def test_pallas_path_matches_jnp(key):
    for name in ("llama3-8b", "mamba2-780m", "recurrentgemma-9b"):
        cfg = reduced_config(REGISTRY[name])
        cfgp = dataclasses.replace(cfg, use_pallas=True)
        prm = P.init_params(cfg, key)
        tokens = jax.random.randint(key, (2, 64), 0, cfg.vocab)
        x1, _ = T.forward(prm, cfg, tokens)
        x2, _ = T.forward(prm, cfgp, tokens)
        np.testing.assert_allclose(np.asarray(x1), np.asarray(x2),
                                   rtol=3e-4, atol=3e-4)
