"""Distribution tests that need >1 device: run in a subprocess with
XLA_FLAGS set (the main pytest session keeps 1 device).  Also unit tests
for the HLO analysis (trip counts, replica groups, roofline math)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.launch import hlo_analysis as ha

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ---------------------------------------------------------------------------
# HLO analysis unit tests
# ---------------------------------------------------------------------------

def test_shape_bytes():
    assert ha.shape_bytes("f32[128,1024]{1,0}") == 128 * 1024 * 4
    assert ha.shape_bytes("bf16[8]") == 16
    assert ha.shape_bytes("(f32[2,2], s32[4])") == 16 + 16
    assert ha.shape_bytes("f32[]") == 4  # scalar


def test_replica_group_parsing():
    g = ha.parse_replica_groups("replica_groups={{0,1},{2,3}}")
    assert g == [[0, 1], [2, 3]]
    g = ha.parse_replica_groups("replica_groups=[4,2]<=[8]")
    assert g == [[0, 1], [2, 3], [4, 5], [6, 7]]
    g = ha.parse_replica_groups("replica_groups=[2,4]<=[4,2]T(1,0)")
    assert g == [[0, 2, 4, 6], [1, 3, 5, 7]]


def test_classify_groups():
    # production coords: id = data*16 + model (single pod)
    assert ha.classify_group([0, 1, 2], multi_pod=False) == "intra_group"
    assert ha.classify_group([0, 16], multi_pod=False) == "intra_pod"
    assert ha.classify_group([0, 256], multi_pod=True) == "cross_pod"


def test_roofline_math():
    r = ha.roofline(flops_per_dev=197e12, bytes_per_dev=819e9,
                    coll_bytes_per_dev=0.0, model_flops_total=197e12 * 256,
                    chips=256)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.dominant in ("compute", "memory")
    assert r.useful_ratio == pytest.approx(1.0)
    assert r.roofline_fraction == pytest.approx(1.0)


def test_nested_while_trip_counts_subprocess():
    out = _run_sub("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import collective_bytes
        at = getattr(jax.sharding, "AxisType", None)
        kw = {"axis_types": (at.Auto,)*2} if at is not None else {}
        mesh = jax.make_mesh((4, 2), ("data", "model"), **kw)
        W = jax.ShapeDtypeStruct((64, 64), jnp.float32,
                                 sharding=NamedSharding(mesh, P(None, "model")))
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32,
                                 sharding=NamedSharding(mesh, P("data", None)))
        def f(x, w):
            def outer(c, _):
                def inner(c2, _):
                    return (c2 @ w) @ w.T, None
                c2, _ = jax.lax.scan(inner, c, None, length=5)
                return c2, None
            out, _ = jax.lax.scan(outer, x, None, length=3)
            return out.sum()
        c = jax.jit(f).lower(x, W).compile()
        stats = collective_bytes(c.as_text(), multi_pod=False)
        mults = sorted(d["mult"] for d in stats.details)
        print("MULTS", mults)
    """)
    assert "15.0" in out     # 3 (outer) x 5 (inner)


def test_tiny_cell_compiles_on_fake_mesh():
    """A reduced config passes the full run_cell machinery on 8 devices."""
    out = _run_sub("""
        import dataclasses, json
        import jax
        from repro.configs import REGISTRY, reduced_config
        from repro.configs.base import ShapeConfig
        from repro.launch import sharding as sh
        from repro.launch.inputs import input_specs
        from repro.launch.steps import make_train_step, make_serve_step
        from repro.models.params import abstract_params
        from repro.models import decode as dec
        from repro.optim.adamw import AdamWConfig, init_opt_state
        from jax.sharding import Mesh, NamedSharding
        import numpy as np

        mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        for name in ("llama3-8b", "mixtral-8x22b", "mamba2-780m",
                     "recurrentgemma-9b", "seamless-m4t-large-v2",
                     "qwen2-vl-2b"):
            cfg = dataclasses.replace(
                reduced_config(REGISTRY[name]), remat="full",
                d_model=64, param_dtype="bfloat16", compute_dtype="bfloat16")
            shape = ShapeConfig("t", "train", 32, 8)
            pspecs = sh.param_specs(cfg, mesh, fsdp=False)
            ap = jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(
                    l.shape, l.dtype, sharding=NamedSharding(mesh, s)),
                abstract_params(cfg), pspecs,
                is_leaf=lambda x: hasattr(x, "shape"))
            aopt = jax.eval_shape(init_opt_state, ap)
            ospecs = sh.opt_specs(cfg, mesh, pspecs)
            aopt = jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(
                    l.shape, l.dtype, sharding=NamedSharding(mesh, s)),
                aopt, ospecs, is_leaf=lambda x: hasattr(x, "shape"))
            batch = input_specs(cfg, shape, mesh)
            step = make_train_step(cfg, AdamWConfig(), microbatches=2)
            with mesh:
                c = jax.jit(step).lower(ap, aopt, batch).compile()
            assert c.memory_analysis().temp_size_in_bytes > 0
            # decode too
            dshape = ShapeConfig("d", "decode", 64, 8)
            ins = input_specs(cfg, dshape, mesh)
            sstep = make_serve_step(cfg)
            args = (ap, ins["cache"], ins["tokens"], ins["pos"])
            if "extras" in ins:
                jax.jit(sstep).lower(*args, ins["extras"]).compile()
            else:
                jax.jit(sstep).lower(*args).compile()
            print("OK", name)
    """, devices=8)
    assert out.count("OK") == 6


def test_trainer_midrun_relayout_meshswap_subprocess():
    """ROADMAP "trainer relayout on real fleets": with 8 forced host
    devices (== topology.total_chips) the adaptive controller moves
    spread_rate mid-training and ``Trainer._on_relayout`` performs an
    ACTUAL mesh swap — params/optimizer resharded onto the new mesh, the
    step re-jitted — and training keeps converging."""
    out = _run_sub("""
        import tempfile
        import jax
        import numpy as np
        from repro.configs import REGISTRY, reduced_config
        from repro.core.controller import ControllerConfig
        from repro.core.layout import Layout
        from repro.core.topology import ChipletTopology
        from repro.data.pipeline import (ShardedLoader, SyntheticCorpus,
                                         write_corpus_shards)
        from repro.runtime.trainer import Trainer, TrainerConfig

        topo = ChipletTopology(n_pods=1, groups_per_pod=4, chips_per_group=2)
        assert len(jax.devices()) == topo.total_chips == 8
        cfg = reduced_config(REGISTRY["llama3-8b"])
        tmp = tempfile.mkdtemp()
        corpus = SyntheticCorpus(cfg.vocab, seed=3)
        files = write_corpus_shards(tmp + "/data", corpus, n_shards=2,
                                    tokens_per_shard=20000)
        loader = ShardedLoader(files, seq_len=16, batch=8)
        mesh0 = Layout(topo, 1).make_mesh()        # s=1: data=4, model=2
        assert (mesh0.shape["data"], mesh0.shape["model"]) == (4, 2)
        tcfg = TrainerConfig(steps=6, ckpt_every=100, log_every=100,
                             ckpt_dir=tmp + "/ckpt")
        # threshold 0: every evaluation spreads -> s walks 1 -> 2 -> 4
        trainer = Trainer(cfg, mesh0, loader, tcfg, topology=topo,
                          controller_cfg=ControllerConfig(
                              scheduler_timer=2, threshold=0.0, min_dwell=0),
                          log=lambda s: None)
        out = trainer.run()
        assert out["counters"]["relayouts"] >= 2
        # the live mesh really swapped: s=4 -> one replica over all 8 chips
        assert (trainer.mesh.shape["data"], trainer.mesh.shape["model"]) \\
            == (1, 8)
        # params/optimizer migrated onto the new mesh
        for leaf in jax.tree.leaves(trainer.params):
            assert leaf.sharding.mesh.shape["model"] == 8
        for leaf in jax.tree.leaves(trainer.opt_state):
            if hasattr(leaf, "sharding"):
                assert leaf.sharding.mesh.shape["model"] == 8
        assert all(np.isfinite(l) for l in out["losses"])
        print("RELAYOUTS", int(out["counters"]["relayouts"]),
              "MESH", trainer.mesh.shape["data"], trainer.mesh.shape["model"])
    """)
    assert "RELAYOUTS" in out
    assert "MESH 1 8" in out


def test_dryrun_records_exist_or_skip():
    """If the full matrix has run, check record invariants."""
    d = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("dry-run matrix not yet generated")
    ok = skipped = 0
    for f in os.listdir(d):
        if not f.endswith(".json"):
            continue
        rec = json.load(open(os.path.join(d, f)))
        if rec["status"] == "skipped":
            skipped += 1
            assert "full quadratic attention" in rec["reason"]
        elif rec["status"] == "ok":
            ok += 1
            assert rec["memory"]["peak_per_device"] > 0
            if "roofline" in rec:
                r = rec["roofline"]
                assert r["compute_s"] > 0
                assert r["dominant"] in ("compute", "memory", "collective")
    assert ok + skipped >= 1
