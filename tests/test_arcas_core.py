"""ARCAS core tests: Algorithm 1 control law, Algorithm 2 placement
properties (hypothesis), layouts, cost model, coroutines + stealing."""
import numpy as np
import pytest

from conftest import hypothesis_tools

given, settings, st = hypothesis_tools()

from repro.configs import SHAPES, get_config
from repro.core.controller import AdaptiveController, ControllerConfig
from repro.core.costmodel import best_layout, estimate
from repro.core.counters import PerfCounters
from repro.core.layout import Layout, layout_family, update_location
from repro.core.tasks import TaskRuntime
from repro.core.topology import ChipletTopology, production_topology


# ---------------------------------------------------------------------------
# Algorithm 2 (Update Location)
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(spread=st.integers(1, 8), chiplets=st.sampled_from([4, 8, 16]),
       cores=st.sampled_from([4, 8, 16]))
def test_alg2_properties(spread, chiplets, cores):
    if spread > chiplets:
        assert update_location(0, spread, chiplets=chiplets,
                               cores_per_chiplet=cores,
                               thread_size=1) is None or spread <= chiplets
        return
    thread_size = min(spread * cores, chiplets * cores)
    cores_seen = set()
    chiplets_used = set()
    for rank in range(thread_size):
        res = update_location(rank, spread, chiplets=chiplets,
                              cores_per_chiplet=cores,
                              thread_size=thread_size)
        assert res is not None
        chip, slot, core = res
        assert 0 <= chip < chiplets            # wrap-around respected
        assert 0 <= core < chiplets * cores    # valid core
        cores_seen.add(core)
        chiplets_used.add(chip)
    assert len(cores_seen) == thread_size      # injective placement


def test_alg2_bounds_check():
    assert update_location(0, 0, chiplets=8, cores_per_chiplet=8,
                           thread_size=1) is None
    assert update_location(0, 9, chiplets=8, cores_per_chiplet=8,
                           thread_size=1) is None


def test_alg2_compact_uses_one_chiplet():
    """spread=1: the first CORES ranks all land on chiplet 0."""
    for rank in range(8):
        chip, slot, core = update_location(rank, 1, chiplets=8,
                                           cores_per_chiplet=8,
                                           thread_size=8)
        assert chip == 0 and core == slot == rank


# ---------------------------------------------------------------------------
# Layouts
# ---------------------------------------------------------------------------

def test_layout_family_bijective():
    topo = production_topology()
    for l in layout_family(topo):
        order = l.device_order()
        assert order.shape == (l.replicas, l.model_degree)
        assert sorted(order.flatten().tolist()) == list(range(256))


def test_layout_affinity_contiguous_groups():
    """Each replica's shards span exactly spread_rate contiguous groups."""
    topo = production_topology()
    for l in layout_family(topo):
        order = l.device_order()
        for r in range(l.replicas):
            groups = sorted({topo.group_of(int(c)) for c in order[r]})
            assert len(groups) == l.spread_rate
            assert groups == list(range(groups[0],
                                        groups[0] + l.spread_rate))


def test_layout_capacity():
    topo = production_topology()
    l1 = Layout(topo, 1)
    assert l1.replica_hbm() == pytest.approx(16 * 16e9)
    assert not l1.fits(300e9)
    assert Layout(topo, 2).fits(300e9)


# ---------------------------------------------------------------------------
# Algorithm 1 (controller)
# ---------------------------------------------------------------------------

def _run_controller(rates, threshold=100.0, start=1):
    topo = production_topology()
    ctrl = AdaptiveController(
        topo, ControllerConfig(scheduler_timer=1, threshold=threshold,
                               min_dwell=0), spread_rate=start)
    cnt = PerfCounters()
    history = []
    for r in rates:
        cnt.add("remote_bytes", r)
        ctrl.maybe_reschedule(cnt)
        history.append(ctrl.spread_rate)
    return history


def test_alg1_spreads_on_high_rate():
    h = _run_controller([500] * 6)
    assert h == [2, 4, 8, 16, 16, 16]      # divisor ladder up, clamped


def test_alg1_compacts_on_low_rate():
    h = _run_controller([1] * 6, start=16)
    assert h == [8, 4, 2, 1, 1, 1]


def test_alg1_threshold_equilibrium():
    """Rates oscillating around the threshold hold the spread in a band."""
    rates = [150, 50] * 10
    h = _run_controller(rates, threshold=100.0, start=4)
    assert set(h) <= {2, 4, 8}


def test_capacity_guard_forces_spread():
    """grok-1 decode: replica must span enough groups to fit params+KV."""
    topo = production_topology()
    cfg = get_config("grok-1-314b")
    ws = 700e9  # ~params+cache per replica
    ctrl = AdaptiveController(
        topo, ControllerConfig(scheduler_timer=1, threshold=1e18,
                               min_dwell=0),
        spread_rate=1, working_set_fn=lambda: ws)
    cnt = PerfCounters()
    cnt.add("remote_bytes", 0.0)
    ctrl.maybe_reschedule(cnt)
    assert Layout(topo, ctrl.spread_rate).fits(ws)
    assert ctrl.spread_rate >= 4


def test_min_dwell_hysteresis():
    """min_dwell holds the layout for N intervals after every move."""
    topo = production_topology()
    ctrl = AdaptiveController(
        topo, ControllerConfig(scheduler_timer=1, threshold=100.0,
                               min_dwell=2), spread_rate=1)
    cnt = PerfCounters()
    spreads, moved = [], []
    for _ in range(7):
        cnt.add("remote_bytes", 500)           # constant high pressure
        d = ctrl.maybe_reschedule(cnt)
        spreads.append(ctrl.spread_rate)
        moved.append(d is not None)
    # a move lands, then two dwell intervals suppress further moves
    assert spreads == [2, 2, 2, 4, 4, 4, 8]
    assert moved == [True, False, False, True, False, False, True]


def test_capacity_guard_blocks_compaction():
    """working_set_fn keeps the controller from compacting below fit."""
    topo = production_topology()
    ws = 700e9                                  # needs spread_rate >= 4
    ctrl = AdaptiveController(
        topo, ControllerConfig(scheduler_timer=1, threshold=100.0,
                               min_dwell=0),
        spread_rate=4, working_set_fn=lambda: ws)
    cnt = PerfCounters()
    for _ in range(3):
        cnt.add("remote_bytes", 1)              # low rate: wants compact
        assert ctrl.maybe_reschedule(cnt) is None
        assert ctrl.spread_rate == 4            # guard pinned the layout
    assert Layout(topo, ctrl.spread_rate).fits(ws)


def test_model_guided_picks_feasible_min():
    topo = production_topology()
    cfg = get_config("qwen2-vl-2b")
    shape = SHAPES["decode_32k"]
    fam = layout_family(topo)
    pick = best_layout(cfg, shape, fam)
    c = estimate(cfg, shape, pick)
    assert c.fits
    # the pick is the argmin of the modeled step time over feasible layouts
    best = min(estimate(cfg, shape, l).overlap_s for l in fam
               if estimate(cfg, shape, l).fits)
    assert c.overlap_s == pytest.approx(best)


# ---------------------------------------------------------------------------
# Cost model sanity
# ---------------------------------------------------------------------------

def test_costmodel_tradeoffs():
    topo = production_topology()
    cfg = get_config("llama3-8b")
    train = SHAPES["train_4k"]
    costs = [estimate(cfg, train, l) for l in layout_family(topo)]
    # spreading increases cross-group collective time monotonically
    rem = [c.ici_remote_s for c in costs]
    assert all(a <= b + 1e-12 for a, b in zip(rem, rem[1:]))
    # compute term is layout-invariant
    assert len({round(c.compute_s, 9) for c in costs}) == 1


def test_costmodel_grok_decode_memory_bound():
    topo = production_topology()
    cfg = get_config("grok-1-314b")
    c = estimate(cfg, SHAPES["decode_32k"], Layout(topo, 4))
    assert c.dominant == "memory"
    assert not estimate(cfg, SHAPES["decode_32k"], Layout(topo, 1)).fits


# ---------------------------------------------------------------------------
# Coroutines + chiplet-first stealing (§4.4)
# ---------------------------------------------------------------------------

def test_steal_order_prefers_same_pod():
    rt = TaskRuntime(n_pods=2, groups_per_pod=2, workers_per_group=1, seed=3)

    def work():
        for _ in range(2):
            yield

    for _ in range(24):
        rt.spawn(work(), group=0)     # all work lands in pod 0, group 0
    rt.run()
    snap = rt.counters.totals
    # same-pod steals must dominate cross-pod ones under locality order
    assert snap.get("steals_pod", 0) >= snap.get("steals_fleet", 0)


def test_tasks_complete_and_yield_counts():
    rt = TaskRuntime(n_pods=1, groups_per_pod=4)
    done = []

    def job(i):
        def gen():
            for _ in range(i % 3 + 1):
                yield
            done.append(i)
        return gen()

    tasks = [rt.spawn(job(i)) for i in range(20)]
    rt.barrier()
    assert sorted(done) == list(range(20))
    assert all(t.stats.yields >= 1 for t in tasks)


def test_steal_tier_preference_order():
    """First steals follow §4.4: group before pod before fleet."""
    rt = TaskRuntime(n_pods=2, groups_per_pod=2, workers_per_group=2, seed=0)

    def work():
        for _ in range(40):
            yield

    for _ in range(16):
        rt.spawn(work(), worker=0)    # all work on worker 0 (group 0, pod 0)
    rt.tick()
    first_tier = {}
    for e in rt.steal_log:
        first_tier.setdefault(e["thief"], e["tier"])
    assert first_tier[1] == "group"   # same-group peer steals locally
    assert first_tier[2] == "pod"     # same pod, different group
    assert first_tier[4] == "fleet"   # other pod: last resort
    snap = rt.counters.totals
    assert snap["steals_group"] >= 1
    assert snap["steals_pod"] >= 1
    assert snap["steals_fleet"] >= 1


def test_tiered_steal_matches_scan_semantics():
    """Both steal implementations drain identical workloads completely."""
    def build(impl):
        rt = TaskRuntime(n_pods=2, groups_per_pod=2, seed=5, steal_impl=impl)
        done = []

        def job(i):
            for _ in range(i % 4 + 1):
                yield
            done.append(i)

        for i in range(30):
            rt.spawn(job(i), group=i % 3)
        rt.run()
        return sorted(done)

    assert build("tiered") == build("scan") == list(range(30))


def test_tick_block_unblock():
    from repro.core.tasks import BLOCK
    rt = TaskRuntime(n_pods=1, groups_per_pod=2)
    log = []

    def producer():
        log.append("p1")
        yield BLOCK                   # park until unblocked
        log.append("p2")
        yield

    t = rt.spawn(producer())
    rt.tick()
    assert t.state == "blocked" and log == ["p1"]
    assert not rt.pending()           # blocked tasks are not runnable
    rt.tick()
    assert log == ["p1"]              # parked tasks never advance
    rt.unblock(t)
    assert rt.pending()
    rt.run()
    assert t.done and log == ["p1", "p2"]


def test_task_priority_runs_first():
    rt = TaskRuntime(n_pods=1, groups_per_pod=1)
    order = []

    def job(tag):
        order.append(tag)
        yield

    rt.spawn(job("lo"), priority=0, worker=0)
    rt.spawn(job("hi"), priority=5, worker=0)
    rt.run()
    assert order == ["hi", "lo"]


def test_topology_latency_classes():
    topo = production_topology(multi_pod=True)
    assert topo.link_class(0, 1) == "intra_group"
    assert topo.link_class(0, 16) == "intra_pod"
    assert topo.link_class(0, 256) == "cross_pod"
    lats, cls = topo.latency_cdf(512)
    assert (lats > 0).all()
