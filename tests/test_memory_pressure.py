"""Oversubscription stress suite (ISSUE 4): swap-tier KV eviction.

Under deep oversubscription the PR-3 stall watchdog broke incremental-
allocation deadlocks by restart-from-scratch eviction — every evicted
stream recomputed all of its tokens.  The swap tier spills the victim's
used pages to a host-side store instead and resumes the stream mid-decode
at its saved cursor when pages are re-granted.  Everything here hammers
the memory-pressure ladder (headroom -> park -> spill -> restart
fallback) and asserts the invariants that make it safe:

  * token identity across ``evict_mode`` in {"swap", "restart"} AND an
    uncontended baseline — spills, restores and evictions must all be
    invisible in the output;
  * no allocation deadlock (every randomized schedule drains);
  * FIFO grant order preserved (admissions are granted in submit order);
  * pool free-block accounting exact after EVERY spill/restore/free cycle
    (``KVBlockPool.audit``);
  * swap mode never recomputes (``recompute_tokens == 0``).
"""
import numpy as np
import pytest

from conftest import hypothesis_tools
from repro.configs import REGISTRY, reduced_config
from repro.core.topology import ChipletTopology
from repro.serving.engine import EngineConfig, ServeEngine
from repro.serving.kvpool import KVBlockPool

given, settings, st = hypothesis_tools()

CFG = reduced_config(REGISTRY["llama3-8b"])


def _engine(*, groups=1, max_batch=2, max_len=32, pool_streams=1,
            evict_mode="swap", headroom=0, adaptive=False, **ecfg_kw):
    topo = ChipletTopology(n_pods=1, groups_per_pod=groups,
                           chips_per_group=1)
    ecfg = EngineConfig(max_batch=max_batch, max_len=max_len, paged=True,
                        lazy=True, pool_streams=pool_streams,
                        adaptive=adaptive, evict_mode=evict_mode,
                        headroom=headroom, **ecfg_kw)
    return ServeEngine(CFG, topo, ecfg, spread_rate=1, seed=0)


def _instrument(eng):
    """Wire up the suite's two live invariants: pool accounting audited
    after every spill/restore/free, and the grant log (WaitQueue.remove is
    called exactly at resource grant)."""
    grants = []
    orig_remove = eng.waiters.remove

    def remove(task):
        grants.append(task.name)
        orig_remove(task)

    eng.waiters.remove = remove
    pool = eng.pool

    def live_tables():
        return [r.table for r in eng.submitted if r.table is not None]

    for name in ("spill", "restore", "free"):
        orig = getattr(pool, name)

        def wrapped(table, _orig=orig):
            out = _orig(table)
            pool.audit(live_tables())
            return out

        setattr(pool, name, wrapped)
    return grants


def _drain(eng):
    res = eng.run_until_done()
    assert all(r.done for r in eng.submitted), "allocation deadlock"
    return res


def _longtail(rng, n, max_len):
    """Randomized (gap, prompt, max_new): bursty arrivals, mixed prompt
    lengths, long-tail max_new (the mix that thrashed PR-3)."""
    out = []
    for _ in range(n):
        gap = int(rng.integers(0, 4))
        plen = int(rng.integers(3, max_len // 2))
        if rng.random() < 0.5:
            max_new = int(rng.integers(max_len // 2, max_len - plen))
        else:
            max_new = int(rng.integers(1, max(2, max_len // 8)))
        out.append((gap, rng.integers(2, CFG.vocab, size=plen), max_new))
    return out


def _fifo_admit_order(grants):
    admits = [int(n[len("admit"):]) for n in grants
              if n.startswith("admit")]
    assert admits == sorted(admits), \
        f"admission grants out of submit order: {admits}"


# ---------------------------------------------------------------------------
# the acceptance property (randomized oversubscription schedules)
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_token_identity_swap_restart_baseline(seed):
    """For every randomized arrival/prompt/max_new schedule: swap mode,
    restart mode and an uncontended baseline generate IDENTICAL tokens;
    swap never recomputes; grants stay FIFO; accounting stays exact."""
    rng = np.random.default_rng(seed)
    sched = _longtail(rng, int(rng.integers(3, 7)), 32)
    groups = int(rng.integers(1, 3))
    outs, counters = {}, {}
    for mode, (evict, streams) in {"swap": ("swap", 1),
                                   "restart": ("restart", 1),
                                   "baseline": ("swap", 8)}.items():
        eng = _engine(groups=groups, max_batch=4, pool_streams=streams,
                      evict_mode=evict)
        grants = _instrument(eng)
        eng.open_loop_client(list(sched))
        res = _drain(eng)
        outs[mode] = [r.generated for r in
                      sorted(eng.submitted, key=lambda r: r.rid)]
        counters[mode] = res["counters"]
        _fifo_admit_order(grants)
        assert eng.pool.occupancy() == 0.0
        assert eng.pool.spilled_tables == 0 and eng.pool.spilled_bytes == 0
        eng.pool.audit([])
    assert outs["swap"] == outs["restart"] == outs["baseline"]
    assert counters["swap"].get("recompute_tokens", 0) == 0
    assert counters["swap"].get("kv_evictions", 0) == 0
    assert counters["baseline"].get("kv_spills", 0) == 0
    # every restart eviction was wasted recompute the swap tier avoids
    if counters["restart"].get("kv_evictions", 0):
        assert counters["restart"]["recompute_tokens"] > 0


def test_deep_oversubscription_evictions_become_spills():
    """The acceptance scenario at test scale: a dense schedule at 1
    stream/domain that forces restart mode to evict repeatedly.  Swap mode
    must generate the identical tokens with ZERO recomputed tokens — every
    eviction becomes a spill/restore cycle."""
    rng = np.random.default_rng(0)
    sched = [(int(rng.integers(0, 2)),
              rng.integers(2, CFG.vocab, size=4), 26) for _ in range(6)]
    runs = {}
    for mode in ("swap", "restart"):
        eng = _engine(groups=1, max_batch=4, pool_streams=1,
                      evict_mode=mode)
        _instrument(eng)
        eng.open_loop_client(list(sched))
        res = _drain(eng)
        runs[mode] = (eng, res["counters"])
    cs, cr = runs["swap"][1], runs["restart"][1]
    assert cr.get("kv_evictions", 0) >= 2, "scenario must thrash restart"
    assert cr.get("recompute_tokens", 0) > 0
    assert cs.get("kv_spills", 0) >= 2
    assert cs.get("kv_restores", 0) == cs.get("kv_spills", 0)
    assert cs.get("kv_evictions", 0) == 0
    assert cs.get("recompute_tokens", 0) == 0
    toks = {m: [r.generated for r in
                sorted(runs[m][0].submitted, key=lambda r: r.rid)]
            for m in runs}
    assert toks["swap"] == toks["restart"]


# ---------------------------------------------------------------------------
# edge cases
# ---------------------------------------------------------------------------

def test_spill_mid_prefill_resumes_at_partial_chunk_cursor():
    """A stream spilled while still MID-PREFILL (its park cursor sits at a
    chunk boundary inside the prompt) restores and finishes the prompt
    from that cursor — never re-chunking from position 0."""
    r = np.random.default_rng(0)
    sched = []
    for _ in range(4):        # bursty arrivals, prompts spanning 2-3 pages
        gap = int(r.integers(0, 6))
        plen = int(r.integers(3, 31))
        mx = int(r.integers(2, 28))
        sched.append((gap, r.integers(2, CFG.vocab, size=plen), mx))
    spilled_at = []

    def run(streams):
        eng = _engine(groups=1, max_batch=2, pool_streams=streams,
                      block_tokens=8)
        orig_spill = eng.pool.spill

        def spy(table):
            for rec in eng._parked.values():
                if rec.req.table is table:
                    spilled_at.append((rec.pos, len(rec.req.prompt)))
            return orig_spill(table)

        eng.pool.spill = spy
        eng.open_loop_client(list(sched))
        _drain(eng)
        return [req.generated for req in
                sorted(eng.submitted, key=lambda q: q.rid)]

    toks = run(1)
    assert any(pos < plen for pos, plen in spilled_at), \
        f"no mid-prefill spill happened: {spilled_at}"
    assert toks == run(8)                      # uncontended baseline


def test_spill_victim_relayouted_before_restore():
    """A relayout (replica groups merge/split) fired while a stream sits
    SPILLED must not strand it: the host-resident table re-points /
    restores into whatever domain has room under the new owners, and the
    run stays token-identical to the undisturbed one."""
    from repro.core.controller import Decision
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, CFG.vocab, size=int(rng.integers(3, 10)))
               for _ in range(12)]
    max_new = [26 if i % 2 == 0 else 3 for i in range(12)]

    def run(relayout_on_spill):
        eng = _engine(groups=4, max_batch=1, pool_streams=1)
        _instrument(eng)
        if relayout_on_spill:
            orig_spill = eng.pool.spill
            fired = []

            def spill_then_relayout(table):
                out = orig_spill(table)
                if not fired:           # first spill: merge 4 groups -> 2
                    fired.append(True)  # (the controller's spread move,
                    ctl = eng.sched.controller          # forced mid-spill)
                    ctl.spread_rate = 2
                    eng._relayout(eng.sched.layout(),
                                  Decision(step=0, old_spread=1,
                                           new_spread=2, rate=0.0,
                                           reason="forced: spill in flight"))
                return out

            eng.pool.spill = spill_then_relayout
        reqs = [eng.submit(p, max_new=m)
                for p, m in zip(prompts, max_new)]
        res = _drain(eng)
        return eng, [r.generated for r in reqs], res

    eng_a, toks_a, res_a = run(True)
    c = res_a["counters"]
    assert c.get("kv_spills", 0) >= 1
    assert c.get("kv_restores", 0) == c.get("kv_spills", 0)
    assert c.get("recompute_tokens", 0) == 0
    assert len(eng_a.groups) == 2          # the relayout really happened
    assert eng_a.pool.occupancy() == 0.0 and eng_a.pool.spilled_tables == 0
    eng_b, toks_b, res_b = run(False)
    assert toks_a == toks_b


def test_spilled_table_steal_migration_is_zero_copy():
    """Migrating a host-resident table (a steal pulling a spilled stream
    into the thief's domain, or a relayout rebalance) re-points ``domain``
    without touching device pages: ``kv_blocks_migrated`` unchanged,
    ``kv_spill_repoints`` counted, restore lands in the new domain."""
    pool = KVBlockPool(CFG, n_domains=2, max_len=32, blocks_per_domain=2,
                       states_per_domain=2)
    t = pool.reserve(0, 40, first_tokens=8)
    pool.grow(t, 1)
    t.used_pages = 2
    pool.spill(t)
    mig0 = pool.counters.totals.get("kv_blocks_migrated", 0.0)
    assert pool.migrate(t, 1)
    assert t.domain == 1 and t.blocks == []
    assert pool.counters.totals.get("kv_blocks_migrated", 0.0) == mig0
    assert pool.counters.totals.get("kv_spill_repoints", 0.0) == 1
    assert pool.restore(t)
    assert t.domain == 1 and len(t.blocks) == 2 and t.used_pages == 2
    lo = 1 + 1 * pool.blocks_per_domain
    assert all(lo <= b < lo + pool.blocks_per_domain for b in t.blocks)
    pool.audit([t])
    pool.free(t)
    pool.audit([])


def test_headroom_zero_reduces_to_pr3_and_k_prevents_deadlock():
    """``headroom=0`` + ``evict_mode="restart"`` IS PR-3: the classic
    two-stream deadlock produces the same eviction the PR-3 watchdog did.
    ``headroom=1`` holds back the second admission so the deadlock never
    forms — no parks, no spills, no evictions — at identical tokens."""
    rng = np.random.default_rng(2)
    prompts = [rng.integers(2, CFG.vocab, size=4) for _ in range(2)]
    outs = {}
    stats = {}
    for name, (evict, k) in {"pr3": ("restart", 0), "swap0": ("swap", 0),
                             "k1": ("swap", 1)}.items():
        eng = _engine(groups=1, max_batch=2, pool_streams=1,
                      evict_mode=evict, headroom=k)
        reqs = [eng.submit(p, max_new=26) for p in prompts]
        res = _drain(eng)
        outs[name] = [r.generated for r in reqs]
        stats[name] = res["counters"]
    assert outs["pr3"] == outs["swap0"] == outs["k1"]
    assert stats["pr3"].get("kv_evictions", 0) >= 1        # PR-3 behavior
    assert stats["pr3"].get("kv_spills", 0) == 0
    # same pressure, resolved by the swap tier instead
    assert stats["swap0"].get("kv_spills", 0) == \
        stats["pr3"].get("kv_evictions", 0)
    assert stats["swap0"].get("recompute_tokens", 0) == 0
    # headroom prevents the deadlock from ever forming
    assert stats["k1"].get("kv_spills", 0) == 0
    assert stats["k1"].get("kv_evictions", 0) == 0
    assert stats["k1"].get("kv_mid_decode_parks", 0) == 0
    # an absurd k throttles (serializes admissions) but can never
    # livelock: reserve() clamps so an empty domain always admits
    eng = _engine(groups=1, max_batch=2, pool_streams=1, headroom=99)
    reqs = [eng.submit(p, max_new=26) for p in prompts]
    _drain(eng)
    assert [r.generated for r in reqs] == outs["pr3"]


def test_watchdog_double_fire_while_spill_outstanding():
    """A second watchdog fire while an earlier victim is still
    host-resident must pick a DIFFERENT victim; once every parked stream
    is spilled the ladder falls back to restart eviction — and the run
    still drains token-identically."""
    rng = np.random.default_rng(5)
    prompts = [rng.integers(2, CFG.vocab, size=4) for _ in range(2)]
    eng = _engine(groups=1, max_batch=2, pool_streams=1)
    reqs = [eng.submit(p, max_new=26) for p in prompts]
    eng._running = True
    for g in eng.groups:
        eng._spawn_group(g)
    rounds = 0
    while len(eng._parked) < 2 and rounds < 500:
        eng.sched.tick()
        rounds += 1
    assert len(eng._parked) == 2, "deadlock scenario failed to form"
    # fire 1: youngest parked stream spills
    assert eng._spill_youngest()
    spilled = {rid for rid, r in eng._parked.items()
               if r.req.table.spill is not None}
    assert len(spilled) == 1
    # fire 2 (spill still outstanding): must pick the OTHER stream
    assert eng._spill_youngest()
    assert all(r.req.table.spill is not None
               for r in eng._parked.values())
    # fire 3: nothing left to spill -> the hook's restart fallback
    assert not eng._spill_youngest()
    ev0 = eng.counters.totals.get("kv_evictions", 0)
    eng._stall_rounds = eng.ecfg.stall_evict_rounds
    eng._progress_mark = eng._progress_signature()
    eng._stall_hook()
    assert eng.counters.totals.get("kv_evictions", 0) == ev0 + 1
    eng.sched.run_until_done(max_rounds=100000,
                             round_hook=eng._stall_hook)
    assert all(r.done for r in eng.submitted)
    assert eng.pool.occupancy() == 0.0 and eng.pool.spilled_tables == 0
    # identical to the uncontended baseline
    base = _engine(groups=1, max_batch=2, pool_streams=8)
    base_reqs = [base.submit(p, max_new=26) for p in prompts]
    _drain(base)
    assert [r.generated for r in reqs] == \
        [r.generated for r in base_reqs]


def test_spill_carries_state_leaves_hybrid_model():
    """A hybrid (recurrent + attention) model's per-stream STATE slot must
    ride the spill with its ring pages: spill, cross-domain re-point,
    restore — bit-identical page and state contents, exact accounting."""
    import jax
    import jax.numpy as jnp
    cfg = reduced_config(REGISTRY["recurrentgemma-9b"])
    pool = KVBlockPool(cfg, n_domains=2, max_len=32, blocks_per_domain=4,
                       states_per_domain=2)
    assert pool.has_state
    t = pool.reserve(0, 40, first_tokens=8)
    if pool.pages_per_stream:
        pool.grow(t, 1)
        t.used_pages = len(t.blocks)
    new = []
    for leaf, s in zip(jax.tree.leaves(pool.storage), pool.spec.leaves):
        ax = s.batch_axis
        idx = (slice(None),) * ax
        if s.token_axis is not None and t.blocks:
            leaf = leaf.at[idx + (jnp.asarray(t.blocks),)].set(3.25)
        elif s.token_axis is None and t.state_slot:
            leaf = leaf.at[idx + (t.state_slot,)].set(7.5)
        new.append(leaf)
    pool.storage = jax.tree.unflatten(pool.spec.treedef, new)
    assert pool.spill(t) == t.used_pages
    assert t.state_slot == 0 and pool.free_states(0) == 2
    assert pool.migrate(t, 1)
    assert pool.restore(t)
    assert t.state_slot and t.domain == 1
    for leaf, s in zip(jax.tree.leaves(pool.storage), pool.spec.leaves):
        ax = s.batch_axis
        if s.token_axis is not None and t.blocks:
            vals = jnp.take(leaf, jnp.asarray(t.blocks), axis=ax)
            assert jnp.all(vals == 3.25), "ring page data lost in spill"
        elif s.token_axis is None and t.state_slot:
            vals = jnp.take(leaf, jnp.asarray([t.state_slot]), axis=ax)
            assert jnp.all(vals == 7.5), "state slot lost in spill"
    pool.audit([t])
    pool.free(t)
    pool.audit([])
    assert pool.spilled_tables == 0 and pool.spilled_bytes == 0.0


def test_parallel_spill_mid_prefill_token_identity():
    """Spill-while-mid-prefill under the FUSED (parallel) chunk path
    (ISSUE 5): a stream spilled with its cursor inside the prompt restores
    and finishes from that chunk boundary, token-identical to the scan
    reference and to an uncontended baseline."""
    r = np.random.default_rng(0)
    sched = []
    for _ in range(4):
        gap = int(r.integers(0, 6))
        plen = int(r.integers(3, 31))
        mx = int(r.integers(2, 28))
        sched.append((gap, r.integers(2, CFG.vocab, size=plen), mx))
    spilled_at = []

    def run(streams, pmode):
        eng = _engine(groups=1, max_batch=2, pool_streams=streams,
                      block_tokens=8, prefill_mode=pmode)
        orig_spill = eng.pool.spill

        def spy(table):
            for rec in eng._parked.values():
                if rec.req.table is table:
                    spilled_at.append((rec.pos, len(rec.req.prompt)))
            return orig_spill(table)

        eng.pool.spill = spy
        eng.open_loop_client(list(sched))
        _drain(eng)
        return [req.generated for req in
                sorted(eng.submitted, key=lambda q: q.rid)]

    toks_p = run(1, "parallel")
    assert any(pos < plen for pos, plen in spilled_at), \
        f"no mid-prefill spill happened: {spilled_at}"
    assert toks_p == run(1, "scan")            # fused == per-token scan
    assert toks_p == run(8, "parallel")        # uncontended baseline


def test_parallel_relayout_between_chunks_token_identity():
    """A forced relayout firing BETWEEN chunk ticks of the fused path
    (streams mid-prefill with partially-grown tables) re-points tables /
    copies used pages exactly as in scan mode: adaptive parallel,
    non-adaptive parallel and non-adaptive scan all generate the same
    tokens."""
    from repro.core.controller import ControllerConfig
    rng = np.random.default_rng(7)
    prompts = [rng.integers(2, CFG.vocab, size=int(rng.integers(4, 20)))
               for _ in range(12)]
    max_new = [2 if i % 4 == 0 else 10 for i in range(12)]

    def run(adaptive, pmode):
        eng = _engine(groups=4, max_batch=1, pool_streams=4,
                      adaptive=adaptive, prefill_mode=pmode,
                      controller=ControllerConfig(scheduler_timer=3,
                                                  threshold=1.0,
                                                  min_dwell=1))
        reqs = [eng.submit(p, max_new=m) for p, m in zip(prompts, max_new)]
        res = _drain(eng)
        return [r.generated for r in reqs], res

    toks_a, res_a = run(True, "parallel")
    assert len(res_a["relayouts"]) >= 1        # really relayouted mid-run
    toks_b, res_b = run(False, "parallel")
    assert res_b["relayouts"] == []
    toks_c, _ = run(False, "scan")
    assert toks_a == toks_b == toks_c


# ---------------------------------------------------------------------------
# pool-level mechanics
# ---------------------------------------------------------------------------

def test_pool_spill_restore_accounting_and_failure_paths():
    """Spill is idempotent, restore fails cleanly when the domain is full,
    byte gauges track the swap tier exactly, and ``audit`` actually
    catches a leak."""
    from repro.core.costmodel import kv_spill_bytes
    pool = KVBlockPool(CFG, n_domains=1, max_len=32, blocks_per_domain=2,
                       states_per_domain=2)
    t = pool.reserve(0, 64, first_tokens=8)
    pool.grow(t, 1)
    t.used_pages = 2
    assert pool.spill(t) == 2
    assert pool.spill(t) == 0                       # idempotent
    assert pool.spilled_bytes == pytest.approx(
        kv_spill_bytes(CFG, 2, pool.block_tokens, False))
    assert pool.peak_spilled_bytes == pool.spilled_bytes
    # another stream takes the whole domain: restore must fail, no effects
    other = pool.reserve(0, 64)
    assert other is not None and len(other.blocks) == 2
    free0 = pool.free_blocks(0)
    assert not pool.restore(t)
    assert pool.free_blocks(0) == free0 and t.spill is not None
    assert pool.counters.totals.get("kv_restore_failures", 0) == 1
    pool.free(other)
    assert pool.restore(t)
    assert pool.spilled_bytes == 0.0
    pool.audit([t, other])
    # audit catches a double-free (a block both held and on the free list)
    pool._free_blocks[0].append(t.blocks[0])
    with pytest.raises(AssertionError):
        pool.audit([t])
    pool._free_blocks[0].pop()
    pool.audit([t])
    # freeing a spilled table drops its host payload (restart fallback)
    pool.free(t)
    t2 = pool.reserve(0, 32, first_tokens=8)
    t2.used_pages = 1
    pool.spill(t2)
    pool.free(t2)
    assert pool.spilled_tables == 0 and pool.spilled_bytes == 0.0
    pool.audit([])


def test_spill_counters_surface_in_kv_stats_and_samples():
    """kv_spilled_pages / kv_restores / recompute_tokens reach kv_stats
    AND the profiler's StepSample stream (the wasted-recompute metric is a
    first-class serving signal now)."""
    rng = np.random.default_rng(2)
    prompts = [rng.integers(2, CFG.vocab, size=4) for _ in range(2)]
    eng = _engine(groups=1, max_batch=2, pool_streams=1)
    [eng.submit(p, max_new=26) for p in prompts]
    _drain(eng)
    kv = eng.kv_stats()
    for key in ("spills", "spilled_pages", "restores", "restore_failures",
                "spill_repoints", "spilled_tables", "peak_spilled_bytes",
                "recompute_tokens", "evictions"):
        assert key in kv, key
    assert kv["spills"] >= 1 and kv["restores"] >= 1
    assert kv["spilled_pages"] >= 1
    assert kv["peak_spilled_bytes"] > 0
    assert kv["recompute_tokens"] == 0 and kv["evictions"] == 0
    samples = eng.counters.samples
    assert sum(s.kv_spilled_pages for s in samples) >= 1
    assert sum(s.kv_restores for s in samples) >= 1
    # restart mode pushes the wasted work into the same surfaces
    eng_r = _engine(groups=1, max_batch=2, pool_streams=1,
                    evict_mode="restart")
    [eng_r.submit(p, max_new=26) for p in prompts]
    _drain(eng_r)
    kv_r = eng_r.kv_stats()
    assert kv_r["recompute_tokens"] > 0 and kv_r["spills"] == 0
    assert sum(s.recompute_tokens for s in eng_r.counters.samples) > 0


def test_waitqueue_to_back_regrant_path():
    """``WaitQueue.to_back`` (the spill regrant path): the victim loses
    its place, keeps line membership, and its parked-since clock restarts
    — later waiters are granted first, exactly like a restart eviction's
    re-admission, but with state intact."""
    from repro.core.tasks import TaskRuntime, WaitQueue

    def gen():
        yield

    rt = TaskRuntime(n_pods=1, groups_per_pod=1)
    t = [0.0]
    wq = WaitQueue(rt, clock=lambda: t[0])
    a, b, c = (rt.spawn(gen(), name=n) for n in "abc")
    wq.park(a)
    t[0] = 1.0
    wq.park(b)
    wq.park(c)
    t[0] = 2.0
    wq.to_back(a)
    assert wq.oldest() is b and wq.youngest() is a
    assert wq.parked_since(a) == 2.0               # the new wait starts now
    assert len(wq) == 3 and a in wq
    wq.to_back(rt.spawn(gen(), name="d"))          # not in line: no-op
    assert len(wq) == 3
