"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles
(interpret mode), plus hypothesis property tests on the math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_tools

given, settings, st = hypothesis_tools()

from repro.kernels.flash_attention.ops import (flash_attention,
                                               ring_chunk_attention)
from repro.kernels.flash_attention.ref import gqa_attention_ref
from repro.kernels.rglru_scan.ops import lru
from repro.kernels.rglru_scan.ref import lru_scan_ref
from repro.kernels.ssd_scan.ops import ssd, ssd_with_state
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.layers import blocked_attention

KEY = jax.random.PRNGKey(11)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_SWEEP = [
    # B, S, Hq, Hkv, dh, causal, window, dtype
    (2, 128, 4, 2, 64, True, 0, jnp.float32),
    (1, 256, 4, 4, 32, True, 64, jnp.float32),
    (2, 128, 8, 2, 64, False, 0, jnp.float32),
    (1, 128, 2, 1, 128, True, 32, jnp.float32),
    (2, 64, 4, 1, 64, True, 0, jnp.bfloat16),
    (1, 192, 6, 3, 32, True, 48, jnp.float32),
]


@pytest.mark.parametrize("B,S,Hq,Hkv,dh,causal,window,dtype", FA_SWEEP)
def test_flash_attention_fwd(B, S, Hq, Hkv, dh, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, dh), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, dh), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, dh), dtype)
    out = flash_attention(q, k, v, causal, window, 64, 64, True)
    ref = gqa_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,S,Hq,Hkv,dh,causal,window,dtype", FA_SWEEP[:4])
def test_flash_attention_grads(B, S, Hq, Hkv, dh, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, dh), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, dh), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, dh), dtype)
    f = lambda q, k, v: (flash_attention(q, k, v, causal, window, 64, 64,
                                         True) ** 2).sum()
    fr = lambda q, k, v: (gqa_attention_ref(q, k, v, causal=causal,
                                            window=window) ** 2).sum()
    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_flash_attention_causality():
    """Changing a future token never changes past outputs."""
    ks = jax.random.split(KEY, 3)
    B, S, H, dh = 1, 64, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    out1 = flash_attention(q, k, v, True, 0, 32, 32, True)
    k2 = k.at[:, -1].add(100.0)
    v2 = v.at[:, -1].add(100.0)
    out2 = flash_attention(q, k2, v2, True, 0, 32, 32, True)
    np.testing.assert_allclose(np.asarray(out1[:, :-1]),
                               np.asarray(out2[:, :-1]), rtol=1e-6, atol=1e-6)


def test_window_equals_masked_dense():
    """SWA kernel == dense attention with an explicit band mask."""
    ks = jax.random.split(KEY, 3)
    B, S, H, dh, W = 1, 96, 2, 16, 24
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    out = flash_attention(q, k, v, True, W, 32, 32, True)
    ref = gqa_attention_ref(q, k, v, causal=True, window=W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.integers(0, 1))
def test_blocked_attention_property(b, heads_pow, causal):
    """jnp blocked attention == dense oracle for random GQA configs."""
    Hq = 2 ** heads_pow
    Hkv = max(1, Hq // 2)
    S, dh = 48, 16
    ks = jax.random.split(jax.random.PRNGKey(b * 7 + heads_pow), 3)
    q = jax.random.normal(ks[0], (b, S, Hq, dh))
    k = jax.random.normal(ks[1], (b, S, Hkv, dh))
    v = jax.random.normal(ks[2], (b, S, Hkv, dh))
    out = blocked_attention(q, k, v, causal=bool(causal), block_q=16,
                            block_kv=16)
    ref = gqa_attention_ref(q, k, v, causal=bool(causal))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ring-chunk attention (serving fused-prefill kernel)
# ---------------------------------------------------------------------------

def _ring_case(B, C, W, Hq, Hkv, dh, pos, nt, window, softcap, bq, bkv,
               dtype, seed=0):
    """Blocked Pallas kernel (interpret) vs the dense chunk_attention
    reference, row-by-row: active rows (t < n_tokens) must match; inactive
    rows are discarded by the engine but must at least stay finite (the
    kernel returns 0 where the dense path degrades to a uniform softmax)."""
    from repro.models.layers import chunk_attention
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, C, Hq, dh), dtype)
    kn = jax.random.normal(ks[1], (B, C, Hkv, dh), dtype)
    vn = jax.random.normal(ks[2], (B, C, Hkv, dh), dtype)
    kc = jax.random.normal(ks[3], (B, W, Hkv, dh), dtype)
    vc = jax.random.normal(ks[4], (B, W, Hkv, dh), dtype)
    pos = jnp.asarray(pos, jnp.int32)
    nt = jnp.asarray(nt, jnp.int32)
    ref = np.asarray(chunk_attention(q, kn, vn, kc, vc, pos, nt,
                                     window=window, softcap=softcap),
                     np.float32)
    out = np.asarray(ring_chunk_attention(q, kn, vn, kc, vc, pos, nt,
                                          window=window, softcap=softcap,
                                          block_q=bq, block_kv=bkv,
                                          interpret=True), np.float32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 5e-5
    for b in range(B):
        n = int(nt[b])
        np.testing.assert_allclose(out[b, :n], ref[b, :n], rtol=tol,
                                   atol=tol, err_msg=f"stream {b}")
        assert np.all(np.isfinite(out[b, n:])), f"stream {b} inactive rows"


RING_SWEEP = [
    # B, C, W, Hq, Hkv, dh, pos, nt, window, softcap, bq, bkv, dtype
    (2, 4, 16, 4, 2, 32, (0, 3), (4, 2), 0, 0.0, 32, 32, jnp.float32),
    (2, 6, 8, 4, 4, 16, (13, 27), (6, 6), 0, 0.0, 4, 4, jnp.float32),
    (2, 10, 8, 4, 2, 16, (5, 21), (10, 7), 0, 0.0, 32, 32, jnp.float32),
    (3, 4, 8, 2, 1, 16, (0, 5, 9), (0, 0, 4), 0, 0.0, 32, 32, jnp.float32),
    (2, 8, 16, 4, 2, 32, (20, 3), (8, 5), 7, 30.0, 4, 8, jnp.float32),
    (1, 4, 8, 8, 1, 32, (11,), (4,), 0, 0.0, 32, 32, jnp.float32),
    (1, 5, 13, 2, 2, 16, (29,), (5,), 0, 0.0, 4, 8, jnp.float32),
    (2, 6, 12, 4, 2, 32, (9, 15), (6, 3), 0, 0.0, 8, 8, jnp.bfloat16),
]


@pytest.mark.parametrize(
    "B,C,W,Hq,Hkv,dh,pos,nt,window,softcap,bq,bkv,dtype", RING_SWEEP,
    ids=["basic", "ring_wrap_tails", "chunk_wider_than_ring", "idle_rows",
         "window_softcap", "gqa_group8", "nondivisible_bkv", "bf16"])
def test_ring_chunk_attention_vs_dense(B, C, W, Hq, Hkv, dh, pos, nt,
                                       window, softcap, bq, bkv, dtype):
    _ring_case(B, C, W, Hq, Hkv, dh, pos, nt, window, softcap, bq, bkv,
               dtype, seed=B * 31 + C)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10**6))
def test_ring_chunk_attention_property(seed):
    """Randomized equivalence: random chunk/ring widths (incl. C > W),
    positions (incl. ring wrap and pos=0), per-stream n_tokens (incl. 0),
    GQA group sizes and block shapes that leave partial tail blocks."""
    rng = np.random.default_rng(seed)
    B = int(rng.integers(1, 4))
    C = int(rng.integers(1, 12))
    W = int(rng.integers(2, 20))
    G = int(rng.choice([1, 2, 4]))
    Hkv = int(rng.choice([1, 2]))
    dh = int(rng.choice([8, 16]))
    pos = rng.integers(0, 3 * W, size=B)
    nt = rng.integers(0, C + 1, size=B)
    window = int(rng.choice([0, 0, max(1, W // 2)]))
    softcap = float(rng.choice([0.0, 25.0]))
    bq = int(rng.choice([3, 4, 8, 32]))
    bkv = int(rng.choice([5, 8, 16, 32]))
    _ring_case(B, C, W, G * Hkv, Hkv, dh, pos, nt, window, softcap, bq,
               bkv, jnp.float32, seed=seed % 1009)


def test_ring_chunk_attention_idle_stream_at_pos0_returns_zeros():
    """A fully-masked row (idle stream with an empty ring) must come out
    exactly 0 from the kernel — the online-softmax finalize guards its
    zero normalizer instead of emitting NaN (the dense path's discarded
    uniform-softmax row is the reference's equivalent hazard)."""
    ks = jax.random.split(KEY, 5)
    B, C, W, H, dh = 1, 4, 8, 2, 16
    out = ring_chunk_attention(
        jax.random.normal(ks[0], (B, C, H, dh)),
        jax.random.normal(ks[1], (B, C, H, dh)),
        jax.random.normal(ks[2], (B, C, H, dh)),
        jax.random.normal(ks[3], (B, W, H, dh)),
        jax.random.normal(ks[4], (B, W, H, dh)),
        jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.int32),
        interpret=True)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

LRU_SWEEP = [(2, 128, 64, 32, 32), (1, 64, 128, 16, 128), (3, 32, 16, 32, 16),
             (1, 256, 32, 64, 32)]


@pytest.mark.parametrize("B,S,W,bs,bw", LRU_SWEEP)
def test_lru_scan(B, S, W, bs, bw):
    k1, k2 = jax.random.split(KEY)
    a = jax.nn.sigmoid(jax.random.normal(k1, (B, S, W)))
    b = jax.random.normal(k2, (B, S, W))
    h = lru(a, b, bs, bw, True)
    href, _ = lru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(href),
                               rtol=1e-5, atol=1e-5)


def test_lru_grads():
    B, S, W = 2, 64, 32
    k1, k2 = jax.random.split(KEY)
    a = jax.nn.sigmoid(jax.random.normal(k1, (B, S, W)))
    b = jax.random.normal(k2, (B, S, W))
    g1 = jax.grad(lambda a, b: (lru(a, b, 16, 32, True) ** 2).sum(),
                  argnums=(0, 1))(a, b)
    g2 = jax.grad(lambda a, b: (lru_scan_ref(a, b)[0] ** 2).sum(),
                  argnums=(0, 1))(a, b)
    for x, y in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_lru_decay_bound_property(seed):
    """|h_t| <= max|b| / (1 - max a) for decays in (0, 1) (BIBO bound)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (1, 64, 8))) * 0.95
    b = jax.random.normal(ks[1], (1, 64, 8))
    h = lru(a, b, 16, 8, True)
    bound = float(jnp.max(jnp.abs(b))) / (1 - 0.95) + 1e-3
    assert float(jnp.max(jnp.abs(h))) <= bound


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_SWEEP = [(2, 64, 4, 16, 1, 32, 16), (1, 32, 4, 8, 2, 16, 32),
             (2, 128, 2, 32, 1, 8, 64)]


@pytest.mark.parametrize("B,S,H,Pd,G,N,chunk", SSD_SWEEP)
def test_ssd_scan(B, S, H, Pd, G, N, chunk):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, S, H, Pd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B_ = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    C_ = jax.random.normal(ks[0], (B, S, G, N)) * 0.5
    y, hT = ssd_with_state(x, dt, A, B_, C_, chunk=chunk, interpret=True)
    yref, href = ssd_scan_ref(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(href),
                               rtol=3e-4, atol=3e-4)


def test_ssd_grads():
    B, S, H, Pd, G, N = 1, 32, 2, 8, 1, 16
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, S, H, Pd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B_ = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    C_ = jax.random.normal(ks[0], (B, S, G, N)) * 0.5
    g1 = jax.grad(lambda x, dt: (ssd(x, dt, A, B_, C_, 16, True) ** 2).sum(),
                  argnums=(0, 1))(x, dt)
    g2 = jax.grad(lambda x, dt: (ssd_scan_ref(x, dt, A, B_, C_)[0] ** 2).sum(),
                  argnums=(0, 1))(x, dt)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 500))
def test_ssd_state_linearity_property(seed):
    """SSD is linear in x: y(ax) = a*y(x) for fixed gates."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    B, S, H, Pd, G, N = 1, 32, 2, 8, 1, 8
    x = jax.random.normal(ks[0], (B, S, H, Pd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.2)
    B_ = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    C_ = jax.random.normal(ks[0], (B, S, G, N)) * 0.5
    y1 = ssd(x, dt, A, B_, C_, 16, True)
    y2 = ssd(2.5 * x, dt, A, B_, C_, 16, True)
    np.testing.assert_allclose(np.asarray(y2), 2.5 * np.asarray(y1),
                               rtol=1e-4, atol=1e-4)
