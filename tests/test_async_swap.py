"""Async two-tier KV memory (ISSUE 10): spill/restore overlapped behind
the token loop.

The PR-4 swap tier spilled synchronously: the pressure ladder gathered
the victim's pages, waited for the copy, then re-granted.  The transfer
engine splits that into ISSUE / POLL / FENCE phases — ``spill_issue``
dispatches the D2H gather and returns, decode ticks keep running, and
the victim's pages are re-granted only when the poll (or a fence) lands
the transfer.  Everything here asserts the invariants that make the
overlap safe:

  * token identity: async mode, its synchronous twin and an uncontended
    baseline generate IDENTICAL tokens on randomized oversubscription
    schedules — and async still never recomputes;
  * fence-before-regrant: an in-flight victim KEEPS its device pages and
    state slot until the transfer lands; the pool free callback fires at
    landing, never at issue;
  * ``pool.audit()`` stays exact WHILE transfers are outstanding;
  * migration (the relayout path) and shutdown drain the pipe first;
  * ``restore_into`` reserves pages + growth + state slot atomically —
    a failed sweep leg has ZERO side effects (the PR-10 regression: the
    old sweep could leak a state checkpoint on a failed grow).
"""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import hypothesis_tools
from repro.configs import REGISTRY, reduced_config
from repro.core.topology import ChipletTopology
from repro.serving.engine import EngineConfig, ServeEngine
from repro.serving.kvpool import KVBlockPool

given, settings, st = hypothesis_tools()

CFG = reduced_config(REGISTRY["llama3-8b"])


def _engine(*, groups=1, max_batch=2, max_len=32, pool_streams=1,
            evict_mode="swap", headroom=0, adaptive=False, **ecfg_kw):
    topo = ChipletTopology(n_pods=1, groups_per_pod=groups,
                           chips_per_group=1)
    ecfg = EngineConfig(max_batch=max_batch, max_len=max_len, paged=True,
                        lazy=True, pool_streams=pool_streams,
                        adaptive=adaptive, evict_mode=evict_mode,
                        headroom=headroom, **ecfg_kw)
    return ServeEngine(CFG, topo, ecfg, spread_rate=1, seed=0)


def _instrument_async(eng):
    """Audit the pool after EVERY transfer-engine transition — issue,
    poll, fence, restore and free — so accounting is checked with
    transfers at every stage of flight, not just at rest."""
    pool = eng.pool

    def live_tables():
        return [r.table for r in eng.submitted if r.table is not None]

    audits = {"n": 0}
    for name in ("spill_issue", "spill_poll", "spill_fence",
                 "restore_into", "restore", "free"):
        orig = getattr(pool, name)

        def wrapped(*a, _orig=orig, **kw):
            out = _orig(*a, **kw)
            pool.audit(live_tables())
            audits["n"] += 1
            return out

        setattr(pool, name, wrapped)
    return audits


def _drain(eng):
    res = eng.run_until_done()
    assert all(r.done for r in eng.submitted), "allocation deadlock"
    return res


def _longtail(rng, n, max_len):
    out = []
    for _ in range(n):
        gap = int(rng.integers(0, 4))
        plen = int(rng.integers(3, max_len // 2))
        if rng.random() < 0.5:
            max_new = int(rng.integers(max_len // 2, max_len - plen))
        else:
            max_new = int(rng.integers(1, max(2, max_len // 8)))
        out.append((gap, rng.integers(2, CFG.vocab, size=plen), max_new))
    return out


def _tokens(eng):
    return [r.generated for r in sorted(eng.submitted, key=lambda r: r.rid)]


# ---------------------------------------------------------------------------
# the acceptance property: async == sync == baseline, token for token
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_async_token_identity_randomized(seed):
    """For every randomized oversubscription schedule: the async engine,
    its synchronous twin and an uncontended baseline generate IDENTICAL
    tokens; async never recomputes, never restart-evicts, audits exactly
    at every transfer transition, and drains its pipe at shutdown."""
    rng = np.random.default_rng(seed)
    sched = _longtail(rng, int(rng.integers(3, 7)), 32)
    groups = int(rng.integers(1, 3))
    outs, counters = {}, {}
    for mode, (streams, is_async) in {"async": (1, True),
                                      "sync": (1, False),
                                      "baseline": (8, False)}.items():
        eng = _engine(groups=groups, max_batch=4, pool_streams=streams,
                      async_swap=is_async)
        if is_async:
            audits = _instrument_async(eng)
        eng.open_loop_client(list(sched))
        res = _drain(eng)
        outs[mode] = _tokens(eng)
        counters[mode] = res["counters"]
        assert eng.pool.inflight_tables() == 0, "transfer outlived the run"
        assert eng.pool.occupancy() == 0.0
        assert eng.pool.spilled_tables == 0 and eng.pool.spilled_bytes == 0
        eng.pool.audit([])
    assert outs["async"] == outs["sync"] == outs["baseline"]
    assert counters["async"].get("recompute_tokens", 0) == 0
    assert counters["async"].get("kv_evictions", 0) == 0
    assert counters["baseline"].get("kv_spills", 0) == 0
    # every issue landed exactly once
    assert counters["async"].get("kv_spill_issues", 0) == \
        counters["async"].get("kv_spills", 0)
    if counters["async"].get("kv_spills", 0):
        assert audits["n"] > 0


def test_async_oversubscription_overlap_counters():
    """The dense 1-stream/domain schedule that forces spill cycles: the
    async twin must spill (issue == land), stay token-identical to the
    sync twin, and surface the overlap accounting the benchmark reports
    (ticks-while-in-flight, overlap rounds, priced D2H seconds)."""
    rng = np.random.default_rng(0)
    sched = [(int(rng.integers(0, 2)),
              rng.integers(2, CFG.vocab, size=4), 26) for _ in range(6)]
    runs = {}
    for is_async in (True, False):
        eng = _engine(groups=1, max_batch=4, pool_streams=1,
                      async_swap=is_async)
        eng.open_loop_client(list(sched))
        res = _drain(eng)
        runs[is_async] = (_tokens(eng), res["counters"], eng.kv_stats())
    toks_a, ctr_a, kv_a = runs[True]
    toks_s, ctr_s, kv_s = runs[False]
    assert toks_a == toks_s
    assert ctr_a.get("kv_spills", 0) >= 1
    assert ctr_s.get("kv_spills", 0) >= 1
    assert ctr_a.get("recompute_tokens", 0) == 0
    assert kv_a["async_swap"] and not kv_s["async_swap"]
    assert kv_a["spill_issues"] == kv_a["spills"]
    assert kv_s["spill_issues"] == kv_s["spills"]  # sync = issue + fence
    # gauges are zero at rest; the overlap surface exists either way
    assert kv_a["spill_inflight_pages"] == 0
    assert kv_a["spill_inflight_bytes"] == 0
    for key in ("ticks_while_inflight", "overlap_rounds_per_spill",
                "fence_waits", "d2h_seconds", "h2d_seconds"):
        assert key in kv_a
    assert kv_a["d2h_seconds"] > 0          # priced spill traffic
    # the sync twin never counts a fence wait: its fences are immediate
    # by construction, not stalls
    assert kv_s["fence_waits"] == 0
    assert kv_s["ticks_while_inflight"] == 0


# ---------------------------------------------------------------------------
# fence-before-regrant (pool unit)
# ---------------------------------------------------------------------------

def test_fence_before_regrant_pool_unit():
    """An issued spill keeps the victim's pages until it lands: free
    counts are unchanged at issue, the free callback fires at landing,
    double-issue is refused, and audit passes at every stage."""
    pool = KVBlockPool(CFG, n_domains=1, max_len=32, blocks_per_domain=4,
                       states_per_domain=2)
    t = pool.reserve(0, 40, first_tokens=8)
    pool.grow(t, 1)
    t.used_pages = 2
    frees = []
    pool.on_free(lambda: frees.append(pool.free_blocks(0)))
    free0 = pool.free_blocks(0)
    assert pool.spill_issue(t) == 2
    # in flight: pages retained, nothing re-granted, no callback yet
    assert t.inflight and t.spill is None
    assert len(t.blocks) == 2
    assert pool.free_blocks(0) == free0
    assert pool.inflight_tables() == 1 and pool.inflight_pages() == 2
    assert pool.inflight_bytes() > 0
    assert pool.inflight_domains() == {0}
    assert frees == []
    pool.audit([t])                         # exact WHILE in flight
    assert pool.spill_issue(t) == 0         # never double-issue
    assert pool.spill_issue(t) == 0
    pool.audit([t])
    # the fence lands it: pages re-granted, callback fired exactly now
    pool.spill_fence(t)
    assert not t.inflight and t.spill is not None
    assert t.blocks == [] and pool.free_blocks(0) == free0 + 2
    assert pool.inflight_tables() == 0
    assert len(frees) == 1
    pool.audit([t])
    snap = pool.counters.totals
    assert snap.get("kv_spill_issues", 0) == 1
    assert snap.get("kv_spills", 0) == 1
    assert pool.restore(t)
    pool.audit([t])
    pool.free(t)
    pool.audit([])


def test_poll_lands_ready_transfers():
    """``spill_poll`` (the per-round poll phase) lands a completed
    transfer without a blocking fence, and the overlap clock counts the
    rounds between issue and landing."""
    pool = KVBlockPool(CFG, n_domains=2, max_len=32, blocks_per_domain=2,
                       states_per_domain=2)
    t = pool.reserve(0, 40, first_tokens=8)
    pool.grow(t, 1)
    t.used_pages = 2
    assert pool.spill_issue(t) == 2
    for leaf in pool._inflight[0].leaves:   # CPU: force completion so the
        if leaf is not None:                # poll observes ready arrays
            leaf.block_until_ready()
    landed = pool.spill_poll()
    assert landed == 1
    assert not t.inflight and t.spill is not None
    assert pool.counters.totals.get("kv_fence_waits", 0) == 0
    pool.audit([t])
    pool.free(t)
    pool.audit([])


def test_migrate_and_free_fence_inflight_first():
    """The relayout/steal path (``migrate``) and the release path
    (``free``) must drain a table's transfer before acting — a re-point
    or a free with bytes on the wire would corrupt the payload."""
    pool = KVBlockPool(CFG, n_domains=2, max_len=32, blocks_per_domain=2,
                       states_per_domain=2)
    t = pool.reserve(0, 40, first_tokens=8)
    pool.grow(t, 1)
    t.used_pages = 2
    assert pool.spill_issue(t) == 2
    assert pool.migrate(t, 1)               # fences, lands, then re-points
    assert not t.inflight and t.spill is not None and t.domain == 1
    assert pool.inflight_tables() == 0
    assert pool.restore(t)
    assert t.domain == 1 and len(t.blocks) == 2
    pool.audit([t])
    # free() with a transfer outstanding: fence first, then release
    t2 = pool.reserve(0, 40, first_tokens=8)
    pool.grow(t2, 1)
    t2.used_pages = 2
    assert pool.spill_issue(t2) == 2
    pool.free(t2)
    assert pool.inflight_tables() == 0
    pool.free(t)
    pool.audit([])


def test_grow_refused_while_inflight():
    """An in-flight victim is FROZEN: grow is refused (the stream parks
    and retries after the landing) instead of mutating pages whose bytes
    are mid-copy."""
    pool = KVBlockPool(CFG, n_domains=1, max_len=32, blocks_per_domain=4,
                       states_per_domain=2)
    t = pool.reserve(0, 40, first_tokens=8)
    t.used_pages = 1
    assert pool.spill_issue(t) == 1
    gf0 = pool.counters.totals.get("kv_grow_failures", 0)
    assert not pool.grow(t, 1)
    assert pool.counters.totals.get("kv_grow_failures", 0) == gf0 + 1
    pool.spill_fence(t)
    pool.audit([t])
    pool.free(t)
    pool.audit([])


# ---------------------------------------------------------------------------
# atomic restore_into (the PR-10 sweep-leg regression)
# ---------------------------------------------------------------------------

def test_restore_into_failed_leg_has_zero_side_effects():
    """A sweep leg that cannot fit pages + growth must leave the table
    EXACTLY as it found it: domain un-repointed, spill intact, free lists
    untouched — the old sweep re-pointed, restored, then grew in separate
    steps and a failed grow stranded the stream."""
    pool = KVBlockPool(CFG, n_domains=2, max_len=32, blocks_per_domain=4,
                       states_per_domain=2)
    t = pool.reserve(0, 40, first_tokens=8)
    pool.grow(t, 1)
    t.used_pages = 2
    assert pool.spill(t) == 2
    # starve domain 1: leave only 1 free block (< the 2 pages needed)
    eat1 = pool.reserve(1, 40, first_tokens=32)
    eat2 = pool.reserve(1, 8, first_tokens=8)
    assert pool.free_blocks(1) == 1
    free0, free1 = pool.free_blocks(0), pool.free_blocks(1)
    assert not pool.restore_into(t, 1)
    # ZERO side effects on the failed leg
    assert t.domain == 0 and t.spill is not None and t.blocks == []
    assert pool.free_blocks(0) == free0 and pool.free_blocks(1) == free1
    pool.audit([t, eat1, eat2])
    # the next leg (home domain) succeeds atomically, growth clamped to
    # the table's page cap
    assert pool.restore_into(t, 0, grow_by=1)
    assert t.domain == 0 and t.spill is None
    assert len(t.blocks) == 2 and t.used_pages == 2   # cap_pages == 2
    pool.audit([t, eat1, eat2])
    for x in (t, eat1, eat2):
        pool.free(x)
    pool.audit([])


def test_restore_into_state_slot_not_leaked_on_failed_leg():
    """Hybrid models: a failed sweep leg must not consume the spilled
    STATE checkpoint or a destination state slot (the leak the audit
    regression guards)."""
    cfg = reduced_config(REGISTRY["recurrentgemma-9b"])
    pool = KVBlockPool(cfg, n_domains=2, max_len=32, blocks_per_domain=4,
                       states_per_domain=1)
    assert pool.has_state
    t = pool.reserve(0, 40, first_tokens=8)
    if pool.pages_per_stream:
        t.used_pages = len(t.blocks)
    assert pool.spill(t) >= 0
    assert t.spill is not None and t.spill.had_state
    # exhaust domain 1's single state slot
    eater = pool.reserve(1, 8, first_tokens=8)
    assert not pool.state_available(1)
    assert not pool.restore_into(t, 1)
    assert t.domain == 0 and t.spill is not None
    assert t.spill.had_state, "state checkpoint consumed by failed leg"
    pool.audit([t, eater])
    assert pool.restore_into(t, 0)
    assert t.state_slot and t.domain == 0
    pool.audit([t, eater])
    pool.free(t)
    pool.free(eater)
    pool.audit([])


def test_restore_prefetch_stages_h2d_and_preserves_bytes():
    """``restore_prefetch`` stages the spilled payload device-side while
    the stream waits in line; the eventual restore reads the staged
    arrays and the bytes survive bit-exact."""
    pool = KVBlockPool(CFG, n_domains=1, max_len=32, blocks_per_domain=4,
                       states_per_domain=2)
    t = pool.reserve(0, 40, first_tokens=8)
    pool.grow(t, 1)
    t.used_pages = 2
    new = []
    for leaf, s in zip(jax.tree.leaves(pool.storage), pool.spec.leaves):
        ax = s.batch_axis
        idx = (slice(None),) * ax
        if s.token_axis is not None and t.blocks:
            leaf = leaf.at[idx + (jnp.asarray(t.blocks),)].set(3.25)
        new.append(leaf)
    pool.storage = jax.tree.unflatten(pool.spec.treedef, new)
    assert pool.spill(t) == 2
    assert pool.restore_prefetch(t)
    assert t.spill.staged is not None
    assert not pool.restore_prefetch(t)     # idempotent
    assert pool.counters.totals.get("kv_restore_prefetches", 0) == 1
    assert pool.restore(t)
    for leaf, s in zip(jax.tree.leaves(pool.storage), pool.spec.leaves):
        if s.token_axis is not None and t.blocks:
            vals = jnp.take(leaf, jnp.asarray(t.blocks), axis=s.batch_axis)
            assert jnp.all(vals == 3.25), "staged restore lost bytes"
    pool.audit([t])
    pool.free(t)
    pool.audit([])


# ---------------------------------------------------------------------------
# engine-level: park + drain with a transfer outstanding
# ---------------------------------------------------------------------------

def test_engine_park_while_transfer_outstanding_drains():
    """Drive the 2-stream deadlock by hand on an async engine: the ladder
    ISSUES the victim's spill (pages retained, line head still parked),
    and the run then drains token-identically — landings, not issues,
    re-grant the pages."""
    rng = np.random.default_rng(5)
    prompts = [rng.integers(2, CFG.vocab, size=4) for _ in range(2)]
    eng = _engine(groups=1, max_batch=2, pool_streams=1, async_swap=True)
    reqs = [eng.submit(p, max_new=26) for p in prompts]
    eng._running = True
    for g in eng.groups:
        eng._spawn_group(g)
    rounds = 0
    while len(eng._parked) < 2 and rounds < 500:
        eng.sched.tick()
        rounds += 1
    assert len(eng._parked) == 2, "deadlock scenario failed to form"
    free0 = sum(eng.pool.free_blocks(d)
                for d in range(eng.pool.n_domains))
    assert eng._spill_parked(domain=None)
    # issued, not landed: fence-before-regrant at the engine level
    assert eng.pool.inflight_tables() == 1
    victim = [r for r in eng._parked.values() if r.req.table.inflight]
    assert len(victim) == 1 and victim[0].req.table.spill is None
    assert sum(eng.pool.free_blocks(d)
               for d in range(eng.pool.n_domains)) == free0
    eng.pool.audit([r.table for r in eng.submitted if r.table is not None])
    # a second ladder fire with the pipe busy must not double-spill the
    # same table (its candidate filter excludes in-flight victims)
    assert victim[0].req.table.spill is None
    eng.sched.run_until_done(max_rounds=100000,
                             round_hook=eng._stall_hook)
    eng._running = False
    eng.pool.drain()
    assert all(r.done for r in eng.submitted)
    assert eng.pool.inflight_tables() == 0
    assert eng.pool.occupancy() == 0.0 and eng.pool.spilled_tables == 0
    base = _engine(groups=1, max_batch=2, pool_streams=8)
    base_reqs = [base.submit(p, max_new=26) for p in prompts]
    _drain(base)
    assert [r.generated for r in reqs] == \
        [r.generated for r in base_reqs]


def test_sync_spill_unchanged_by_default():
    """``async_swap`` defaults OFF and the default engine's spill path is
    the PR-4 synchronous one: ``pool.spill`` still fires (spy-visible),
    with no issue left unfenced at any point."""
    eng = _engine(groups=1, max_batch=2, pool_streams=1)
    assert not eng._async and not eng.ecfg.async_swap
    calls = []
    orig = eng.pool.spill

    def spy(table, _o=orig):
        out = _o(table)
        calls.append(out)
        assert eng.pool.inflight_tables() == 0
        return out

    eng.pool.spill = spy
    rng = np.random.default_rng(5)
    for p in [rng.integers(2, CFG.vocab, size=4) for _ in range(2)]:
        eng.submit(p, max_new=26)
    _drain(eng)
    assert calls, "the deadlock schedule never spilled"
    assert eng.counters.totals.get("kv_fence_waits", 0) == 0
