"""Prefix-sharing copy-on-write KV pages (ISSUE 7).

The acceptance property: for randomized shared-preamble workloads — across
spill/restore pressure, restart eviction and forced relayouts of
refcount>1 tables — a sharing-enabled engine generates tokens IDENTICAL to
the unshared run, while the pool's refcount/prefix-index/checkpoint
accounting audits clean after every refcounted operation.

Deterministic companions pin the mechanisms one by one: the hash-chain
index lifecycle (publish -> match -> attach -> CoW -> cached retention ->
reuse), the satellite bugfix (a fully-cached prompt charges only its
unshared tail, so it admits when the pool has almost nothing free), ring-
wrap CoW forks, hybrid-model state checkpoints, and the audit actually
catching refcount corruption.
"""
import numpy as np
import pytest

from conftest import hypothesis_tools
from repro.configs import REGISTRY, reduced_config
from repro.core.topology import ChipletTopology
from repro.serving.engine import EngineConfig, ServeEngine
from repro.serving.kvpool import KVBlockPool

given, settings, st = hypothesis_tools()

CFG = reduced_config(REGISTRY["llama3-8b"])
HYB = reduced_config(REGISTRY["recurrentgemma-9b"])


def _engine(cfg=CFG, *, groups=1, max_batch=2, max_len=48, pool_streams=2,
            share=True, evict_mode="swap", adaptive=False, **ecfg_kw):
    topo = ChipletTopology(n_pods=1, groups_per_pod=groups,
                           chips_per_group=1)
    ecfg = EngineConfig(max_batch=max_batch, max_len=max_len, paged=True,
                        lazy=True, pool_streams=pool_streams,
                        adaptive=adaptive, evict_mode=evict_mode,
                        prefix_share=share, **ecfg_kw)
    return ServeEngine(cfg, topo, ecfg, spread_rate=1, seed=0)


def _instrument(eng):
    """Audit the pool's refcount/index/checkpoint accounting after EVERY
    refcounted operation the engine can trigger."""
    pool = eng.pool

    def live_tables():
        return [r.table for r in eng.submitted if r.table is not None]

    from repro.serving.kvpool import KVTable

    for name in ("reserve", "grow", "free", "spill", "restore", "migrate",
                 "cow_fork", "register_prefix", "note_writes"):
        orig = getattr(pool, name)

        def wrapped(*a, _orig=orig, **kw):
            out = _orig(*a, **kw)
            extra = [out] if isinstance(out, KVTable) else []
            pool.audit(live_tables() + extra)     # a fresh reservation is
            return out                            # not yet on its Request

        setattr(pool, name, wrapped)


def _drain(eng):
    res = eng.run_until_done()
    assert all(r.done for r in eng.submitted), "allocation deadlock"
    return res


def _preamble_prompts(rng, n, pre_len, tail_max):
    """n prompts sharing a ``pre_len``-token preamble with random tails —
    the multi-tenant system-prompt workload prefix caching exists for."""
    pre = rng.integers(2, CFG.vocab, size=pre_len)
    return [np.concatenate([pre, rng.integers(2, CFG.vocab,
                                              size=int(rng.integers(1, tail_max)))])
            for _ in range(n)]


# ---------------------------------------------------------------------------
# the acceptance property (randomized shared-preamble schedules)
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10**6),
       evict_mode=st.sampled_from(("swap", "restart")))
def test_token_identity_sharing_property(seed, evict_mode):
    """Sharing on vs off over an OVERSUBSCRIBED pool (spills/evictions
    and mid-decode parks fire) with shared-preamble arrivals over time:
    identical tokens, clean audits throughout, pool drains to zero."""
    rng = np.random.default_rng(seed)
    prompts = _preamble_prompts(rng, 8, 2 * 16, 12)
    sched = [(int(rng.integers(0, 5)), p, int(rng.integers(2, 10)))
             for p in prompts]

    def run(share):
        eng = _engine(groups=1, max_batch=2, max_len=64, pool_streams=2,
                      share=share, evict_mode=evict_mode)
        _instrument(eng)
        eng.open_loop_client(iter(sched))
        _drain(eng)
        eng.pool.audit([])
        assert eng.pool.occupancy() == 0.0
        return [r.generated for r in eng.submitted], eng.kv_stats()

    gen_on, s_on = run(True)
    gen_off, s_off = run(False)
    assert gen_on == gen_off
    assert s_off["prefix_hits"] == 0


# ---------------------------------------------------------------------------
# the index lifecycle, pinned (pool-level)
# ---------------------------------------------------------------------------

def test_pool_prefix_index_lifecycle():
    """publish -> match -> refcounted attach -> free -> cached retention
    -> cached reuse, auditing at every step."""
    pool = KVBlockPool(CFG, n_domains=2, max_len=32, blocks_per_domain=4,
                       states_per_domain=4, block_tokens=16)
    bt = pool.block_tokens
    rng = np.random.default_rng(0)
    prompt = rng.integers(2, CFG.vocab, size=2 * bt + 3)
    keys = pool.prefix_keys(prompt)
    assert len(keys) == 2

    t1 = pool.reserve(0, len(prompt) + 8, first_tokens=len(prompt))
    pool.audit([t1])
    # nothing published yet: no match
    assert pool.match_prefix(0, keys, prompt_len=len(prompt)) == ([], 0)
    pool.register_prefix(t1, keys, 0, 2 * bt, len(prompt))
    pool.audit([t1])
    blocks, ckpt = pool.match_prefix(0, keys, prompt_len=len(prompt))
    assert blocks == t1.blocks[:2] and ckpt == 0
    # wrong domain: no match
    assert pool.match_prefix(1, keys, prompt_len=len(prompt)) == ([], 0)

    t2 = pool.reserve(0, len(prompt) + 8, first_tokens=len(prompt),
                      prefix_blocks=blocks)
    pool.audit([t1, t2])
    assert t2.blocks[:2] == t1.blocks[:2]
    assert t2.used_pages == 2
    assert pool.shared_pages() == 2 and pool.shared_extra_refs() == 2
    assert pool.stats()["logical_kv_bytes"] > pool.stats()["resident_kv_bytes"]

    # a write into a shared page must be forked first
    page = pool.fork_pages(t2, 0, bt)
    assert page == [0]
    assert pool.cow_fork(t2, 0)
    pool.audit([t1, t2])
    assert t2.blocks[0] != t1.blocks[0]
    pool.note_writes(t2, 0, bt)
    pool.audit([t1, t2])
    # t1's entry survives the fork (the OLD block keeps it)
    assert pool.match_prefix(0, keys,
                             prompt_len=len(prompt))[0] == t1.blocks[:2]

    pool.free(t2)
    pool.audit([t1])
    pool.free(t1)
    pool.audit([])
    assert pool.occupancy() == 0.0
    # cached retention: freed-but-indexed pages still match and re-attach
    assert pool.cached_pages() >= 2
    blocks, _ = pool.match_prefix(0, keys, prompt_len=len(prompt))
    assert len(blocks) == 2
    t3 = pool.reserve(0, len(prompt) + 8, first_tokens=len(prompt),
                      prefix_blocks=blocks)
    pool.audit([t3])
    assert t3.blocks[:2] == blocks
    pool.free(t3)
    pool.audit([])


def test_match_always_leaves_tail_to_recompute():
    """Even a prompt whose every page is published matches at most
    (S-1)//bt pages: the final prompt token must run through the model to
    seed generation."""
    pool = KVBlockPool(CFG, n_domains=1, max_len=32, blocks_per_domain=4,
                       states_per_domain=4, block_tokens=16)
    bt = pool.block_tokens
    rng = np.random.default_rng(1)
    prompt = rng.integers(2, CFG.vocab, size=2 * bt)   # page-aligned
    keys = pool.prefix_keys(prompt)
    t1 = pool.reserve(0, len(prompt) + 4, first_tokens=len(prompt))
    pool.register_prefix(t1, keys, 0, len(prompt), len(prompt))
    blocks, _ = pool.match_prefix(0, keys, prompt_len=len(prompt))
    assert len(blocks) == 1                    # not 2: the tail recomputes
    pool.free(t1)
    pool.audit([])


def test_spill_restore_of_shared_pages():
    """Spilling a table whose pages are refcount>1 copies the payload and
    releases the refs; the restore is private; the survivor still matches."""
    pool = KVBlockPool(CFG, n_domains=1, max_len=32, blocks_per_domain=6,
                       states_per_domain=6, block_tokens=16)
    bt = pool.block_tokens
    rng = np.random.default_rng(2)
    prompt = rng.integers(2, CFG.vocab, size=2 * bt + 2)
    keys = pool.prefix_keys(prompt)
    t1 = pool.reserve(0, len(prompt) + 8, first_tokens=len(prompt))
    pool.register_prefix(t1, keys, 0, 2 * bt, len(prompt))
    blocks, _ = pool.match_prefix(0, keys, prompt_len=len(prompt))
    t2 = pool.reserve(0, len(prompt) + 8, first_tokens=len(prompt),
                      prefix_blocks=blocks)
    t2.used_pages = len(t2.blocks)
    pool.audit([t1, t2])
    assert pool.spill(t2)
    pool.audit([t1, t2])
    assert pool.shared_pages() == 0            # refs released by the spill
    assert pool.restore(t2)
    pool.audit([t1, t2])
    assert not set(t2.blocks[:2]) & set(t1.blocks[:2])   # private now
    assert pool.match_prefix(0, keys,
                             prompt_len=len(prompt))[0] == t1.blocks[:2]
    pool.free(t1)
    pool.free(t2)
    pool.audit([])


def test_migrate_privatizes_shared_table():
    """Relayout/steal of a refcount>1 table: the cross-domain copy makes
    the moved table private; the donor keeps its pages and index entry."""
    pool = KVBlockPool(CFG, n_domains=2, max_len=32, blocks_per_domain=4,
                       states_per_domain=4, block_tokens=16)
    bt = pool.block_tokens
    rng = np.random.default_rng(3)
    prompt = rng.integers(2, CFG.vocab, size=bt + 2)
    keys = pool.prefix_keys(prompt)
    t1 = pool.reserve(0, len(prompt) + 8, first_tokens=len(prompt))
    pool.register_prefix(t1, keys, 0, bt, len(prompt))
    blocks, _ = pool.match_prefix(0, keys, prompt_len=len(prompt))
    t2 = pool.reserve(0, len(prompt) + 8, first_tokens=len(prompt),
                      prefix_blocks=blocks)
    t2.used_pages = len(t2.blocks)
    pool.audit([t1, t2])
    assert pool.migrate(t2, 1)
    pool.audit([t1, t2])
    assert t2.domain == 1 and pool.shared_pages() == 0
    assert pool.match_prefix(0, keys,
                             prompt_len=len(prompt))[0] == t1.blocks[:1]
    pool.free(t1)
    pool.free(t2)
    pool.audit([])


def test_audit_catches_refcount_and_index_corruption():
    pool = KVBlockPool(CFG, n_domains=1, max_len=32, blocks_per_domain=4,
                       states_per_domain=4, block_tokens=16)
    t1 = pool.reserve(0, 20, first_tokens=20)
    pool.audit([t1])
    b = t1.blocks[0]
    pool._ref[b] += 1
    with pytest.raises(AssertionError):
        pool.audit([t1])
    pool._ref[b] -= 1
    pool.audit([t1])
    pool._entry_of_block[b] = b"bogus"
    with pytest.raises(AssertionError):
        pool.audit([t1])
    del pool._entry_of_block[b]
    pool.free(t1)
    pool.audit([])


# ---------------------------------------------------------------------------
# the satellite bugfix: cached prompts admit at high occupancy
# ---------------------------------------------------------------------------

def test_fully_cached_prompt_admits_when_pool_is_tight():
    """``reserve(first_tokens=)`` charges only the UNSHARED pages: a
    prompt whose prefix is fully resident admits even when the domain has
    just one free block left for the tail."""
    pool = KVBlockPool(CFG, n_domains=1, max_len=32, blocks_per_domain=3,
                       states_per_domain=4, block_tokens=16)
    bt = pool.block_tokens
    rng = np.random.default_rng(4)
    prompt = rng.integers(2, CFG.vocab, size=bt + 4)
    keys = pool.prefix_keys(prompt)
    t1 = pool.reserve(0, len(prompt) + 8, first_tokens=len(prompt))
    pool.register_prefix(t1, keys, 0, bt, len(prompt))
    blocks, _ = pool.match_prefix(0, keys, prompt_len=len(prompt))
    # 1 of 3 blocks free: an unshared 2-page first chunk cannot fit ...
    assert pool.free_blocks(0) == 1
    assert pool.reserve(0, len(prompt) + 8,
                        first_tokens=len(prompt)) is None
    # ... but the cached-prefix admission charges only the tail page
    t2 = pool.reserve(0, len(prompt) + 8, first_tokens=len(prompt),
                      prefix_blocks=blocks)
    assert t2 is not None and len(t2.blocks) == 2
    pool.audit([t1, t2])
    pool.free(t1)
    pool.free(t2)
    pool.audit([])


def test_cached_attach_charges_the_free_list():
    """CACHED prefix hits sit ON the free list, and attaching pulls them
    off: a reservation whose unshared tail doesn't fit beyond them must
    be refused cleanly — not drain the list and crash ``_pop_block``
    (found by the open-loop benchmark under restart-eviction churn)."""
    pool = KVBlockPool(CFG, n_domains=1, max_len=32, blocks_per_domain=2,
                       states_per_domain=4, block_tokens=16)
    bt = pool.block_tokens
    rng = np.random.default_rng(10)
    prompt = rng.integers(2, CFG.vocab, size=bt + 4)
    keys = pool.prefix_keys(prompt)
    t1 = pool.reserve(0, len(prompt) + 8, first_tokens=len(prompt))
    pool.register_prefix(t1, keys, 0, bt, len(prompt))
    pool.free(t1)                       # both blocks free, one cached
    t2 = pool.reserve(0, bt, first_tokens=bt)   # takes the UNCACHED one
    blocks, _ = pool.match_prefix(0, keys, prompt_len=len(prompt))
    assert len(blocks) == 1
    # 1 free block == the cached hit itself: no room for the tail page
    assert pool.free_blocks(0) == 1
    assert pool.reserve(0, len(prompt) + 8, first_tokens=len(prompt),
                        prefix_blocks=blocks) is None
    pool.audit([t2])
    pool.free(t2)                       # tail fits now: same match admits
    t3 = pool.reserve(0, len(prompt) + 8, first_tokens=len(prompt),
                      prefix_blocks=blocks)
    assert t3 is not None and t3.blocks[0] == blocks[0]
    pool.audit([t3])
    pool.free(t3)
    pool.audit([])


# ---------------------------------------------------------------------------
# engine-level mechanisms
# ---------------------------------------------------------------------------

def test_second_wave_skips_prefill_and_matches_tokens():
    """Wave 2 of a shared-preamble workload attaches the CACHED pages of
    wave 1 and skips their prefill chunks; tokens match the unshared
    engine exactly."""
    rng = np.random.default_rng(5)
    pre = rng.integers(2, CFG.vocab, size=32)
    prompts = [np.concatenate([pre, rng.integers(2, CFG.vocab, size=7)])
               for _ in range(4)]

    eng = _engine(groups=1, max_batch=2, max_len=64, pool_streams=4)
    _instrument(eng)
    w1 = [eng.submit(p, 4) for p in prompts[:2]]
    _drain(eng)
    c0 = eng.counters.totals.get("prefill_chunks", 0)
    w2 = [eng.submit(p, 4) for p in prompts[2:]]
    _drain(eng)
    s = eng.kv_stats()
    # each wave-2 request matched both preamble pages (32 tokens)
    assert s["prefill_tokens_skipped"] >= 2 * 32
    assert s["prefix_hits"] >= 2
    # wave 2 ran only tail chunks: 1 per request, not 3
    assert eng.counters.totals["prefill_chunks"] - c0 <= 2
    eng.pool.audit([])
    assert eng.pool.occupancy() == 0.0

    ref = _engine(groups=1, max_batch=2, max_len=64, pool_streams=4,
                  share=False)
    q = [ref.submit(p, 4) for p in prompts]
    _drain(ref)
    assert ([r.generated for r in w1 + w2]
            == [r.generated for r in ref.submitted])
    assert ref.kv_stats()["prefix_hits"] == 0


def test_ring_wrap_cow_forks_keep_identity():
    """Streams decoding past the ring width W wrap onto their shared
    prefix pages: the write must CoW-fork them, and tokens stay identical
    to the unshared engine."""
    def run(share):
        eng = _engine(groups=1, max_batch=2, max_len=64, pool_streams=4,
                      share=share)
        _instrument(eng)
        W = eng.pool.pages_per_stream * eng.pool.block_tokens
        rng = np.random.default_rng(6)
        # one-page preamble: prefill never wraps (which would invalidate
        # the published page); only the deep decode below wraps onto it
        pre = rng.integers(2, CFG.vocab, size=eng.pool.block_tokens)
        prompts = [np.concatenate([pre, rng.integers(2, CFG.vocab, size=3)])
                   for _ in range(3)]
        # decode far enough that pos crosses W: wrap writes land on page 0
        max_new = W - len(prompts[0]) + eng.pool.block_tokens
        eng.submit(prompts[0], 4)
        _drain(eng)
        for p in prompts[1:]:
            eng.submit(p, max_new)
        _drain(eng)
        eng.pool.audit([])
        assert eng.pool.occupancy() == 0.0
        return [r.generated for r in eng.submitted], eng.kv_stats()

    gen_on, s_on = run(True)
    gen_off, s_off = run(False)
    assert gen_on == gen_off
    assert s_on["prefix_hits"] >= 2
    assert s_on["cow_forks"] >= 1          # the wrap hit a shared page
    assert s_off["cow_forks"] == 0


def test_relayout_of_shared_tables_keeps_identity():
    """Adaptive relayouts while refcount>1 tables are in flight (rebalance
    copies privatize them) vs the non-adaptive run: identical tokens."""
    from repro.core.controller import ControllerConfig
    rng = np.random.default_rng(7)
    prompts = _preamble_prompts(rng, 12, 16, 8)
    max_new = [2 if i % 4 == 0 else 8 for i in range(12)]

    def run(adaptive):
        eng = _engine(groups=4, max_batch=1, max_len=48, pool_streams=4,
                      adaptive=adaptive,
                      controller=ControllerConfig(scheduler_timer=3,
                                                  threshold=1.0,
                                                  min_dwell=1))
        _instrument(eng)
        reqs = [eng.submit(p, m) for p, m in zip(prompts, max_new)]
        res = _drain(eng)
        eng.pool.audit([])
        return [r.generated for r in reqs], res

    gen_a, res_a = run(True)
    assert len(res_a["relayouts"]) >= 1
    gen_b, res_b = run(False)
    assert res_b["relayouts"] == []
    assert gen_a == gen_b


def test_hybrid_state_checkpoint_enables_hits():
    """recurrentgemma (ring + rgLRU state): a prefix hit needs a state
    CHECKPOINT at the match boundary — position-dependent state cannot be
    shared in place.  Wave 2 hits via the checkpoint and tokens match the
    unshared engine.  The one-page preamble keeps the whole stream inside
    the ring width (a wrap would invalidate the published page)."""
    rng = np.random.default_rng(8)
    pre = rng.integers(2, HYB.vocab, size=16)
    prompts = [np.concatenate([pre, rng.integers(2, HYB.vocab, size=5)])
               for _ in range(3)]

    def run(share):
        eng = _engine(HYB, groups=1, max_batch=2, max_len=64,
                      pool_streams=4, share=share)
        _instrument(eng)
        eng.submit(prompts[0], 3)
        _drain(eng)
        for p in prompts[1:]:
            eng.submit(p, 3)
        _drain(eng)
        eng.pool.audit([])
        assert eng.pool.occupancy() == 0.0
        return [r.generated for r in eng.submitted], eng.kv_stats()

    gen_on, s_on = run(True)
    gen_off, s_off = run(False)
    assert gen_on == gen_off
    assert s_on["prefix_hits"] >= 1
    assert s_on["prefill_tokens_skipped"] > 0
    assert s_off["prefix_hits"] == 0


def test_oversubscribed_restart_converges_via_cached_prefixes():
    """Deep oversubscription under restart eviction, where the prompts
    need nearly the whole domain: the UNSHARED engine thrashes (the
    baseline restart livelock — every re-admission recomputes the full
    prompt and deadlocks again), while sharing lets each re-admission
    attach the victim's own cached pages and skip straight past the
    recomputation — the workload converges, token-identical to an
    uncontended unshared run."""
    rng = np.random.default_rng(9)
    prompts = _preamble_prompts(rng, 6, 32, 8)
    sched = [(1, p, 12) for p in prompts]

    eng = _engine(groups=1, max_batch=2, max_len=64, pool_streams=1,
                  share=True, evict_mode="restart", stall_evict_rounds=3)
    _instrument(eng)
    eng.open_loop_client(iter(list(sched)))
    _drain(eng)
    eng.pool.audit([])
    assert eng.pool.occupancy() == 0.0
    s = eng.kv_stats()
    assert s["prefix_hits"] >= 1
    assert s["evictions"] >= 1              # pressure actually fired

    ref = _engine(groups=1, max_batch=2, max_len=64, pool_streams=4,
                  share=False)
    for _, p, m in sched:
        ref.submit(p, m)
    _drain(ref)
    assert ([r.generated for r in eng.submitted]
            == [r.generated for r in ref.submitted])
