"""Substrate tests: optimizer (f32 + 8-bit), data pipeline determinism,
checkpoint roundtrip + elastic reshard, compression error feedback,
failure/straggler handling, serving engine invariants."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_inputs
from repro.configs import REGISTRY, reduced_config
from repro.checkpoint.manager import CheckpointManager, load_pytree, save_pytree
from repro.compression.grad_compress import (init_compression,
                                             int8_compress_transform,
                                             topk_compress_transform)
from repro.core.topology import ChipletTopology
from repro.data.pipeline import (ShardedLoader, SyntheticCorpus, make_batch,
                                 write_corpus_shards)
from repro.models import params as P
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state, \
    lr_schedule
from repro.optim.quantized import adamw8bit_update, init_opt_state_8bit
from repro.runtime.elastic import degraded_mesh, rebatch_for
from repro.runtime.failure import StragglerDetector

KEY = jax.random.PRNGKey(5)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _quad_problem():
    """min ||Wx - y||^2: AdamW should drive the loss down fast."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    W_true = jax.random.normal(k1, (16, 8))
    X = jax.random.normal(k2, (64, 16))
    Y = X @ W_true
    params = {"w": jax.random.normal(k3, (16, 8)) * 0.1}
    loss = lambda p: jnp.mean((X @ p["w"] - Y) ** 2)
    return params, loss


def test_adamw_reduces_loss():
    params, loss = _quad_problem()
    state = init_opt_state(params)
    cfg = AdamWConfig(peak_lr=0.05, warmup_steps=5, total_steps=200,
                      weight_decay=0.0)
    l0 = float(loss(params))
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 0.05 * l0


def test_adamw8bit_tracks_fp32():
    """8-bit AdamW trajectory stays close to f32 AdamW."""
    params, loss = _quad_problem()
    p32, p8 = params, params
    s32 = init_opt_state(params)
    s8 = init_opt_state_8bit(params)
    cfg = AdamWConfig(peak_lr=0.05, warmup_steps=5, total_steps=200,
                      weight_decay=0.0)
    for _ in range(60):
        g32 = jax.grad(loss)(p32)
        g8 = jax.grad(loss)(p8)
        p32, s32, _ = adamw_update(g32, s32, p32, cfg)
        p8, s8, _ = adamw8bit_update(g8, s8, p8, cfg)
    l32, l8 = float(loss(p32)), float(loss(p8))
    assert l8 < 0.15 * float(loss(params))       # converges
    assert l8 < max(4.0 * l32, 0.02)             # close to fp32 quality
    # moments really are 8-bit
    assert s8["m"]["w"]["q"].dtype == jnp.int8
    assert s8["v"]["w"]["q"].dtype == jnp.uint8


def test_lr_schedule_shape():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(lr_schedule(cfg, jnp.int32(0))) == 0.0
    assert float(lr_schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(lr_schedule(cfg, jnp.int32(100))) == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_corpus_deterministic(tmp_path):
    c1 = SyntheticCorpus(1000, seed=7)
    c2 = SyntheticCorpus(1000, seed=7)
    np.testing.assert_array_equal(c1.shard_tokens(3, 1000),
                                  c2.shard_tokens(3, 1000))
    assert not np.array_equal(c1.shard_tokens(3, 1000),
                              c1.shard_tokens(4, 1000))


def test_loader_sharding_and_resume(tmp_path):
    corpus = SyntheticCorpus(512, seed=1)
    files = write_corpus_shards(str(tmp_path), corpus, n_shards=4,
                                tokens_per_shard=4000)
    l_all = ShardedLoader(files, seq_len=16, batch=2)
    b1 = l_all.next()
    b2 = l_all.next()
    assert b1.shape == (2, 17)
    assert not np.array_equal(b1, b2)
    # resume from state: same position -> same next block
    state = l_all.state_dict()
    b3 = l_all.next()
    l_resumed = ShardedLoader(files, seq_len=16, batch=2)
    l_resumed.load_state_dict(state)
    np.testing.assert_array_equal(b3, l_resumed.next())
    # host sharding: different hosts read disjoint shards
    h0 = ShardedLoader(files, host=0, n_hosts=2, seq_len=16, batch=2)
    h1 = ShardedLoader(files, host=1, n_hosts=2, seq_len=16, batch=2)
    assert not np.array_equal(h0.next(), h1.next())


def test_make_batch_families(key=KEY):
    for name in ("llama3-8b", "qwen2-vl-2b", "seamless-m4t-large-v2"):
        cfg = reduced_config(REGISTRY[name])
        block = np.random.default_rng(0).integers(
            0, cfg.vocab, size=(2, 33)).astype(np.int32)
        b = make_batch(cfg, block)
        assert b["tokens"].dtype == np.int32
        assert b["targets"].shape == b["mask"].shape


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16)}}
    save_pytree(str(tmp_path / "ck"), tree, metadata={"step": 3})
    like = jax.tree.map(jnp.zeros_like, tree)
    out, meta = load_pytree(str(tmp_path / "ck"), like)
    assert meta["step"] == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_manager_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        mgr.save(s, {"x": jnp.full((2,), s)})
    assert mgr.latest() == 3
    assert mgr.steps() == [2, 3]          # gc dropped step 1
    out, meta = mgr.restore({"x": jnp.zeros((2,))})
    assert meta["step"] == 3


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(1, {"x": jnp.ones((4,))}, blocking=False)
    mgr.wait()
    assert mgr.latest() == 1


def test_elastic_reshard_on_load(tmp_path):
    """Checkpoint saved replicated restores onto any target sharding."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as Pc
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_pytree(str(tmp_path / "ck"), tree)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    shd = {"w": NamedSharding(mesh, Pc(None, "model"))}
    out, _ = load_pytree(str(tmp_path / "ck"), tree, shardings=shd)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert out["w"].sharding == shd["w"]


def test_degraded_mesh_and_rebatch():
    mesh, kept = degraded_mesh((1, 1), failed_rows=[])
    assert mesh.shape["data"] == 1
    assert rebatch_for(256, 15) == 255
    assert rebatch_for(7, 8) == 8


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_error_feedback_unbiased():
    """With EF, the accumulated compressed signal converges to the truth."""
    g_true = {"w": jnp.array([[0.3, -0.001, 0.7, 0.0002]] * 2)}
    ef = init_compression(g_true)["ef"]
    acc = jnp.zeros_like(g_true["w"])
    for _ in range(50):
        gq, ef = int8_compress_transform(g_true, ef)
        acc = acc + gq["w"]
    np.testing.assert_allclose(np.asarray(acc / 50),
                               np.asarray(g_true["w"]), rtol=0.02, atol=1e-4)


def test_topk_keeps_largest():
    g = {"w": jnp.array([[1.0, 0.1, -2.0, 0.01]])}
    ef = init_compression(g)["ef"]
    gq, ef = topk_compress_transform(g, ef, frac=0.5)
    w = np.asarray(gq["w"][0])
    assert w[2] == -2.0 and w[0] == 1.0
    assert w[1] == 0.0 and w[3] == 0.0
    # EF holds the dropped mass
    np.testing.assert_allclose(np.asarray(ef["w"][0]),
                               [0.0, 0.1, 0.0, 0.01], atol=1e-7)


def test_compression_training_converges():
    """int8+EF compressed training reaches ~uncompressed loss."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    W_true = jax.random.normal(k1, (8, 4))
    X = jax.random.normal(k2, (32, 8))
    Y = X @ W_true
    loss = lambda p: jnp.mean((X @ p["w"] - Y) ** 2)
    cfg = AdamWConfig(peak_lr=0.05, warmup_steps=5, weight_decay=0.0)

    def train(compressed):
        p = {"w": jax.random.normal(k3, (8, 4)) * 0.1}
        s = init_opt_state(p)
        ef = init_compression(p)["ef"]
        for _ in range(80):
            g = jax.grad(loss)(p)
            if compressed:
                g, ef = int8_compress_transform(g, ef)
            p, s, _ = adamw_update(g, s, p, cfg)
        return float(loss(p))

    lc, lu = train(True), train(False)
    assert lc < max(3.0 * lu, 1e-3)


# ---------------------------------------------------------------------------
# failure / straggler
# ---------------------------------------------------------------------------

def test_straggler_detector():
    det = StragglerDetector(factor=2.0, min_samples=3)
    for _ in range(6):
        det.observe(0.1)
    assert det.observe(0.5) is True
    assert det.observe(0.1) is False
    assert len(det.events) == 1


def test_heartbeat_monitor():
    from repro.runtime.failure import HeartbeatMonitor
    t = [0.0]
    clock = lambda: t[0]
    dead = []
    mon = HeartbeatMonitor([0, 1], timeout=1.0, on_dead=dead.append,
                           clock=clock)
    t[0] = 0.5
    mon.beat(0)
    t[0] = 1.2
    assert mon.check() == [1]
    assert dead == [1]
    t[0] = 1.9
    assert mon.check() == [0]


# ---------------------------------------------------------------------------
# serving engine invariants
# ---------------------------------------------------------------------------

def test_serving_batched_equals_single():
    """A request decoded in a batch == the same request decoded alone."""
    from repro.serving.engine import EngineConfig, ServeEngine
    cfg = reduced_config(REGISTRY["llama3-8b"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=2, chips_per_group=2)
    rng = np.random.default_rng(2)
    prompt = rng.integers(2, cfg.vocab, size=8)

    def run(n_extra):
        eng = ServeEngine(cfg, topo, EngineConfig(max_batch=4, max_len=48),
                          spread_rate=1, seed=0)
        main = eng.submit(prompt, max_new=5)
        extra = [eng.submit(rng.integers(2, cfg.vocab, size=8), 5)
                 for _ in range(n_extra)]
        eng.run_until_done()
        return main.generated

    assert run(0) == run(3)


def test_serving_midrun_relayout_preserves_tokens():
    """The headline adaptive behavior (ISSUE 1 acceptance, extended to the
    paged allocator): under uneven load the controller changes spread_rate
    DURING run_until_done, replica groups are rebuilt, in-flight streams
    survive migration — their block tables re-point at the new owner of
    their chiplet-group domain — and every request generates exactly the
    tokens of a non-adaptive run."""
    from repro.core.controller import ControllerConfig
    from repro.serving.engine import EngineConfig, ServeEngine
    cfg = reduced_config(REGISTRY["llama3-8b"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=4, chips_per_group=1)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(2, cfg.vocab, size=6) for _ in range(12)]
    # round-robin routing puts every 4th request on group 0; its short
    # generations drain first, so group 0 steals early and remote_bytes
    # crosses the threshold while other groups still hold KV state
    # (pool_streams=4: generous budget, so nothing parks and all twelve
    # queue up front like the old slot-monolith test)
    max_new = [2 if i % 4 == 0 else 10 for i in range(12)]

    def run(adaptive):
        ecfg = EngineConfig(
            max_batch=1, max_len=32, adaptive=adaptive, pool_streams=4,
            controller=ControllerConfig(scheduler_timer=3, threshold=1.0,
                                        min_dwell=1))
        eng = ServeEngine(cfg, topo, ecfg, spread_rate=1, seed=0)
        reqs = [eng.submit(p, max_new=max_new[i])
                for i, p in enumerate(prompts)]
        res = eng.run_until_done()
        return eng, reqs, res

    eng_a, reqs_a, res_a = run(True)
    assert all(r.done for r in reqs_a)
    # paged mode is the default
    assert eng_a.ecfg.paged and eng_a.pool is not None
    # at least one relayout fired mid-run and actually changed the groups
    assert len(res_a["relayouts"]) >= 1
    assert res_a["relayouts"][0]["old_groups"] != \
        res_a["relayouts"][0]["new_groups"]
    assert len(eng_a.groups) != 4
    # in-flight streams survived the migration
    assert res_a["relayouts"][0]["moved_slots"] >= 1
    assert res_a["counters"]["kv_slots_migrated"] == \
        res_a["counters"]["kv_slots_restored"]
    assert sum(r.migrations for r in reqs_a) >= 1
    # spread relayouts merge groups: every domain keeps its owner, so NO
    # block contents moved — tables only
    spreads = [r for r in res_a["relayouts"]
               if r["new_groups"] < r["old_groups"]]
    assert spreads and all(r["blocks_migrated"] == 0 for r in spreads)
    # identical generations vs the non-adaptive run
    eng_b, reqs_b, res_b = run(False)
    assert all(r.done for r in reqs_b)
    assert res_b["relayouts"] == [] and res_b["decisions"] == []
    assert [r.generated for r in reqs_a] == [r.generated for r in reqs_b]


def test_serving_legacy_slot_monolith_still_works():
    """paged=False keeps the PR-1 slot-monolith path alive (and its tokens
    match the paged path bit-for-bit)."""
    from repro.serving.engine import EngineConfig, ServeEngine
    cfg = reduced_config(REGISTRY["llama3-8b"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=2, chips_per_group=1)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(2, cfg.vocab, size=7) for _ in range(4)]

    def run(paged):
        eng = ServeEngine(cfg, topo,
                          EngineConfig(max_batch=2, max_len=32, paged=paged),
                          spread_rate=1, seed=0)
        reqs = [eng.submit(p, max_new=4) for p in prompts]
        eng.run_until_done()
        assert all(r.done for r in reqs)
        return [r.generated for r in reqs]

    assert run(True) == run(False)


def test_paged_pool_migrate_touches_only_referenced_blocks():
    """A cross-domain migration copies exactly the table's USED pages (+
    state slot); every other physical block in the pool is bit-identical
    afterwards — never whole-cache slices."""
    import jax.numpy as jnp
    from repro.serving.kvpool import KVBlockPool
    cfg = reduced_config(REGISTRY["llama3-8b"])
    pool = KVBlockPool(cfg, n_domains=2, max_len=32, blocks_per_domain=4,
                       states_per_domain=2, block_tokens=16)
    # fill the whole storage with sentinels so copies are observable
    pool.storage = jax.tree.map(
        lambda a: jnp.arange(a.size, dtype=a.dtype).reshape(a.shape),
        pool.storage)
    t = pool.reserve(0, total_tokens=32)          # 2 pages in domain 0
    t.used_pages = 1                              # only page 0 written
    before = [np.asarray(l).copy() for l in jax.tree.leaves(pool.storage)]
    src = list(t.blocks)
    assert pool.migrate(t, 1)
    assert t.domain == 1
    dst = list(t.blocks)
    assert src != dst and len(dst) == 2
    after = [np.asarray(l) for l in jax.tree.leaves(pool.storage)]
    touched = {dst[0]}                            # only the used page copied
    for b4, a4, spec in zip(before, after, pool.spec.leaves):
        if spec.token_axis is None:
            continue
        moved = np.moveaxis(a4, spec.batch_axis, 0)
        moved_b4 = np.moveaxis(b4, spec.batch_axis, 0)
        for blk in range(moved.shape[0]):
            if blk in touched:
                np.testing.assert_array_equal(
                    moved[blk], np.moveaxis(
                        b4, spec.batch_axis, 0)[src[0]])
            else:
                np.testing.assert_array_equal(moved[blk], moved_b4[blk])
    assert pool.counters.totals["kv_blocks_migrated"] == 1  # used page only


def test_paged_compact_relayout_migrates_used_blocks_only():
    """Splitting a big replica (compact move) rebalances some in-flight
    streams onto replicas that don't own their domain: exactly those
    streams' used pages are copied, far fewer than a whole-cache move."""
    from repro.core.controller import ControllerConfig
    from repro.serving.engine import EngineConfig, ServeEngine
    cfg = reduced_config(REGISTRY["llama3-8b"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=4, chips_per_group=1)
    rng = np.random.default_rng(11)
    # start fully spread (one big replica over 4 domains); a huge threshold
    # makes Algorithm 1 compact mid-run (4 -> 2 groups)
    ecfg = EngineConfig(
        max_batch=6, max_len=32, adaptive=True, pool_streams=6,
        controller=ControllerConfig(scheduler_timer=3, threshold=1e18,
                                    min_dwell=0))
    eng = ServeEngine(cfg, topo, ecfg, spread_rate=4, seed=0)
    reqs = [eng.submit(rng.integers(2, cfg.vocab, size=6), max_new=12)
            for _ in range(6)]
    res = eng.run_until_done()
    assert all(r.done for r in reqs)
    compacts = [r for r in res["relayouts"]
                if r["new_groups"] > r["old_groups"]]
    assert compacts, res["relayouts"]
    # rebalancing copied SOME used pages, but far fewer than the whole
    # cache (6 streams x 2 pages): tables moved, data mostly stayed put
    moved = sum(r["blocks_migrated"] for r in compacts)
    total_pages = 6 * eng.pool.pages_per_stream
    assert 1 <= moved < total_pages
    assert res["counters"]["kv_tables_migrated"] >= 1


def test_paged_pool_unaligned_ring_width():
    """Ring widths that aren't multiples of block_tokens align the page
    size down identically in budget and pool, so a full-length stream
    always fits its budgeted domain (regression: max_len=40, bt=16)."""
    from repro.serving.engine import EngineConfig, ServeEngine
    from repro.serving.kvpool import KVBlockPool
    cfg = reduced_config(REGISTRY["llama3-8b"])
    budget = KVBlockPool.blocks_for_streams(cfg, max_len=40, streams=1,
                                            block_tokens=16)
    pool = KVBlockPool(cfg, n_domains=1, max_len=40, block_tokens=16,
                       **budget)
    assert budget["blocks_per_domain"] == pool.pages_per_stream
    t = pool.reserve(0, total_tokens=40)       # full-length stream fits
    assert t is not None and len(t.blocks) == pool.pages_per_stream
    # end-to-end: the engine serves a full-length request at this max_len
    topo = ChipletTopology(n_pods=1, groups_per_pod=2, chips_per_group=1)
    eng = ServeEngine(cfg, topo,
                      EngineConfig(max_batch=1, max_len=40, adaptive=False),
                      spread_rate=1, seed=0)
    rng = np.random.default_rng(1)
    req = eng.submit(rng.integers(2, cfg.vocab, size=20), max_new=20)
    eng.run_until_done()
    assert req.done and len(req.generated) == 20


def test_paged_admission_parks_on_exhaustion_and_resumes():
    """Pool exhaustion is the back-pressure mechanism: admissions park via
    yield BLOCK (counted as alloc failures + blocked tasks), are woken by
    the pool's free callback, and every request still completes."""
    from repro.serving.engine import EngineConfig, ServeEngine
    cfg = reduced_config(REGISTRY["llama3-8b"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=2, chips_per_group=1)
    rng = np.random.default_rng(5)
    # budget: ONE full-length stream per domain; twelve long requests
    # (2 pages each = a whole domain) must take turns through the pool
    eng = ServeEngine(cfg, topo,
                      EngineConfig(max_batch=2, max_len=32, pool_streams=1,
                                   adaptive=False),
                      spread_rate=1, seed=0)
    reqs = [eng.submit(rng.integers(2, cfg.vocab, size=20), max_new=12)
            for _ in range(12)]
    res = eng.run_until_done()
    assert all(r.done for r in reqs)
    c = res["counters"]
    assert c["kv_alloc_failures"] > 0          # pool really was exhausted
    assert c["tasks_blocked"] > 0              # admissions parked via BLOCK
    assert c["tasks_unblocked"] > 0            # and were woken by frees
    assert res["kv"]["park_rate"] > 0
    assert eng.pool.occupancy() == 0.0         # everything freed at the end


def test_paged_2x_batch_same_memory_budget():
    """max_batch twice the slot-monolith limit completes — and actually
    decodes more concurrent streams than the budget's stream count — for
    the same per-domain byte budget."""
    from repro.serving.engine import EngineConfig, ServeEngine
    cfg = reduced_config(REGISTRY["llama3-8b"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=2, chips_per_group=1)
    rng = np.random.default_rng(9)
    # pool budget = 1 full stream/domain (the old monolith limit for
    # max_batch=1); run with max_batch=2
    eng = ServeEngine(cfg, topo,
                      EngineConfig(max_batch=2, max_len=48, pool_streams=1,
                                   adaptive=False),
                      spread_rate=1, seed=0)
    peak = [0]
    orig = eng._decode_tick

    def spy(g):
        peak[0] = max(peak[0], sum(s is not None for s in g.slots))
        orig(g)

    eng._decode_tick = spy
    # short requests: one page each, so two fit in one domain's budget
    reqs = [eng.submit(rng.integers(2, cfg.vocab, size=6), max_new=6)
            for _ in range(8)]
    eng.run_until_done()
    assert all(r.done for r in reqs)
    assert peak[0] == 2                        # 2x the monolith's 1 slot
    assert eng.pool.peak_used_blocks <= eng.pool.total_blocks()


def test_serving_max_new_one_generates_one_token():
    """max_new=1 is satisfied by the prefill token: no decode slot, no
    extra token (regression: the old path always decoded once more)."""
    from repro.serving.engine import EngineConfig, ServeEngine
    cfg = reduced_config(REGISTRY["llama3-8b"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=2, chips_per_group=1)
    eng = ServeEngine(cfg, topo,
                      EngineConfig(max_batch=2, max_len=32, adaptive=False),
                      spread_rate=1, seed=0)
    rng = np.random.default_rng(6)
    one = eng.submit(rng.integers(2, cfg.vocab, size=8), max_new=1)
    two = eng.submit(rng.integers(2, cfg.vocab, size=8), max_new=3)
    eng.run_until_done()
    assert one.done and len(one.generated) == 1
    assert two.done and len(two.generated) == 3
    assert eng.pool.occupancy() == 0.0


def test_paged_admission_uses_all_group_domains():
    """A replica spanning several domains admits into ANY of them: with
    spread_rate=2 one group owns two 1-stream domains and serves two
    full-length requests concurrently without parking."""
    from repro.serving.engine import EngineConfig, ServeEngine
    cfg = reduced_config(REGISTRY["llama3-8b"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=2, chips_per_group=1)
    eng = ServeEngine(cfg, topo,
                      EngineConfig(max_batch=2, max_len=32, pool_streams=1,
                                   adaptive=False),
                      spread_rate=2, seed=0)
    rng = np.random.default_rng(8)
    # two full-length requests: 2 pages each = one whole domain each
    reqs = [eng.submit(rng.integers(2, cfg.vocab, size=20), max_new=12)
            for _ in range(2)]
    res = eng.run_until_done()
    assert all(r.done for r in reqs)
    assert res["counters"].get("kv_alloc_failures", 0) == 0
    assert {r.table.domain for r in reqs} == {0, 1}


def test_openloop_client_submits_over_time():
    """The open-loop client coroutine shares the TaskRuntime: arrivals
    interleave with decode (some requests finish before later ones are even
    submitted) and all complete."""
    from repro.serving.engine import EngineConfig, ServeEngine
    cfg = reduced_config(REGISTRY["mamba2-780m"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=2, chips_per_group=1)
    rng = np.random.default_rng(4)
    eng = ServeEngine(cfg, topo,
                      EngineConfig(max_batch=2, max_len=32, adaptive=False),
                      spread_rate=1, seed=0)
    sched = [(6, rng.integers(2, cfg.vocab, size=5), 3) for _ in range(6)]
    eng.open_loop_client(sched)
    res = eng.run_until_done()
    reqs = eng.submitted
    assert len(reqs) == 6
    assert all(r.done for r in reqs)
    # open-loop: a later arrival happened after an earlier completion
    assert max(r.arrived for r in reqs) > min(r.t_done for r in reqs)
    st = eng.stats(reqs)
    assert st["n"] == 6 and st["ttft_p99"] >= st["ttft_p50"] >= 0
    assert res["kv"]["occupancy"] == 0.0


def test_tiered_queues_group_tier_order():
    """With neighborhoods, request stealing walks group -> pod -> fleet
    (ROADMAP "TieredQueues group tier")."""
    from repro.core.scheduler import TieredQueues
    from repro.core.counters import PerfCounters
    cnt = PerfCounters()
    tq = TieredQueues([0, 0, 0, 1], neighborhoods=[0, 0, 1, 2],
                      counters=cnt, bytes_fn=lambda r: 4.0)
    tq.push(1, "near")        # same pod, same neighborhood as queue 0
    tq.push(2, "far")         # same pod, different neighborhood
    tq.push(3, "other_pod")   # different pod
    assert tq.pop(0) == ("near", "group")
    assert tq.pop(0) == ("far", "pod")
    assert tq.pop(0) == ("other_pod", "fleet")
    assert tq.pop(0) == (None, None)
    assert cnt.totals["steals_group"] == 1
    assert cnt.totals["steals_pod"] == 1
    assert cnt.totals["steals_fleet"] == 1
    assert cnt.totals["remote_bytes"] == 12.0
    assert cnt.totals["dcn_bytes"] == 4.0     # only the cross-pod move


def test_tiered_queues_accept_hook_refuses_steal():
    """pop(accept=...) leaves refused items on their victim queue and the
    steal uncounted (engine: KV reservation cannot move)."""
    from repro.core.scheduler import TieredQueues
    from repro.core.counters import PerfCounters
    cnt = PerfCounters()
    tq = TieredQueues([0, 0], counters=cnt)
    tq.push(1, "x")
    assert tq.pop(0, accept=lambda item, tier: False) == (None, None)
    assert len(tq.queue(1)) == 1              # still there
    assert cnt.totals.get("steals_pod", 0) == 0
    assert tq.pop(0) == ("x", "pod")          # unconditional pop succeeds


def test_serving_request_steal_tier_order():
    """Request stealing follows pod-before-fleet order (§4.4 for requests)."""
    from repro.core.scheduler import TieredQueues
    from repro.core.counters import PerfCounters
    cnt = PerfCounters()
    tq = TieredQueues([0, 0, 1, 1], counters=cnt, bytes_fn=lambda r: 8.0)
    tq.push(1, "a")
    tq.push(2, "b")
    item, tier = tq.pop(0)
    assert (item, tier) == ("a", "pod")       # same-pod victim preferred
    item, tier = tq.pop(0)
    assert (item, tier) == ("b", "fleet")     # cross-pod as last resort
    assert tq.pop(0) == (None, None)
    assert cnt.totals["steals_pod"] == 1
    assert cnt.totals["steals_fleet"] == 1
    assert cnt.totals["remote_bytes"] == 16.0
    assert cnt.totals["dcn_bytes"] == 8.0     # only the cross-pod move


def test_serving_work_stealing_balances():
    from repro.serving.engine import EngineConfig, ServeEngine
    cfg = reduced_config(REGISTRY["mamba2-780m"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=4, chips_per_group=1)
    eng = ServeEngine(cfg, topo, EngineConfig(max_batch=1, max_len=32),
                      spread_rate=1)
    rng = np.random.default_rng(0)
    # submit everything at once: queues imbalance -> steals must occur
    reqs = [eng.submit(rng.integers(2, cfg.vocab, size=4), 3)
            for _ in range(12)]
    eng.run_until_done()
    assert all(r.done for r in reqs)
    assert sum(g.steps for g in eng.groups) > 0
