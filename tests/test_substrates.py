"""Substrate tests: optimizer (f32 + 8-bit), data pipeline determinism,
checkpoint roundtrip + elastic reshard, compression error feedback,
failure/straggler handling, serving engine invariants."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_inputs
from repro.configs import REGISTRY, reduced_config
from repro.checkpoint.manager import CheckpointManager, load_pytree, save_pytree
from repro.compression.grad_compress import (init_compression,
                                             int8_compress_transform,
                                             topk_compress_transform)
from repro.core.topology import ChipletTopology
from repro.data.pipeline import (ShardedLoader, SyntheticCorpus, make_batch,
                                 write_corpus_shards)
from repro.models import params as P
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state, \
    lr_schedule
from repro.optim.quantized import adamw8bit_update, init_opt_state_8bit
from repro.runtime.elastic import degraded_mesh, rebatch_for
from repro.runtime.failure import StragglerDetector

KEY = jax.random.PRNGKey(5)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _quad_problem():
    """min ||Wx - y||^2: AdamW should drive the loss down fast."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    W_true = jax.random.normal(k1, (16, 8))
    X = jax.random.normal(k2, (64, 16))
    Y = X @ W_true
    params = {"w": jax.random.normal(k3, (16, 8)) * 0.1}
    loss = lambda p: jnp.mean((X @ p["w"] - Y) ** 2)
    return params, loss


def test_adamw_reduces_loss():
    params, loss = _quad_problem()
    state = init_opt_state(params)
    cfg = AdamWConfig(peak_lr=0.05, warmup_steps=5, total_steps=200,
                      weight_decay=0.0)
    l0 = float(loss(params))
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 0.05 * l0


def test_adamw8bit_tracks_fp32():
    """8-bit AdamW trajectory stays close to f32 AdamW."""
    params, loss = _quad_problem()
    p32, p8 = params, params
    s32 = init_opt_state(params)
    s8 = init_opt_state_8bit(params)
    cfg = AdamWConfig(peak_lr=0.05, warmup_steps=5, total_steps=200,
                      weight_decay=0.0)
    for _ in range(60):
        g32 = jax.grad(loss)(p32)
        g8 = jax.grad(loss)(p8)
        p32, s32, _ = adamw_update(g32, s32, p32, cfg)
        p8, s8, _ = adamw8bit_update(g8, s8, p8, cfg)
    l32, l8 = float(loss(p32)), float(loss(p8))
    assert l8 < 0.15 * float(loss(params))       # converges
    assert l8 < max(4.0 * l32, 0.02)             # close to fp32 quality
    # moments really are 8-bit
    assert s8["m"]["w"]["q"].dtype == jnp.int8
    assert s8["v"]["w"]["q"].dtype == jnp.uint8


def test_lr_schedule_shape():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(lr_schedule(cfg, jnp.int32(0))) == 0.0
    assert float(lr_schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(lr_schedule(cfg, jnp.int32(100))) == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_corpus_deterministic(tmp_path):
    c1 = SyntheticCorpus(1000, seed=7)
    c2 = SyntheticCorpus(1000, seed=7)
    np.testing.assert_array_equal(c1.shard_tokens(3, 1000),
                                  c2.shard_tokens(3, 1000))
    assert not np.array_equal(c1.shard_tokens(3, 1000),
                              c1.shard_tokens(4, 1000))


def test_loader_sharding_and_resume(tmp_path):
    corpus = SyntheticCorpus(512, seed=1)
    files = write_corpus_shards(str(tmp_path), corpus, n_shards=4,
                                tokens_per_shard=4000)
    l_all = ShardedLoader(files, seq_len=16, batch=2)
    b1 = l_all.next()
    b2 = l_all.next()
    assert b1.shape == (2, 17)
    assert not np.array_equal(b1, b2)
    # resume from state: same position -> same next block
    state = l_all.state_dict()
    b3 = l_all.next()
    l_resumed = ShardedLoader(files, seq_len=16, batch=2)
    l_resumed.load_state_dict(state)
    np.testing.assert_array_equal(b3, l_resumed.next())
    # host sharding: different hosts read disjoint shards
    h0 = ShardedLoader(files, host=0, n_hosts=2, seq_len=16, batch=2)
    h1 = ShardedLoader(files, host=1, n_hosts=2, seq_len=16, batch=2)
    assert not np.array_equal(h0.next(), h1.next())


def test_make_batch_families(key=KEY):
    for name in ("llama3-8b", "qwen2-vl-2b", "seamless-m4t-large-v2"):
        cfg = reduced_config(REGISTRY[name])
        block = np.random.default_rng(0).integers(
            0, cfg.vocab, size=(2, 33)).astype(np.int32)
        b = make_batch(cfg, block)
        assert b["tokens"].dtype == np.int32
        assert b["targets"].shape == b["mask"].shape


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16)}}
    save_pytree(str(tmp_path / "ck"), tree, metadata={"step": 3})
    like = jax.tree.map(jnp.zeros_like, tree)
    out, meta = load_pytree(str(tmp_path / "ck"), like)
    assert meta["step"] == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_manager_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        mgr.save(s, {"x": jnp.full((2,), s)})
    assert mgr.latest() == 3
    assert mgr.steps() == [2, 3]          # gc dropped step 1
    out, meta = mgr.restore({"x": jnp.zeros((2,))})
    assert meta["step"] == 3


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(1, {"x": jnp.ones((4,))}, blocking=False)
    mgr.wait()
    assert mgr.latest() == 1


def test_elastic_reshard_on_load(tmp_path):
    """Checkpoint saved replicated restores onto any target sharding."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as Pc
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_pytree(str(tmp_path / "ck"), tree)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    shd = {"w": NamedSharding(mesh, Pc(None, "model"))}
    out, _ = load_pytree(str(tmp_path / "ck"), tree, shardings=shd)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert out["w"].sharding == shd["w"]


def test_degraded_mesh_and_rebatch():
    mesh, kept = degraded_mesh((1, 1), failed_rows=[])
    assert mesh.shape["data"] == 1
    assert rebatch_for(256, 15) == 255
    assert rebatch_for(7, 8) == 8


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_error_feedback_unbiased():
    """With EF, the accumulated compressed signal converges to the truth."""
    g_true = {"w": jnp.array([[0.3, -0.001, 0.7, 0.0002]] * 2)}
    ef = init_compression(g_true)["ef"]
    acc = jnp.zeros_like(g_true["w"])
    for _ in range(50):
        gq, ef = int8_compress_transform(g_true, ef)
        acc = acc + gq["w"]
    np.testing.assert_allclose(np.asarray(acc / 50),
                               np.asarray(g_true["w"]), rtol=0.02, atol=1e-4)


def test_topk_keeps_largest():
    g = {"w": jnp.array([[1.0, 0.1, -2.0, 0.01]])}
    ef = init_compression(g)["ef"]
    gq, ef = topk_compress_transform(g, ef, frac=0.5)
    w = np.asarray(gq["w"][0])
    assert w[2] == -2.0 and w[0] == 1.0
    assert w[1] == 0.0 and w[3] == 0.0
    # EF holds the dropped mass
    np.testing.assert_allclose(np.asarray(ef["w"][0]),
                               [0.0, 0.1, 0.0, 0.01], atol=1e-7)


def test_compression_training_converges():
    """int8+EF compressed training reaches ~uncompressed loss."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    W_true = jax.random.normal(k1, (8, 4))
    X = jax.random.normal(k2, (32, 8))
    Y = X @ W_true
    loss = lambda p: jnp.mean((X @ p["w"] - Y) ** 2)
    cfg = AdamWConfig(peak_lr=0.05, warmup_steps=5, weight_decay=0.0)

    def train(compressed):
        p = {"w": jax.random.normal(k3, (8, 4)) * 0.1}
        s = init_opt_state(p)
        ef = init_compression(p)["ef"]
        for _ in range(80):
            g = jax.grad(loss)(p)
            if compressed:
                g, ef = int8_compress_transform(g, ef)
            p, s, _ = adamw_update(g, s, p, cfg)
        return float(loss(p))

    lc, lu = train(True), train(False)
    assert lc < max(3.0 * lu, 1e-3)


# ---------------------------------------------------------------------------
# failure / straggler
# ---------------------------------------------------------------------------

def test_straggler_detector():
    det = StragglerDetector(factor=2.0, min_samples=3)
    for _ in range(6):
        det.observe(0.1)
    assert det.observe(0.5) is True
    assert det.observe(0.1) is False
    assert len(det.events) == 1


def test_heartbeat_monitor():
    from repro.runtime.failure import HeartbeatMonitor
    t = [0.0]
    clock = lambda: t[0]
    dead = []
    mon = HeartbeatMonitor([0, 1], timeout=1.0, on_dead=dead.append,
                           clock=clock)
    t[0] = 0.5
    mon.beat(0)
    t[0] = 1.2
    assert mon.check() == [1]
    assert dead == [1]
    t[0] = 1.9
    assert mon.check() == [0]


# ---------------------------------------------------------------------------
# serving engine invariants
# ---------------------------------------------------------------------------

def test_serving_batched_equals_single():
    """A request decoded in a batch == the same request decoded alone."""
    from repro.serving.engine import EngineConfig, ServeEngine
    cfg = reduced_config(REGISTRY["llama3-8b"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=2, chips_per_group=2)
    rng = np.random.default_rng(2)
    prompt = rng.integers(2, cfg.vocab, size=8)

    def run(n_extra):
        eng = ServeEngine(cfg, topo, EngineConfig(max_batch=4, max_len=48),
                          spread_rate=1, seed=0)
        main = eng.submit(prompt, max_new=5)
        extra = [eng.submit(rng.integers(2, cfg.vocab, size=8), 5)
                 for _ in range(n_extra)]
        eng.run_until_done()
        return main.generated

    assert run(0) == run(3)


def test_serving_midrun_relayout_preserves_tokens():
    """The headline adaptive behavior (ISSUE 1 acceptance): under uneven
    load the controller changes spread_rate DURING run_until_done, replica
    groups are rebuilt, in-flight KV slots survive migration, and every
    request generates exactly the tokens of a non-adaptive run."""
    from repro.core.controller import ControllerConfig
    from repro.serving.engine import EngineConfig, ServeEngine
    cfg = reduced_config(REGISTRY["llama3-8b"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=4, chips_per_group=1)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(2, cfg.vocab, size=6) for _ in range(12)]
    # round-robin routing puts every 4th request on group 0; its short
    # generations drain first, so group 0 steals early and remote_bytes
    # crosses the threshold while other groups still hold KV state
    max_new = [2 if i % 4 == 0 else 10 for i in range(12)]

    def run(adaptive):
        ecfg = EngineConfig(
            max_batch=1, max_len=32, adaptive=adaptive,
            controller=ControllerConfig(scheduler_timer=3, threshold=1.0,
                                        min_dwell=1))
        eng = ServeEngine(cfg, topo, ecfg, spread_rate=1, seed=0)
        reqs = [eng.submit(p, max_new=max_new[i])
                for i, p in enumerate(prompts)]
        res = eng.run_until_done()
        return eng, reqs, res

    eng_a, reqs_a, res_a = run(True)
    assert all(r.done for r in reqs_a)
    # at least one relayout fired mid-run and actually changed the groups
    assert len(res_a["relayouts"]) >= 1
    assert res_a["relayouts"][0]["old_groups"] != \
        res_a["relayouts"][0]["new_groups"]
    assert len(eng_a.groups) != 4
    # in-flight KV state survived the migration
    assert res_a["relayouts"][0]["moved_slots"] >= 1
    assert res_a["counters"]["kv_slots_migrated"] == \
        res_a["counters"]["kv_slots_restored"]
    assert sum(r.migrations for r in reqs_a) >= 1
    # identical generations vs the non-adaptive run
    eng_b, reqs_b, res_b = run(False)
    assert all(r.done for r in reqs_b)
    assert res_b["relayouts"] == [] and res_b["decisions"] == []
    assert [r.generated for r in reqs_a] == [r.generated for r in reqs_b]


def test_serving_request_steal_tier_order():
    """Request stealing follows pod-before-fleet order (§4.4 for requests)."""
    from repro.core.scheduler import TieredQueues
    from repro.core.counters import PerfCounters
    cnt = PerfCounters()
    tq = TieredQueues([0, 0, 1, 1], counters=cnt, bytes_fn=lambda r: 8.0)
    tq.push(1, "a")
    tq.push(2, "b")
    item, tier = tq.pop(0)
    assert (item, tier) == ("a", "pod")       # same-pod victim preferred
    item, tier = tq.pop(0)
    assert (item, tier) == ("b", "fleet")     # cross-pod as last resort
    assert tq.pop(0) == (None, None)
    assert cnt.totals["steals_pod"] == 1
    assert cnt.totals["steals_fleet"] == 1
    assert cnt.totals["remote_bytes"] == 16.0
    assert cnt.totals["dcn_bytes"] == 8.0     # only the cross-pod move


def test_serving_work_stealing_balances():
    from repro.serving.engine import EngineConfig, ServeEngine
    cfg = reduced_config(REGISTRY["mamba2-780m"])
    topo = ChipletTopology(n_pods=1, groups_per_pod=4, chips_per_group=1)
    eng = ServeEngine(cfg, topo, EngineConfig(max_batch=1, max_len=32),
                      spread_rate=1)
    rng = np.random.default_rng(0)
    # submit everything at once: queues imbalance -> steals must occur
    reqs = [eng.submit(rng.integers(2, cfg.vocab, size=4), 3)
            for _ in range(12)]
    eng.run_until_done()
    assert all(r.done for r in reqs)
    assert sum(g.steps for g in eng.groups) > 0
