"""Continuous-batching token loop: chunked paged prefill + lazy page
growth with mid-decode parking (ISSUE 3).

The engine's three allocator modes must be interchangeable at the token
level: LAZY (chunked prefill, elastic page growth, mid-decode parks and —
under the incremental-allocation deadlock — evictions) vs EAGER (PR-2 full
capped reservation + whole-prompt prefill) vs the PR-1 slot monolith
(``paged=False``).  Everything here asserts that equivalence plus the
mechanics that make lazy mode safe: FIFO fairness of the wait line,
page-by-page commitment, and the stall watchdog."""
import numpy as np
import pytest

from conftest import hypothesis_tools
from repro.configs import REGISTRY, reduced_config
from repro.core.topology import ChipletTopology
from repro.serving.engine import EngineConfig, ServeEngine

given, settings, st = hypothesis_tools()

CFG = reduced_config(REGISTRY["llama3-8b"])


def _run(prompts, max_new, *, lazy=True, paged=True, pool_streams=1,
         max_batch=2, max_len=32, groups=2, client_sched=None,
         adaptive=False, **ecfg_kw):
    topo = ChipletTopology(n_pods=1, groups_per_pod=groups,
                           chips_per_group=1)
    ecfg = EngineConfig(max_batch=max_batch, max_len=max_len, paged=paged,
                        lazy=lazy, pool_streams=pool_streams,
                        adaptive=adaptive, **ecfg_kw)
    eng = ServeEngine(CFG, topo, ecfg, spread_rate=1, seed=0)
    reqs = [eng.submit(p, max_new=m) for p, m in zip(prompts, max_new)]
    if client_sched is not None:
        eng.open_loop_client(client_sched)
    res = eng.run_until_done()
    assert all(r.done for r in eng.submitted)
    return eng, reqs, res


# ---------------------------------------------------------------------------
# token identity across allocator modes (property, conftest-fallback safe)
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_lazy_eager_legacy_token_identity(seed):
    """Random prompt/max_new mixes generate IDENTICAL tokens under lazy
    paging (chunked prefill + growth + parks), eager paging and the legacy
    monolith.  pool_streams=1 keeps the pool tight so long examples
    really do park mid-decode and wrap the ring."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 6))
    prompts = [rng.integers(2, CFG.vocab, size=int(rng.integers(3, 28)))
               for _ in range(n)]
    max_new = [int(rng.integers(1, 20)) for _ in range(n)]
    outs = {}
    for mode, (lazy, paged) in {"lazy": (True, True),
                                "eager": (False, True),
                                "legacy": (False, False)}.items():
        _, reqs, _ = _run(prompts, max_new, lazy=lazy, paged=paged)
        outs[mode] = [r.generated for r in reqs]
        assert all(len(g) == m for g, m in zip(outs[mode], max_new))
    assert outs["lazy"] == outs["eager"] == outs["legacy"]


def test_forced_mid_decode_park_token_identity():
    """A stream that PARKS mid-decode (domain exhausted at a page
    boundary) resumes via the pool free callback and still generates
    exactly the eager run's tokens."""
    rng = np.random.default_rng(0)
    # one domain, 2 pages (max_len=32, bt=16).  The long request A (cap 2
    # pages, admitted with 1 — admission grants are FIFO by submit order)
    # shares the domain with a stream of one-page requests that keep the
    # second page continuously occupied (each finish grants the next
    # parked admission).  When A's pos crosses the page boundary the
    # domain is exhausted and A parks mid-decode until a page frees.
    prompts = [rng.integers(2, CFG.vocab, size=4) for _ in range(4)]
    max_new = [24, 8, 8, 8]
    eng, reqs, res = _run(prompts, max_new, lazy=True, groups=1)
    c = res["counters"]
    assert c.get("kv_mid_decode_parks", 0) >= 1      # A really parked
    assert c.get("kv_lazy_grows", 0) >= 1            # and grew on resume
    assert c.get("kv_evictions", 0) == 0             # B's finish unblocked A
    assert eng.pool.occupancy() == 0.0
    _, reqs_e, _ = _run(prompts, max_new, lazy=False, groups=1)
    assert [r.generated for r in reqs] == [r.generated for r in reqs_e]


def test_mid_decode_park_fairness_over_new_admissions():
    """Admission-order fairness (ISSUE 3 satellite): a stream parked
    mid-decode joins the FIFO wait line at park time, so requests arriving
    AFTER it queue behind it — the next free goes to the parked stream,
    not a newcomer."""
    rng = np.random.default_rng(1)
    # A's prompt nearly fills its first page, so it parks a few decode
    # ticks in (pos 16) while one-page B (alive for 12 generated tokens)
    # holds the domain's second page.  C and D are submitted THE MOMENT A
    # parks (tick spy) and must wait behind A in the line.
    prompts = [rng.integers(2, CFG.vocab, size=s) for s in (14, 4, 4, 4)]
    topo = ChipletTopology(n_pods=1, groups_per_pod=1, chips_per_group=1)
    eng = ServeEngine(CFG, topo,
                      EngineConfig(max_batch=2, max_len=32, pool_streams=1,
                                   adaptive=False),
                      spread_rate=1, seed=0)
    a = eng.submit(prompts[0], max_new=10)
    b = eng.submit(prompts[1], max_new=12)
    orig_tick = eng._decode_tick

    def spy(g):
        if a.rid in eng._parked and len(eng.submitted) == 2:
            eng.submit(prompts[2], max_new=4)
            eng.submit(prompts[3], max_new=4)
        orig_tick(g)

    eng._decode_tick = spy
    res = eng.run_until_done()
    assert all(r.done for r in eng.submitted) and len(eng.submitted) == 4
    c_req, d_req = eng.submitted[2], eng.submitted[3]
    assert res["counters"].get("kv_mid_decode_parks", 0) >= 1
    assert res["counters"].get("kv_evictions", 0) == 0
    # C arrived while A sat parked...
    assert c_req.arrived > a.t_first
    assert c_req.arrived < a.t_done
    # ...yet A finished before C or D were even granted pages (prefill
    # implies a table): longest-parked-first granting
    assert c_req.t_first >= a.t_done
    assert d_req.t_first >= a.t_done


def test_eviction_breaks_incremental_allocation_deadlock():
    """Two streams each holding one page and each needing one more is the
    classic incremental-allocation deadlock: in ``evict_mode="restart"``
    (the PR-3 policy, now behind a flag) the stall watchdog evicts the
    most-recently-parked stream, its pages unblock the other, and the
    evicted request restarts — with greedy decoding the final tokens are
    identical to the eager (serialized) run.  The swap-tier default is
    exercised by tests/test_memory_pressure.py."""
    rng = np.random.default_rng(2)
    prompts = [rng.integers(2, CFG.vocab, size=4) for _ in range(2)]
    max_new = [26, 26]
    eng, reqs, res = _run(prompts, max_new, lazy=True, groups=1,
                          evict_mode="restart")
    c = res["counters"]
    assert c.get("kv_mid_decode_parks", 0) >= 2      # both parked
    assert c.get("kv_evictions", 0) >= 1             # watchdog fired
    assert c.get("kv_spills", 0) == 0                # swap tier never used
    assert c.get("recompute_tokens", 0) > 0          # the wasted work
    assert eng.pool.occupancy() == 0.0
    _, reqs_e, _ = _run(prompts, max_new, lazy=False, groups=1)
    assert [r.generated for r in reqs] == [r.generated for r in reqs_e]


# ---------------------------------------------------------------------------
# chunked prefill mechanics
# ---------------------------------------------------------------------------

def test_chunked_prefill_commits_page_by_page():
    """A long prompt prefills in page-sized chunks THROUGH the pool: the
    whole-prompt prefill path is never invoked, one chunk is processed per
    tick, and pages are committed lazily as the prompt crosses page
    boundaries — admission holds a single page."""
    rng = np.random.default_rng(3)
    prompt = rng.integers(2, CFG.vocab, size=30)          # 2 pages of 16

    def boom(*a, **k):
        raise AssertionError("lazy engine must never whole-prompt prefill")

    topo = ChipletTopology(n_pods=1, groups_per_pod=1, chips_per_group=1)
    eng = ServeEngine(CFG, topo,
                      EngineConfig(max_batch=1, max_len=32, pool_streams=1,
                                   adaptive=False),
                      spread_rate=1, seed=0)
    eng._prefill = boom
    admitted_pages = []
    orig_tick = eng._decode_tick

    def spy(g):
        if g.slots[0] is not None and g.pos_h[0] == 0:
            admitted_pages.append(len(g.slots[0].table.blocks))
        orig_tick(g)

    eng._decode_tick = spy
    req = eng.submit(prompt, max_new=2)
    res = eng.run_until_done()
    assert req.done and len(req.generated) == 2
    c = res["counters"]
    assert c["prefill_chunks"] == 2                  # ceil(30 / 16)
    assert c.get("kv_lazy_grows", 0) >= 1            # page 2 grown mid-prompt
    assert admitted_pages == [1]                     # admission took 1 page
    assert eng.pool.occupancy() == 0.0


def test_max_new_one_in_lazy_mode():
    """max_new=1 is satisfied by the last prefill chunk's logits — no
    decode tick, pool drained at the end."""
    rng = np.random.default_rng(4)
    eng, reqs, _ = _run([rng.integers(2, CFG.vocab, size=20)], [1],
                        lazy=True, groups=1)
    assert len(reqs[0].generated) == 1
    assert eng.pool.occupancy() == 0.0


def test_single_token_final_chunk_token_identity():
    """A prompt of chunk+1 tokens leaves a FINAL prefill chunk of exactly
    one token, which rides the plain (non-chunked) step — it must feed the
    prompt token, not the stale last-emitted token (regression: plen=17
    diverged at the first generated token)."""
    rng = np.random.default_rng(8)
    for plen in (17, 33):
        prompts = [rng.integers(2, CFG.vocab, size=plen)]
        out = {}
        for lazy in (True, False):
            _, reqs, _ = _run(prompts, [4], lazy=lazy, groups=1,
                              max_len=48)
            out[lazy] = reqs[0].generated
        assert out[True] == out[False], plen


def test_lazy_relayout_migrates_partial_tables():
    """Live relayout with streams mid-prefill and partially-grown tables:
    adaptive and non-adaptive lazy runs stay token-identical (harvested
    streams carry their chunk cursor; tables re-point or copy only used
    pages)."""
    from repro.core.controller import ControllerConfig
    rng = np.random.default_rng(7)
    prompts = [rng.integers(2, CFG.vocab, size=6) for _ in range(12)]
    max_new = [2 if i % 4 == 0 else 10 for i in range(12)]

    def run(adaptive):
        return _run(prompts, max_new, lazy=True, groups=4, max_batch=1,
                    pool_streams=4, adaptive=adaptive,
                    controller=ControllerConfig(scheduler_timer=3,
                                                threshold=1.0, min_dwell=1))

    eng_a, reqs_a, res_a = run(True)
    assert len(res_a["relayouts"]) >= 1
    eng_b, reqs_b, res_b = run(False)
    assert res_b["relayouts"] == []
    assert [r.generated for r in reqs_a] == [r.generated for r in reqs_b]


# ---------------------------------------------------------------------------
# parallel (fused) vs scan chunk path (ISSUE 5)
# ---------------------------------------------------------------------------

FAMILY_ARCHS = ("llama3-8b", "mixtral-8x22b", "mamba2-780m",
                "recurrentgemma-9b", "seamless-m4t-large-v2")


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10**6), arch=st.sampled_from(FAMILY_ARCHS),
       kernel=st.sampled_from(("dense", "blocked")),
       wide=st.booleans())
def test_parallel_scan_chunk_identity_property(seed, arch, kernel, wide):
    """The fused multi-token forward (``prefill_chunk_step``) matches the
    per-token scan reference (``chunk_decode_step``) within tolerance on
    logits AND every cache leaf, for random chunks over a randomly warmed
    ring — across dense / MoE / SSM / hybrid / enc-dec families, with
    mixed per-stream lengths including a decode stream (n=1) and an idle
    slot (n=0), and with positions deep enough to wrap the ring.  Both
    chunk kernels (dense einsum and the blocked Pallas ring kernel) must
    pass, including chunks WIDER than the ring (``wide`` shrinks the ring
    below the chunk: the C≤W clamp is lifted)."""
    import jax
    import jax.numpy as jnp
    from repro.models import decode as dec
    from repro.models.params import init_params
    cfg = reduced_config(REGISTRY[arch])
    rng = np.random.default_rng(seed)
    B, C = 3, 6
    max_len = 4 if wide else 16          # wide: ring W=4 < C=6
    src = 6 if cfg.family == "encdec" else 0
    params = init_params(cfg, jax.random.PRNGKey(seed % 7))
    spec = dec.cache_view_specs(cfg, max_len, src)
    cache = dec.init_cache(cfg, B, max_len, src)
    if cfg.family == "encdec":
        key = jax.random.PRNGKey(seed % 11)
        for leaf in ("cross_k", "cross_v"):
            cache[leaf] = 0.1 * jax.random.normal(
                key, cache[leaf].shape, cache[leaf].dtype)
    # warm each stream to a random depth (possibly past the ring width)
    # with the trusted scan path, then compare ONE chunk step
    warm = int(rng.integers(0, max_len + 4))
    pos = jnp.zeros((B,), jnp.int32)
    if warm:
        wt = jnp.asarray(rng.integers(2, cfg.vocab, size=(B, warm)),
                         jnp.int32)
        nw = jnp.asarray([warm, max(1, warm // 2), warm], jnp.int32)
        _, cache = dec.chunk_decode_step(params, cfg, spec, cache, wt, pos,
                                         nw)
        pos = nw
    toks = jnp.asarray(rng.integers(2, cfg.vocab, size=(B, C)), jnp.int32)
    nt = jnp.asarray([C, 1, 0], jnp.int32)   # prefill chunk, decode, idle
    lg_s, c_s = dec.chunk_decode_step(params, cfg, spec, cache, toks, pos,
                                      nt)
    lg_p, c_p = dec.prefill_chunk_step(params, cfg, spec, cache, toks, pos,
                                       nt, chunk_kernel=kernel)
    act = np.asarray(nt) > 0
    np.testing.assert_allclose(np.asarray(lg_p)[act], np.asarray(lg_s)[act],
                               rtol=2e-2, atol=2e-3)
    assert np.asarray(lg_p)[~act].max() <= -1e29      # idle rows poisoned
    for a, b in zip(jax.tree.leaves(c_p), jax.tree.leaves(c_s)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_parallel_prefill_one_model_step_per_chunk_tick():
    """The acceptance claim at test scale: a C-token prompt chunk costs
    ONE model forward on the parallel path and C sequential steps on the
    scan reference — token-identically."""
    rng = np.random.default_rng(9)
    prompts = [rng.integers(2, CFG.vocab, size=s) for s in (30, 20, 5)]
    max_new = [4, 6, 3]
    outs = {}
    for pm in ("parallel", "scan"):
        eng, reqs, _ = _run(prompts, max_new, lazy=True, groups=2,
                            prefill_mode=pm)
        outs[pm] = [r.generated for r in reqs]
        kv = eng.kv_stats()
        assert kv["chunk_ticks"] > 0
        expect = 1 if pm == "parallel" else eng._chunk
        assert kv["prefill_model_steps"] == expect * kv["chunk_ticks"], pm
    assert outs["parallel"] == outs["scan"]


def test_parallel_mid_chunk_park_token_identity():
    """A stream that PARKS while still mid-prompt (growth fails at a chunk
    boundary inside the prefill) under the FUSED path resumes at its chunk
    cursor and stays token-identical to the scan path and to the eager
    whole-prompt run — the spill/park machinery is path-agnostic."""
    rng = np.random.default_rng(3)
    prompts = [rng.integers(2, CFG.vocab, size=30) for _ in range(2)]
    max_new = [4, 4]
    outs = {}
    for pm in ("parallel", "scan"):
        eng, reqs, res = _run(prompts, max_new, lazy=True, groups=1,
                              max_batch=2, prefill_mode=pm)
        c = res["counters"]
        assert c.get("kv_mid_decode_parks", 0) >= 1, pm
        assert eng.pool.occupancy() == 0.0
        outs[pm] = [r.generated for r in reqs]
    _, reqs_e, _ = _run(prompts, max_new, lazy=False, groups=1)
    assert outs["parallel"] == outs["scan"] == \
        [r.generated for r in reqs_e]


def test_parallel_chunk_spanning_pages_token_identity():
    """``prefill_chunk`` above the page size (a chunk whose growth commits
    2 pages mid-chunk) and below it both stay token-identical across the
    two compiled paths — the chunk-size sweep's correctness core."""
    rng = np.random.default_rng(11)
    prompts = [rng.integers(2, CFG.vocab, size=s) for s in (28, 9)]
    max_new = [3, 5]
    base = None
    for chunk in (6, 24):
        for pm in ("parallel", "scan"):
            _, reqs, _ = _run(prompts, max_new, lazy=True, groups=1,
                              max_len=32, prefill_mode=pm,
                              prefill_chunk=chunk)
            toks = [r.generated for r in reqs]
            base = base or toks
            assert toks == base, (chunk, pm)


def test_chunk_kernel_and_split_ticks_token_identity():
    """Every cell of the kernel x split matrix generates the scan
    reference's exact tokens, and the split cells actually split: decode
    streams execute ZERO masked prefill-query rows (counter-verified)
    while unsplit mixed ticks pay (C-1) rows per decode stream."""
    rng = np.random.default_rng(13)
    # long prompts prefill while earlier streams decode -> mixed ticks
    prompts = [rng.integers(2, CFG.vocab, size=s) for s in (4, 30, 28, 5)]
    max_new = [14, 4, 4, 10]
    base = None
    for kern in ("blocked", "dense"):
        for split in (True, False):
            eng, reqs, res = _run(prompts, max_new, lazy=True, groups=1,
                                  max_batch=4, pool_streams=4,
                                  chunk_kernel=kern, split_ticks=split)
            toks = [r.generated for r in reqs]
            base = base or toks
            assert toks == base, (kern, split)
            c = res["counters"]
            if split:
                assert c.get("split_ticks", 0) >= 1, (kern, split)
                assert c.get("mixed_tick_decode_rows_saved", 0) > 0
                assert c.get("decode_masked_query_rows", 0) == 0
            else:
                assert c.get("split_ticks", 0) == 0
                assert c.get("decode_masked_query_rows", 0) > 0
            kv = eng.kv_stats()
            assert kv["chunk_kernel"] == kern
    _, reqs_s, _ = _run(prompts, max_new, lazy=True, groups=1, max_batch=4,
                        pool_streams=4, prefill_mode="scan")
    assert [r.generated for r in reqs_s] == base
    # scan mode prices no fused transient regardless of requested kernel
    eng, _, _ = _run(prompts[:1], max_new[:1], lazy=True, groups=1,
                     prefill_mode="scan", chunk_kernel="blocked")
    assert eng.kv_stats()["chunk_kernel"] == "dense"


def test_chunk_wider_than_ring_engine_token_identity():
    """The C<=W clamp is LIFTED: a hybrid model (ring W=32 < max_len=48)
    runs 40-token prefill chunks — wider than its ring — through both
    fused kernels and stays token-identical to the scan path (which steps
    token-by-token and never saw a clamp)."""
    hyb = reduced_config(REGISTRY["recurrentgemma-9b"])
    rng = np.random.default_rng(17)
    prompts = [rng.integers(2, hyb.vocab, size=44) for _ in range(2)]
    topo = ChipletTopology(n_pods=1, groups_per_pod=1, chips_per_group=1)
    outs = {}
    for key, (pm, kern) in {"blocked": ("parallel", "blocked"),
                            "dense": ("parallel", "dense"),
                            "scan": ("scan", "dense")}.items():
        ecfg = EngineConfig(max_batch=2, max_len=48, pool_streams=2,
                            prefill_chunk=40, prefill_mode=pm,
                            chunk_kernel=kern, adaptive=False)
        eng = ServeEngine(hyb, topo, ecfg, spread_rate=1, seed=0)
        assert eng._chunk == 40 > eng.pool.spec.width == 32
        reqs = [eng.submit(p, max_new=3) for p in prompts]
        eng.run_until_done()
        assert all(r.done for r in reqs)
        outs[key] = [r.generated for r in reqs]
    assert outs["blocked"] == outs["dense"] == outs["scan"]


def test_idle_slot_logits_are_poisoned_not_argmaxable():
    """ISSUE 5 bugfix regression: pre-fix, ``chunk_decode_step``
    initialized idle-slot logits to ZEROS, whose argmax is token 0 — a
    perfectly plausible token id at the engine's append site.  Both chunk
    paths must poison idle rows to NEG_INF and ``next_token_ids`` must map
    them to the -1 sentinel, so an idle slot can never append a token in
    any mode (the engine additionally asserts ``tok >= 0`` on append)."""
    import jax
    import jax.numpy as jnp
    from repro.models import decode as dec
    from repro.models.params import init_params
    max_len = 16
    params = init_params(CFG, jax.random.PRNGKey(0))
    spec = dec.cache_view_specs(CFG, max_len)
    cache = dec.init_cache(CFG, 2, max_len)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        2, CFG.vocab, size=(2, 4)), jnp.int32)
    nt = jnp.asarray([4, 0], jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    for step in (dec.chunk_decode_step, dec.prefill_chunk_step):
        lg, _ = step(params, CFG, spec, cache, toks, pos, nt)
        lg = np.asarray(lg)
        assert lg[1].max() <= -1e29, step.__name__    # no argmax-able row
        ids = np.asarray(dec.next_token_ids(jnp.asarray(lg), nt))
        assert ids[1] == -1 and ids[0] >= 0, step.__name__


# ---------------------------------------------------------------------------
# counters / stats surface + cost model
# ---------------------------------------------------------------------------

def test_new_counters_surface_in_kv_stats_and_samples():
    """kv_lazy_grows / kv_mid_decode_parks / prefill_chunks reach the
    engine's kv_stats AND the profiler's StepSample stream."""
    rng = np.random.default_rng(5)
    prompts = [rng.integers(2, CFG.vocab, size=20) for _ in range(2)]
    eng, reqs, res = _run(prompts, [12, 12], lazy=True, groups=1,
                          max_batch=2, pool_streams=2)
    kv = eng.kv_stats()
    for key in ("lazy_grows", "mid_decode_parks", "prefill_chunks",
                "evictions", "peak_active_tables", "peak_used_per_domain",
                "prefill_chunk_bytes"):
        assert key in kv, key
    assert kv["prefill_chunks"] >= 2
    assert kv["lazy_grows"] >= 1
    assert kv["prefill_chunk_bytes"] > 0
    samples = eng.counters.samples
    assert sum(s.prefill_chunks for s in samples) >= 2
    assert sum(s.kv_lazy_grows for s in samples) >= 1
    # per-domain watermark actually watched the one busy domain
    assert max(kv["peak_used_per_domain"]) == kv["peak_used_blocks"]


def test_prefill_chunk_bytes_costmodel():
    """prefill_chunk_bytes = chunk * slope(kv_cache_bytes) + state bytes —
    byte-accurate against the cost model for ring and pure-state models."""
    from repro.configs.base import ShapeConfig
    from repro.core.costmodel import (kv_cache_bytes, kv_state_bytes,
                                      kv_token_bytes, prefill_chunk_bytes)
    cfg = CFG
    per_tok = kv_token_bytes(cfg)
    assert per_tok > 0
    s8 = kv_cache_bytes(cfg, ShapeConfig("kv", "decode", 8, 1), 1)
    s16 = kv_cache_bytes(cfg, ShapeConfig("kv", "decode", 16, 1), 1)
    assert s16 - s8 == pytest.approx(8 * per_tok)
    assert prefill_chunk_bytes(cfg, 16) == \
        pytest.approx(16 * per_tok + kv_state_bytes(cfg))
    # a chunk never exceeds the ring
    assert prefill_chunk_bytes(cfg, 64, max_len=16) == \
        pytest.approx(16 * per_tok + kv_state_bytes(cfg))
    ssm = reduced_config(REGISTRY["mamba2-780m"])
    assert kv_token_bytes(ssm) == 0
    assert prefill_chunk_bytes(ssm, 16) == pytest.approx(kv_state_bytes(ssm))


def test_prefill_chunk_score_bytes_costmodel():
    """The parallel path's (C, W + C) f32 score transient, hand-computed
    for one dense and one hybrid config (ISSUE 5 satellite) — and
    ``prefill_chunk_bytes(mode="parallel")`` must price it on top of the
    scan footprint so chunk sweeps compare honest bytes."""
    from repro.core.costmodel import (prefill_chunk_bytes,
                                      prefill_chunk_score_bytes)
    # dense (llama smoke): full attention -> ring width W = max_len = 32;
    # 4 query heads, C=8 queries x (32 prior + 8 chunk) f32 scores, two
    # live buffers (joint scores + softmax probabilities)
    assert prefill_chunk_score_bytes(CFG, 8, max_len=32) == \
        pytest.approx(2 * 4 * 8 * (32 + 8) * 4.0)
    # hybrid (recurrentgemma smoke): attn layers use local_window=32,
    # ring W = min(max_len=16, 32) = 16; recurrent layers add no scores
    hyb = reduced_config(REGISTRY["recurrentgemma-9b"])
    assert hyb.local_window == 32 and hyb.n_heads == 4
    assert prefill_chunk_score_bytes(hyb, 8, max_len=16) == \
        pytest.approx(2 * 4 * 8 * (16 + 8) * 4.0)
    # pure-state model: no attention scores at all
    ssm = reduced_config(REGISTRY["mamba2-780m"])
    assert prefill_chunk_score_bytes(ssm, 8, max_len=16) == 0.0
    # parallel footprint = scan footprint + score transient; a chunk never
    # exceeds the ring in either term
    for cfg, ml in ((CFG, 32), (hyb, 16)):
        assert prefill_chunk_bytes(cfg, 8, ml, mode="parallel") == \
            pytest.approx(prefill_chunk_bytes(cfg, 8, ml)
                          + prefill_chunk_score_bytes(cfg, 8, ml))
    assert prefill_chunk_score_bytes(CFG, 64, max_len=16) == \
        pytest.approx(prefill_chunk_score_bytes(CFG, 16, max_len=16))


def test_prefill_chunk_score_bytes_blocked_kernel():
    """The blocked (Pallas online-softmax) kernel's transient is ONE
    (block_q, block_kv) tile pair, hand-computed for dense and hybrid
    configs, and — the acceptance bound — NEVER exceeds
    2*n_heads*block_q*block_kv*4 no matter how wide the ring or the chunk
    grows (the dense transient scales as C*(W+C))."""
    from repro.core.costmodel import (prefill_chunk_bytes,
                                      prefill_chunk_score_bytes)
    # llama smoke (4 query heads, window=0 -> ring W = max_len):
    # C=8 clips block_q, W+C=40 saturates block_kv=32
    assert prefill_chunk_score_bytes(CFG, 8, max_len=32, kernel="blocked") \
        == pytest.approx(2 * 4 * min(32, 8) * min(32, 32 + 8) * 4.0)
    # hybrid: W = min(max_len=16, local_window=32) = 16, so W+C=24 < 32
    # clips block_kv too
    hyb = reduced_config(REGISTRY["recurrentgemma-9b"])
    assert prefill_chunk_score_bytes(hyb, 8, max_len=16, kernel="blocked") \
        == pytest.approx(2 * 4 * 8 * 24 * 4.0)
    # W- and C-independence: once C and W+C exceed the block sizes the
    # transient is exactly one tile, for ANY chunk/ring width
    bound = 2 * CFG.n_heads * 32 * 32 * 4.0
    for c_tokens, ml in ((32, 64), (256, 1024), (512, 4096), (4096, 65536)):
        got = prefill_chunk_score_bytes(CFG, c_tokens, max_len=ml,
                                        kernel="blocked")
        assert got == pytest.approx(bound)
    for c_tokens, ml in ((1, 8), (8, 32), (64, 4096)):
        assert prefill_chunk_score_bytes(CFG, c_tokens, max_len=ml,
                                         kernel="blocked") <= bound
    # blocked strictly undercuts dense whenever the dense transient
    # outgrows one tile
    assert prefill_chunk_score_bytes(CFG, 16, max_len=512,
                                     kernel="blocked") < \
        prefill_chunk_score_bytes(CFG, 16, max_len=512)
    # pure-state model: still zero
    ssm = reduced_config(REGISTRY["mamba2-780m"])
    assert prefill_chunk_score_bytes(ssm, 8, max_len=16,
                                     kernel="blocked") == 0.0
    # footprint composition threads the kernel through
    for cfg, ml in ((CFG, 32), (hyb, 16)):
        assert prefill_chunk_bytes(cfg, 8, ml, mode="parallel",
                                   kernel="blocked") == \
            pytest.approx(prefill_chunk_bytes(cfg, 8, ml)
                          + prefill_chunk_score_bytes(cfg, 8, ml,
                                                      kernel="blocked"))
    with pytest.raises(ValueError):
        prefill_chunk_score_bytes(CFG, 8, max_len=32, kernel="banded")


def test_waitqueue_order_accessors():
    """WaitQueue keeps first-park order across wake/re-park cycles and
    exposes oldest/youngest + parked_since (used by the fairness path and
    the eviction watchdog)."""
    from repro.core.tasks import TaskRuntime, WaitQueue

    def gen():
        yield

    rt = TaskRuntime(n_pods=1, groups_per_pod=1)
    t = [0.0]
    wq = WaitQueue(rt, clock=lambda: t[0])
    a = rt.spawn(gen(), name="a")
    b = rt.spawn(gen(), name="b")
    t[0] = 1.0
    wq.park(a)
    t[0] = 2.0
    wq.park(b)
    assert a in wq and b in wq and len(wq) == 2
    assert wq.oldest() is a and wq.youngest() is b
    assert wq.parked_since(a) == 1.0
    t[0] = 3.0
    wq.park(a)                       # re-park: keeps position AND timestamp
    assert wq.oldest() is a and wq.parked_since(a) == 1.0
    wq.remove(a)
    assert a not in wq and wq.oldest() is b
    assert wq.parked_since(a) is None


def test_lazy_admits_more_concurrency_than_eager_same_budget():
    """The acceptance property at test scale: under a long-tail max_new
    mix and one full-length stream of budget per domain, lazy admission
    sustains strictly more concurrent reservations than eager."""
    rng = np.random.default_rng(6)
    prompts = [rng.integers(2, CFG.vocab, size=int(rng.integers(4, 14)))
               for _ in range(8)]
    max_new = [20 if i % 4 == 0 else 4 for i in range(8)]
    peaks = {}
    toks = {}
    for mode in ("lazy", "eager"):
        eng, reqs, _ = _run(prompts, max_new, lazy=(mode == "lazy"),
                            groups=2, max_batch=4, pool_streams=1)
        peaks[mode] = eng.pool.peak_active_tables
        toks[mode] = [r.generated for r in reqs]
    assert toks["lazy"] == toks["eager"]
    assert peaks["lazy"] > peaks["eager"]
