"""SLO-tiered admission suite (ISSUE 9): request classes + size-aware
bypass + proactive watermark spill.

PR 9 replaces the wait line's FIFO-only grant rule with ONE relaxation: a
``bypass``-class request may be granted past a PARKED line head when its
charged pages provably fit inside the free pool minus the head's restore
need (``_head_need_in`` / ``kv_bypass_floor_bytes``).  Everything here
asserts the properties that make that relaxation free:

  * token identity — for any arrival schedule x class mix x
    oversubscription level, the bypass-on and bypass-off twins generate
    IDENTICAL tokens (greedy decode is batch-composition independent, so
    any divergence is an engine bug);
  * no starvation — the head the first bypass jumped is re-granted at the
    same round or EARLIER than in the FIFO twin (twin dynamics are
    step-identical up to that first grant: the off engine still WAKES
    bypass-class waiters, it just never grants them);
  * exact pool accounting (``KVBlockPool.audit``) after every bypass
    grant and every proactive / watchdog spill;
  * the proactive watermark rung spills BEFORE the stall watchdog and the
    low-mark hysteresis caps its spill volume;
  * the per-class latency surfaces (``kv_stats()['per_class']``) are the
    SAME samples ``ServeEngine.stats`` reports, just partitioned.
"""
import collections

import numpy as np
import pytest

from conftest import hypothesis_tools
from repro.configs import REGISTRY, reduced_config
from repro.core.costmodel import kv_bypass_floor_bytes, kv_state_bytes, \
    kv_token_bytes
from repro.core.topology import ChipletTopology
from repro.serving.engine import ClassSLO, EngineConfig, Request, \
    ServeEngine
from repro.serving.kvpool import KVBlockPool

given, settings, st = hypothesis_tools()

CFG = reduced_config(REGISTRY["llama3-8b"])


def _engine(*, groups=2, max_batch=4, max_len=32, pool_streams=1,
            evict_mode="swap", seed=0, **ecfg_kw):
    topo = ChipletTopology(n_pods=1, groups_per_pod=groups,
                           chips_per_group=1)
    ecfg = EngineConfig(max_batch=max_batch, max_len=max_len, paged=True,
                        lazy=True, pool_streams=pool_streams,
                        adaptive=False, evict_mode=evict_mode, **ecfg_kw)
    return ServeEngine(CFG, topo, ecfg, spread_rate=1, seed=seed)


def _audit_instrument(eng):
    """Audit the pool's exact accounting after EVERY reserve (bypass
    grants included — the fresh table is not on a request yet, so it is
    appended explicitly), spill and free.  Returns the audit counter."""
    pool = eng.pool
    hits = {"audits": 0}

    def live():
        return [r.table for r in eng.submitted if r.table is not None]

    orig_reserve = pool.reserve

    def reserve(*a, **kw):
        t = orig_reserve(*a, **kw)
        if t is not None:
            pool.audit(live() + ([t] if t not in live() else []))
            hits["audits"] += 1
        return t

    pool.reserve = reserve
    for name in ("spill", "free", "restore"):
        orig = getattr(pool, name)

        def wrapped(table, _orig=orig):
            out = _orig(table)
            pool.audit(live())
            hits["audits"] += 1
            return out

        setattr(pool, name, wrapped)
    return hits


def _drain(eng):
    res = eng.run_until_done()
    assert all(r.done for r in eng.submitted), "allocation deadlock"
    return res


def _mixed(seed, n, max_len, interactive_frac=2):
    """Randomized (gap, prompt, max_new, cls) arrivals: bursty mixed-class
    load — big ``batch`` growers that park under oversubscription and
    small ``interactive`` arrivals behind them."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        if i and rng.integers(0, interactive_frac + 1):
            plen = int(rng.integers(3, 7))
            max_new = int(rng.integers(1, 5))
            cls = "interactive"
        else:
            plen = int(rng.integers(4, max_len // 2))
            max_new = int(rng.integers(max_len // 2, max_len - plen))
            cls = "batch"
        out.append((int(rng.integers(0, 4)),
                    rng.integers(2, CFG.vocab, size=plen), max_new, cls))
    return out


def _twins(seed, *, n=None, audit=False, **ecfg_kw):
    """One randomized schedule through the bypass engine and its FIFO
    twin -> {True: eng, False: eng}."""
    rng = np.random.default_rng(seed)
    n = n if n is not None else int(rng.integers(4, 9))
    groups = int(rng.integers(1, 3))
    streams = int(rng.integers(1, 3))
    sched = _mixed(seed, n, 32)
    cells = {}
    for bypass in (True, False):
        eng = _engine(groups=groups, pool_streams=streams,
                      slo_bypass=bypass, **ecfg_kw)
        if audit:
            eng._audits = _audit_instrument(eng)
        eng.open_loop_client(list(sched))
        _drain(eng)
        cells[bypass] = eng
    return cells


def _tokens(eng):
    return [r.generated for r in sorted(eng.submitted, key=lambda r: r.rid)]


# ---------------------------------------------------------------------------
# the acceptance properties (randomized schedule x class mix x pressure)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_property_token_identity_bypass_on_off(seed):
    """(a) tokens are identical with the bypass on and off, for any
    schedule / class mix / oversubscription level — and the FIFO twin
    never grants a bypass."""
    cells = _twins(seed)
    assert _tokens(cells[True]) == _tokens(cells[False])
    assert cells[False].kv_stats()["bypass_grants"] == 0
    assert cells[False].bypass_log == []


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_property_head_never_granted_later(seed):
    """(b) no starvation: when the bypass fires, the head it jumped is
    re-granted at the same round or EARLIER than in the FIFO twin.  The
    comparison is exact because twin dynamics are step-identical up to
    the first bypass grant."""
    cells = _twins(seed)
    on, off = cells[True], cells[False]
    for r0, _rid, head_rid in on.bypass_log[:1]:
        g_on = next((t for t in on.submitted[head_rid].grant_rounds
                     if t >= r0), None)
        g_off = next((t for t in off.submitted[head_rid].grant_rounds
                      if t >= r0), None)
        assert g_on is not None and g_off is not None, \
            f"jumped head rid={head_rid} has no re-grant after {r0}"
        assert g_on <= g_off, \
            f"seed={seed}: bypass delayed head rid={head_rid}: " \
            f"{g_on} vs {g_off}"


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_property_audit_after_every_grant_and_spill(seed):
    """(c) ``KVBlockPool.audit`` passes after every reservation (bypass
    grants included), spill, restore and free — on both twins — and the
    drained pool audits clean."""
    cells = _twins(seed, audit=True)
    for eng in cells.values():
        assert eng._audits["audits"] > 0
        eng.pool.audit([])
        assert eng.pool.occupancy() == 0.0


# ---------------------------------------------------------------------------
# crafted bypass scenario (deterministic anchor for the property trio)
# ---------------------------------------------------------------------------

def _crafted(bypass, *, aging=None, audit=False):
    """Three big batch growers congest two 1-stream domains; four 1-page
    interactive arrivals are injected the moment a grower parks — the
    canonical bypass window (a parked head pinned to its group, frees in
    the other group useless to it)."""
    rng = np.random.default_rng(7)
    kw = {} if aging is None else {"slo_aging_rounds": aging}
    eng = _engine(groups=2, max_len=32, pool_streams=1,
                  slo_bypass=bypass, **kw)
    # the profiler keeps a RING of recent samples; widen it so the
    # early-run bypass deltas survive to the post-drain assertions
    eng.counters.samples = collections.deque(maxlen=100000)
    if audit:
        eng._audits = _audit_instrument(eng)
    for _ in range(3):
        eng.submit(rng.integers(2, CFG.vocab, size=6), max_new=24,
                   cls="batch")
    sprompts = [rng.integers(2, CFG.vocab, size=4) for _ in range(4)]
    orig, fired = eng._decode_tick, []

    def spy(g):
        if not fired and eng._parked:
            for p in sprompts:
                eng.submit(p, max_new=4, cls="interactive")
            fired.append(True)
        orig(g)

    eng._decode_tick = spy
    _drain(eng)
    return eng


def test_crafted_bypass_fires_and_head_unharmed():
    on, off = _crafted(True, audit=True), _crafted(False)
    kv_on, kv_off = on.kv_stats(), off.kv_stats()
    assert kv_on["bypass_grants"] >= 1 and kv_off["bypass_grants"] == 0
    assert kv_on["class_bypass_grants"]["interactive"] \
        == kv_on["bypass_grants"]
    assert kv_on["class_bypass_grants"]["batch"] == 0
    assert _tokens(on) == _tokens(off)
    # the priced safety floor the grants preserved for the jumped heads
    assert kv_on["bypass_floor_bytes"] == kv_bypass_floor_bytes(
        CFG, int(kv_on["bypass_floor_pages"]), on.pool.block_tokens)
    # every bypass grant marked its request
    byp = [r for r in on.submitted if r.bypassed]
    assert len(byp) == kv_on["bypass_grants"]
    assert all(r.cls == "interactive" for r in byp)
    # the jumped head is re-granted no later than in the FIFO twin
    r0, _, head_rid = on.bypass_log[0]
    g_on = next(t for t in on.submitted[head_rid].grant_rounds if t >= r0)
    g_off = next(t for t in off.submitted[head_rid].grant_rounds if t >= r0)
    assert g_on <= g_off
    # the counters surface in the profiler's StepSample stream too
    assert sum(s.kv_bypass_grants for s in on.counters.samples) \
        == kv_on["bypass_grants"]
    assert sum(s.kv_head_wait_ticks for s in on.counters.samples) > 0
    on.pool.audit([])


def test_aging_backstop_suspends_bypass():
    """``slo_aging_rounds=0`` makes every waiter "aged" the round after it
    parks: the backstop suspends bypass and the line drains strictly FIFO
    — same tokens, zero grants."""
    on = _crafted(True, aging=0)
    off = _crafted(False, aging=0)
    assert on.kv_stats()["bypass_grants"] == 0
    assert _tokens(on) == _tokens(off)


# ---------------------------------------------------------------------------
# class-SLO plumbing
# ---------------------------------------------------------------------------

def test_unknown_class_fails_fast_at_submit():
    eng = _engine()
    with pytest.raises(ValueError, match="unknown SLO class"):
        eng.submit(np.arange(2, 6, dtype=np.int32), max_new=2, cls="gold")
    assert eng.submitted == [] and len(eng.waiters) == 0
    custom = _engine(slo_classes={"realtime": ClassSLO(bypass=True)})
    with pytest.raises(ValueError, match="realtime"):
        custom.submit(np.arange(2, 6, dtype=np.int32), max_new=2)
    with pytest.raises(ValueError, match="at least one class"):
        _engine(slo_classes={})


def test_per_class_percentiles_match_hand_built_traces():
    """``class_stats`` partitions the SAME samples ``stats`` reports: the
    per-class percentiles over hand-built tick traces equal a hand
    percentile over that class's requests, plus the class targets and
    met/missed flags."""
    def req(rid, cls, arrived, t_first, t_done, n_tok):
        r = Request(rid, np.arange(2, 6, dtype=np.int32), n_tok,
                    arrived=arrived, cls=cls)
        r.t_first, r.t_done = t_first, t_done
        r.generated = list(range(n_tok))
        assert r.done
        return r

    reqs = [req(0, "interactive", 0.0, 0.1, 0.3, 5),
            req(1, "interactive", 1.0, 1.4, 1.5, 3),
            req(2, "batch", 0.0, 2.0, 4.0, 9),
            req(3, "batch", 1.0, 1.2, 6.0, 17)]
    classes = {"interactive": ClassSLO(ttft_target=0.5, tpot_target=0.06,
                                       bypass=True),
               "batch": ClassSLO()}
    per = ServeEngine.class_stats(reqs, classes)
    for c in ("interactive", "batch"):
        sub = [r for r in reqs if r.cls == c]
        ttft = np.array([r.t_first - r.arrived for r in sub])
        tpot = np.array([(r.t_done - r.t_first)
                         / max(1, len(r.generated) - 1) for r in sub])
        assert per[c]["n"] == len(sub)
        assert per[c]["ttft_p50"] == pytest.approx(
            float(np.percentile(ttft, 50)))
        assert per[c]["ttft_p99"] == pytest.approx(
            float(np.percentile(ttft, 99)))
        assert per[c]["tpot_p50"] == pytest.approx(
            float(np.percentile(tpot, 50)))
        # the class partition IS the global stats restricted to the class
        assert per[c]["tokens"] == ServeEngine.stats(sub)["tokens"]
        for k in ("ttft_p50", "ttft_p99", "tpot_p50", "tpot_p99"):
            assert per[c][k] == ServeEngine.stats(sub)[k]
    # interactive: ttft_p99 = 0.4 < 0.5 target met; tpot_p99 = 0.05 met
    assert per["interactive"]["ttft_slo_met"] is True
    assert per["interactive"]["tpot_slo_met"] is True
    # batch targets default to inf: always met
    assert per["batch"]["ttft_target"] == float("inf")
    assert per["batch"]["ttft_slo_met"] is True
    # a class with no finished requests still reports its targets
    per2 = ServeEngine.class_stats([reqs[2]], classes)
    assert per2["interactive"]["n"] == 0
    assert per2["interactive"]["ttft_target"] == 0.5


def test_kv_stats_per_class_counters_consistent():
    eng = _crafted(True)
    kv = eng.kv_stats()
    subs = {c: sum(1 for r in eng.submitted if r.cls == c)
            for c in ("batch", "interactive")}
    assert kv["class_submits"] == {"batch": 3.0, "interactive": 4.0}
    assert kv["class_submits"]["batch"] == subs["batch"]
    # every submit of a drained run was admitted (restart re-admissions
    # can only add)
    for c, n in subs.items():
        assert kv["class_admits"][c] >= n
    assert set(kv["per_class"]) == {"batch", "interactive"}
    assert kv["per_class"]["interactive"]["n"] == 4


def test_batch_only_workload_keeps_fifo_and_counters():
    """Single-class workloads are untouched by the feature (default class
    never bypasses): zero grants, FIFO admission order, and the twin
    engines' KV counter totals are identical."""
    sched = [(g, p, m) for g, p, m, _c in _mixed(3, 6, 32,
                                                 interactive_frac=0)]
    outs, kvs = {}, {}
    for bypass in (True, False):
        eng = _engine(groups=1, pool_streams=1, slo_bypass=bypass)
        grants = []
        orig_remove = eng.waiters.remove
        eng.waiters.remove = lambda t: (grants.append(t.name),
                                        orig_remove(t))
        eng.open_loop_client(list(sched))
        _drain(eng)
        admits = [int(n[len("admit"):]) for n in grants
                  if n.startswith("admit")]
        assert admits == sorted(admits), "FIFO admission order broken"
        outs[bypass] = _tokens(eng)
        kvs[bypass] = eng.kv_stats()
        assert kvs[bypass]["bypass_grants"] == 0
    assert outs[True] == outs[False]
    for k in ("spills", "restores", "head_wait_ticks",
              "peak_active_tables"):
        assert kvs[True][k] == kvs[False][k], k


# ---------------------------------------------------------------------------
# the wait line: bypassed parks re-enter at their arrival position
# ---------------------------------------------------------------------------

def test_bypassed_park_reenters_at_arrival_seq():
    """Regression: a bypassed stream that later parks mid-flight re-joins
    the wait line at its ORIGINAL arrival seq — not the back.  It jumped
    the line once under the no-delay bound; parking must not also demote
    it behind arrivals it legitimately preceded.  ``to_back`` demotion
    stays reserved for spill victims, who consumed their turn."""
    eng = _engine(groups=1, max_batch=2, pool_streams=4)

    def waiter():
        yield

    # a later ARRIVAL is already in line at seq 10
    later = eng.sched.spawn(waiter(), name="later")
    eng.waiters.park(later, seq=10)

    def parked_req(rid, bypassed, wq_seq):
        req = Request(rid, np.arange(2, 8, dtype=np.int32), 12,
                      cls="interactive" if bypassed else "batch")
        req.table = eng.pool.reserve(0, 18, first_tokens=6)
        assert req.table is not None
        req.bypassed, req.wq_seq = bypassed, wq_seq
        eng.submitted.append(req)
        g = eng.groups[0]
        g.slots[0], g.pos_h[0], g.tok_h[0] = req, 6, 3
        eng._park_stream(g, 0)
        return eng._parked[rid]

    rec = parked_req(0, True, 4)            # bypassed: arrival seq 4 < 10
    task = rec.cell["task"]
    assert eng.waiters.seq_of(task) == 4
    assert rec.req.wq_seq == 4
    assert eng.waiters.oldest() is task     # ahead of the later arrival
    # a spill demotes it to the BACK (fresh seq past every waiter)
    assert eng._spill_parked(domain=None)
    assert eng.waiters.seq_of(task) > 10
    assert eng.waiters.oldest() is later
    assert rec.req.wq_seq == eng.waiters.seq_of(task)
    # a NON-bypassed park draws a fresh park-time seq (joins behind)
    rec2 = parked_req(1, False, 4)
    assert eng.waiters.seq_of(rec2.cell["task"]) > 10


# ---------------------------------------------------------------------------
# proactive watermark spill
# ---------------------------------------------------------------------------

def test_watermark_hysteresis_unit():
    """Pool-level watermark ladder: a domain reports itself at the HIGH
    mark, ``watermark_arm`` latches it after a confirmed spill, and it
    re-arms only under the LOW mark."""
    probe = KVBlockPool(CFG, n_domains=1, max_len=32,
                        blocks_per_domain=64, states_per_domain=4)
    pp = probe.pages_needed(32)             # pages one full stream holds
    pool = KVBlockPool(CFG, n_domains=1, max_len=32,
                       blocks_per_domain=2 * pp, states_per_domain=4)
    pool.set_watermarks(0.45, 0.2)
    assert pool.watermark_domains() == []
    t1 = pool.reserve(0, 32, first_tokens=None)
    assert pool.occupancy() == pytest.approx(0.5)
    assert pool.watermark_domains() == [0]
    # crossing does not latch by itself: still eligible next round
    assert pool.watermark_domains() == [0]
    pool.watermark_arm(0)
    assert pool.watermark_domains() == []   # latched
    t2 = pool.reserve(0, 32, first_tokens=None)
    assert pool.watermark_domains() == []   # still latched at occupancy 1.0
    pool.free(t2)
    assert pool.watermark_domains() == []   # 0.5 > LOW: hysteresis holds
    pool.free(t1)
    # the dip under LOW is observed by the per-round poll: this call
    # re-arms the domain (and reports nothing at 0.0 occupancy)
    assert pool.watermark_domains() == []
    t3 = pool.reserve(0, 32, first_tokens=None)
    assert pool.watermark_domains() == [0]  # eligible again
    pool.free(t3)
    with pytest.raises(ValueError, match="watermarks"):
        pool.set_watermarks(0.5, 0.8)
    pool.set_watermarks(None)               # disabled: never reports
    t4 = pool.reserve(0, 32, first_tokens=None)
    assert pool.watermark_domains() == []
    pool.free(t4)
    pool.audit([])


def _pressure_engine(*, watermarks, seed=2, n=4, audit=False):
    rng = np.random.default_rng(seed)
    eng = _engine(groups=1, max_batch=4, pool_streams=1,
                  spill_watermarks=watermarks)
    if audit:
        eng._audits = _audit_instrument(eng)
    sched = [(int(rng.integers(0, 2)),
              rng.integers(2, CFG.vocab, size=int(rng.integers(4, 8))),
              int(rng.integers(12, 24)), "batch") for _ in range(n)]
    eng.open_loop_client(sched)
    _drain(eng)
    return eng


def test_proactive_spill_fires_before_watchdog():
    """The watermark rung sheds the coldest parked stream BEFORE the
    stall watchdog can fire, token-identically, with clean accounting —
    and the hysteresis keeps total spill volume at or under the
    watchdog-only run's on the same schedule."""
    pro = _pressure_engine(watermarks=(0.75, 0.5), audit=True)
    dog = _pressure_engine(watermarks=None)
    kv_p, kv_d = pro.kv_stats(), dog.kv_stats()
    assert kv_p["proactive_spills"] >= 1
    assert kv_d["proactive_spills"] == 0 and kv_d["watchdog_spills"] >= 1
    # acting at the watermark pre-empts the stall: the proactive run
    # needs strictly fewer watchdog rescues
    assert kv_p["watchdog_spills"] < kv_d["watchdog_spills"]
    assert kv_p["spills"] <= kv_d["spills"]
    assert _tokens(pro) == _tokens(dog)
    assert sum(s.kv_spilled_pages for s in pro.counters.samples) >= 1
    pro.pool.audit([])


def test_proactive_spill_mid_prefill_victim_token_identical():
    """A proactive victim parked MID-PREFILL restores at its partial
    chunk cursor: tokens identical to the watermark-off twin."""
    rng = np.random.default_rng(5)
    prompts = [rng.integers(2, CFG.vocab, size=20) for _ in range(3)]
    outs = {}
    for marks in ((0.7, 0.4), None):
        eng = _engine(groups=1, max_batch=4, pool_streams=1, max_len=48,
                      spill_watermarks=marks, prefill_chunk=4)
        picked = []
        orig = eng._spill_parked

        def spy(domain, exclude_rid=None, _e=eng, _o=orig, _p=picked):
            before = {rid: rec.pos for rid, rec in _e._parked.items()
                      if rec.req.table is not None
                      and rec.req.table.spill is None}
            out = _o(domain, exclude_rid)
            if out:
                after = {rid for rid, rec in _e._parked.items()
                         if rec.req.table is not None
                         and rec.req.table.spill is None}
                for rid, pos in before.items():
                    if rid not in after:
                        _p.append((rid, pos,
                                   len(_e.submitted[rid].prompt)))
            return out

        eng._spill_parked = spy
        for p in prompts:
            eng.submit(p, max_new=16, cls="batch")
        _drain(eng)
        outs[marks] = _tokens(eng)
        if marks is not None:
            assert eng.kv_stats()["proactive_spills"] >= 1
            assert any(pos < plen for _rid, pos, plen in picked), \
                "no proactive victim was parked mid-prefill"
    assert outs[(0.7, 0.4)] == outs[None]


def test_proactive_spill_hybrid_state_slot_victim():
    """A hybrid (recurrent + attention) victim's proactive spill carries
    its STATE slot through the swap tier.  State slots and token pages
    are budgeted jointly (``pool_streams`` sizes both), so the engine can
    never oversubscribe pages on its own: a mid-decode park is FORCED at
    a fixed cursor in both twins, and the watermark twin must then shed
    it proactively — state riding the host payload — and restore
    token-identically against both the no-watermark twin and an unforced
    baseline."""
    cfg = reduced_config(REGISTRY["recurrentgemma-9b"])
    rng = np.random.default_rng(4)
    prompts = [rng.integers(2, cfg.vocab, size=6) for _ in range(3)]
    outs = {}
    for mode in ("marks", "plain", "baseline"):
        topo = ChipletTopology(n_pods=1, groups_per_pod=1,
                               chips_per_group=1)
        ecfg = EngineConfig(max_batch=4, max_len=32, paged=True, lazy=True,
                            pool_streams=2, adaptive=False,
                            evict_mode="swap",
                            spill_watermarks=((0.2, 0.1)
                                              if mode == "marks" else None))
        eng = ServeEngine(cfg, topo, ecfg, spread_rate=1, seed=0)
        spill_states = []
        orig_spill = eng.pool.spill

        def spy_spill(t, _o=orig_spill, _s=spill_states):
            out = _o(t)
            _s.append(bool(t.spill is not None and t.spill.had_state))
            return out

        eng.pool.spill = spy_spill
        if mode != "baseline":
            orig_tick = eng._decode_tick
            forced = {"parked": False}

            def tick(g, _e=eng, _o=orig_tick, _f=forced):
                out = _o(g)
                if not _f["parked"]:
                    for i, r in enumerate(g.slots):
                        if r is not None and \
                                int(g.pos_h[i]) >= len(r.prompt) + 4:
                            _e._park_stream(g, i)
                            _f["parked"] = True
                            break
                return out

            eng._decode_tick = tick
        for p in prompts:
            eng.submit(p, max_new=20, cls="batch")
        _drain(eng)
        outs[mode] = _tokens(eng)
        kv = eng.kv_stats()
        if mode == "marks":
            assert kv["proactive_spills"] >= 1
            assert spill_states and all(spill_states), \
                "hybrid spill payload must carry the state slot"
        assert kv["recompute_tokens"] == 0
        eng.pool.audit([])
    assert outs["marks"] == outs["plain"] == outs["baseline"]


# ---------------------------------------------------------------------------
# the priced safety floor
# ---------------------------------------------------------------------------

def test_bypass_floor_bytes_prices_the_head_need():
    bt = 8
    assert kv_bypass_floor_bytes(CFG, 0, bt) == 0.0
    assert kv_bypass_floor_bytes(CFG, -3, bt) == 0.0
    one = kv_bypass_floor_bytes(CFG, 1, bt)
    assert one == bt * kv_token_bytes(CFG)
    assert kv_bypass_floor_bytes(CFG, 5, bt) == 5 * one
    hyb = reduced_config(REGISTRY["recurrentgemma-9b"])
    assert kv_bypass_floor_bytes(hyb, 2, bt, with_state=True) \
        == 2 * bt * kv_token_bytes(hyb) + kv_state_bytes(hyb)
    assert kv_state_bytes(hyb) > 0
