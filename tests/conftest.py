"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 real CPU device;
multi-device dry-run behavior is tested via subprocesses (test_dryrun.py).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

from repro.configs import REGISTRY, reduced_config


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(params=sorted(REGISTRY))
def arch_cfg(request):
    return reduced_config(REGISTRY[request.param])


def make_inputs(cfg, key, B=2, S=32):
    """Batch dict for a reduced config (any family)."""
    import jax.numpy as jnp
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens,
             "targets": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "vlm":
        sv = int(S * cfg.vision_frac)
        batch["tokens"] = tokens[:, :S - sv]
        batch["vision_embeds"] = jax.random.normal(key, (B, sv, cfg.d_model)) * 0.1
        batch["position_ids"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    if cfg.family == "encdec":
        st = S // 2
        batch["frame_embeds"] = jax.random.normal(key, (B, st, cfg.d_model)) * 0.1
        batch["tokens"] = tokens[:, :st]
        batch["targets"] = batch["targets"][:, :st]
        batch["mask"] = batch["mask"][:, :st]
    return batch
