"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 real CPU device;
multi-device dry-run behavior is tested via subprocesses (test_dryrun.py).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

from repro.configs import REGISTRY, reduced_config


def hypothesis_tools():
    """(given, settings, st) — the real hypothesis decorators when the
    package is installed; otherwise a deterministic mini property-test
    driver so the property tests still RUN (not skip) in containers
    without hypothesis.  CI installs real hypothesis via ``.[test]``.

    The fallback supports the strategies this suite uses (``integers``,
    ``sampled_from``, ``booleans``) and draws a fixed number of seeded
    samples per test — no shrinking, but every property is exercised."""
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        import random

        class _Strategy:
            def __init__(self, draw):
                self.draw = draw

        class _FallbackStrategies:
            @staticmethod
            def integers(min_value, max_value):
                return _Strategy(lambda rng: rng.randint(min_value,
                                                         max_value))

            @staticmethod
            def sampled_from(seq):
                seq = list(seq)
                return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

            @staticmethod
            def booleans():
                return _Strategy(lambda rng: bool(rng.randrange(2)))

        def _fallback_given(*arg_strats, **kw_strats):
            def deco(fn):
                def run(*args, **kwargs):
                    examples = getattr(run, "_max_examples", 20)
                    rng = random.Random(0)
                    for _ in range(examples):
                        a = tuple(s.draw(rng) for s in arg_strats)
                        kw = {k: s.draw(rng) for k, s in kw_strats.items()}
                        fn(*args, *a, **kwargs, **kw)
                run.__name__ = fn.__name__
                run.__doc__ = fn.__doc__
                return run
            return deco

        def _fallback_settings(max_examples=20, **_kw):
            def deco(fn):
                fn._max_examples = min(max_examples, 20)
                return fn
            return deco

        return _fallback_given, _fallback_settings, _FallbackStrategies()


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(params=sorted(REGISTRY))
def arch_cfg(request):
    return reduced_config(REGISTRY[request.param])


def make_inputs(cfg, key, B=2, S=32):
    """Batch dict for a reduced config (any family)."""
    import jax.numpy as jnp
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens,
             "targets": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "vlm":
        sv = int(S * cfg.vision_frac)
        batch["tokens"] = tokens[:, :S - sv]
        batch["vision_embeds"] = jax.random.normal(key, (B, sv, cfg.d_model)) * 0.1
        batch["position_ids"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    if cfg.family == "encdec":
        st = S // 2
        batch["frame_embeds"] = jax.random.normal(key, (B, st, cfg.d_model)) * 0.1
        batch["tokens"] = tokens[:, :st]
        batch["targets"] = batch["targets"][:, :st]
        batch["mask"] = batch["mask"][:, :st]
    return batch
