"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 real CPU device;
multi-device dry-run behavior is tested via subprocesses (test_dryrun.py).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

from repro.configs import REGISTRY, reduced_config


def hypothesis_tools():
    """(given, settings, st) — the real hypothesis decorators when the
    package is installed; otherwise stand-ins that degrade each property
    test to ``pytest.importorskip("hypothesis")`` (reported as skipped) so
    the suite still collects."""
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        class _MissingStrategies:
            def __getattr__(self, _name):
                return lambda *a, **k: None

        def _skipping_decorator(*_a, **_k):
            def deco(fn):
                def run(*_args, **_kwargs):
                    pytest.importorskip("hypothesis")
                run.__name__ = fn.__name__
                run.__doc__ = fn.__doc__
                return run
            return deco

        return _skipping_decorator, _skipping_decorator, _MissingStrategies()


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(params=sorted(REGISTRY))
def arch_cfg(request):
    return reduced_config(REGISTRY[request.param])


def make_inputs(cfg, key, B=2, S=32):
    """Batch dict for a reduced config (any family)."""
    import jax.numpy as jnp
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens,
             "targets": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "vlm":
        sv = int(S * cfg.vision_frac)
        batch["tokens"] = tokens[:, :S - sv]
        batch["vision_embeds"] = jax.random.normal(key, (B, sv, cfg.d_model)) * 0.1
        batch["position_ids"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    if cfg.family == "encdec":
        st = S // 2
        batch["frame_embeds"] = jax.random.normal(key, (B, st, cfg.d_model)) * 0.1
        batch["tokens"] = tokens[:, :st]
        batch["targets"] = batch["targets"][:, :st]
        batch["mask"] = batch["mask"][:, :st]
    return batch
