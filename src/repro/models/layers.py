"""Shared neural-net layers: norms, RoPE/M-RoPE, blocked GQA attention, MLPs.

All functions are pure; parameters are plain dict pytrees.  Attention is
implemented as an online-softmax blocked computation (flash-attention
algorithm in pure jnp) so that 32k-token prefills never materialize an
(S, S) score matrix.  ``unroll=True`` statically unrolls the block loops —
used by the dry-run analysis path so ``cost_analysis()`` (which counts a
while-loop body once) sees the true FLOP/byte totals.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def dt(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE and M-RoPE)
# ---------------------------------------------------------------------------

def rope_tables(positions, head_dim: int, theta: float):
    """positions: (..., S) int -> cos/sin tables (..., S, head_dim//2), f32."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def mrope_tables(position_ids, head_dim: int, theta: float, sections):
    """M-RoPE (Qwen2-VL): position_ids (3, ..., S); sections sum to head_dim//2.

    Component c contributes its angle to ``sections[c]`` frequency slots.
    For pure text all three components are equal and this reduces to RoPE.
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang_c = position_ids.astype(jnp.float32)[..., None] * freq  # (3, ..., S, half)
    sel = jnp.repeat(jnp.arange(len(sections)), jnp.array(sections),
                     total_repeat_length=half)                  # (half,) in {0,1,2}
    onehot = jax.nn.one_hot(sel, len(sections), dtype=jnp.float32)  # (half, 3)
    ang = jnp.einsum("c...h,hc->...h", ang_c, onehot)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, dh); cos/sin: (B, S, half) or (S, half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None]
        sin = sin[None]
    c = cos[:, :, None, :].astype(jnp.float32)
    s = sin[:, :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocked GQA attention (flash algorithm, pure jnp)
# ---------------------------------------------------------------------------

def _attn_mask(q_pos, kv_pos, *, causal: bool, window: int, kv_len=None):
    """q_pos: (bq,), kv_pos: (bkv,) -> bool (bq, bkv)."""
    m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        m &= kv_pos[None, :] <= q_pos[:, None]
    if window:
        m &= kv_pos[None, :] > q_pos[:, None] - window
    if kv_len is not None:
        m &= kv_pos[None, :] < kv_len
    return m


def blocked_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                      block_q=512, block_kv=1024, softcap=0.0,
                      unroll=False, kv_offset=0):
    """Online-softmax attention.  q: (B,Sq,Hq,dh), k/v: (B,Skv,Hkv,dh).

    Never materializes (Sq, Skv).  GQA handled natively by grouping query
    heads over KV heads.  Returns (B, Sq, Hq, dh) in q.dtype.

    Gradients flow through a flash-style custom VJP (saves out+lse, replays
    blocks in the backward pass) so the inner online-softmax scan never
    checkpoints its per-block state — without this, vjp-of-scan stores
    every (m, l, acc) carry and activation memory explodes.
    """
    out, _ = _attn_vjp(q, k, v, causal, window, q_offset, block_q, block_kv,
                       softcap, unroll, kv_offset)
    return out


def _pad_blocks(q, k, v, block_q, block_kv):
    B, Sq, Hq, dh = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    Sq0, Skv0 = Sq, Skv
    if Sq % bq:
        q = jnp.pad(q, ((0, 0), (0, bq - Sq % bq), (0, 0), (0, 0)))
    if Skv % bkv:
        pad = bkv - Skv % bkv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kv_len = Skv0 if k.shape[1] != Skv0 else None
    return q, k, v, bq, bkv, Sq0, Skv0, kv_len


def _attn_fwd_impl(q, k, v, causal, window, q_offset, block_q, block_kv,
                   softcap, unroll, kv_offset):
    """Returns (out (B,Sq,Hq,dh), lse (B,Sq,Hq) f32)."""
    q, k, v, bq, bkv, Sq0, Skv0, kv_len = _pad_blocks(q, k, v, block_q,
                                                      block_kv)
    B, Sq, Hq, dh = q.shape
    Skv = k.shape[1]
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = dh ** -0.5
    nq, nkv = Sq // bq, Skv // bkv
    qg = q.reshape(B, Sq, Hkv, G, dh)

    def one_q_block(iq):
        qb = lax.dynamic_slice_in_dim(qg, iq * bq, bq, axis=1)
        q_pos = q_offset + iq * bq + jnp.arange(bq)

        def kv_step(carry, jk):
            m, l, acc = carry
            kb = lax.dynamic_slice_in_dim(k, jk * bkv, bkv, axis=1)
            vb = lax.dynamic_slice_in_dim(v, jk * bkv, bkv, axis=1)
            kv_pos = kv_offset + jk * bkv + jnp.arange(bkv)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            if softcap:
                s = softcap * jnp.tanh(s / softcap)
            mask = _attn_mask(q_pos, kv_pos, causal=causal, window=window,
                              kv_len=kv_len)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb, preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, bq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, bq, dh), jnp.float32)
        if unroll:
            carry = (m0, l0, a0)
            for jk in range(nkv):
                carry, _ = kv_step(carry, jk)
            m, l, acc = carry
        else:
            (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(nkv))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        # (B, Hkv, G, bq, [dh]) -> (B, bq, Hq, [dh])
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, bq, Hq, dh)
        lse = lse.transpose(0, 3, 1, 2).reshape(B, bq, Hq)
        return out.astype(q.dtype), lse

    if unroll:
        blocks = [one_q_block(i) for i in range(nq)]
        out = jnp.concatenate([b[0] for b in blocks], axis=1) \
            if nq > 1 else blocks[0][0]
        lse = jnp.concatenate([b[1] for b in blocks], axis=1) \
            if nq > 1 else blocks[0][1]
    else:
        outs, lses = lax.map(one_q_block, jnp.arange(nq))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, Hq, dh)
        lse = lses.transpose(1, 0, 2, 3).reshape(B, Sq, Hq)
    if Sq != Sq0:
        out, lse = out[:, :Sq0], lse[:, :Sq0]
    return out, lse


def _attn_bwd_impl(q, k, v, lse, delta, g, causal, window, q_offset,
                   block_q, block_kv, softcap, unroll, kv_offset):
    """Flash backward: scan q blocks, accumulate dk/dv, emit dq blocks."""
    in_dtype = q.dtype
    q, k, v, bq, bkv, Sq0, Skv0, kv_len = _pad_blocks(q, k, v, block_q,
                                                      block_kv)
    B, Sq, Hq, dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = dh ** -0.5
    nq, nkv = Sq // bq, Skv // bkv

    def pad_q(x):
        return jnp.pad(x, ((0, 0), (0, Sq - Sq0)) + ((0, 0),) * (x.ndim - 2)) \
            if Sq != Sq0 else x

    qg = q.reshape(B, Sq, Hkv, G, dh)
    gg = pad_q(g).reshape(B, Sq, Hkv, G, dh)
    lseg = pad_q(lse).reshape(B, Sq, Hkv, G)
    deltag = pad_q(delta).reshape(B, Sq, Hkv, G)

    def q_block(carry, iq):
        dk_acc, dv_acc = carry
        qb = lax.dynamic_slice_in_dim(qg, iq * bq, bq, axis=1)
        gb = lax.dynamic_slice_in_dim(gg, iq * bq, bq, axis=1).astype(jnp.float32)
        lb = lax.dynamic_slice_in_dim(lseg, iq * bq, bq, axis=1)
        db = lax.dynamic_slice_in_dim(deltag, iq * bq, bq, axis=1)
        q_pos = q_offset + iq * bq + jnp.arange(bq)
        # (B,bq,Hkv,G) -> (B,Hkv,G,bq)
        lb = lb.transpose(0, 2, 3, 1)
        db = db.transpose(0, 2, 3, 1)

        def kv_step(inner, jk):
            dk_a, dv_a, dq_blk = inner
            kb = lax.dynamic_slice_in_dim(k, jk * bkv, bkv, axis=1)
            vb = lax.dynamic_slice_in_dim(v, jk * bkv, bkv, axis=1)
            kv_pos = kv_offset + jk * bkv + jnp.arange(bkv)
            s_raw = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                               preferred_element_type=jnp.float32) * scale
            if softcap:
                th = jnp.tanh(s_raw / softcap)
                s = softcap * th
                dsoft = 1.0 - jnp.square(th)
            else:
                s = s_raw
                dsoft = None
            mask = _attn_mask(q_pos, kv_pos, causal=causal, window=window,
                              kv_len=kv_len)
            p = jnp.where(mask[None, None, None],
                          jnp.exp(s - lb[..., None]), 0.0)
            dv_new = jnp.einsum("bhgqk,bqhgd->bkhd", p, gb,
                                preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", gb, vb,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - db[..., None]) * scale
            if dsoft is not None:
                ds = ds * dsoft
            dq_new = jnp.einsum("bhgqk,bkhd->bqhgd", ds.astype(kb.dtype), kb,
                                preferred_element_type=jnp.float32)
            dk_new = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qb,
                                preferred_element_type=jnp.float32)
            dk_a = lax.dynamic_update_slice_in_dim(
                dk_a, lax.dynamic_slice_in_dim(dk_a, jk * bkv, bkv, 1)
                + dk_new, jk * bkv, axis=1)
            dv_a = lax.dynamic_update_slice_in_dim(
                dv_a, lax.dynamic_slice_in_dim(dv_a, jk * bkv, bkv, 1)
                + dv_new, jk * bkv, axis=1)
            return (dk_a, dv_a, dq_blk + dq_new), None

        dq0 = jnp.zeros((B, bq, Hkv, G, dh), jnp.float32)
        if unroll:
            inner = (dk_acc, dv_acc, dq0)
            for jk in range(nkv):
                inner, _ = kv_step(inner, jk)
            dk_acc, dv_acc, dq_blk = inner
        else:
            (dk_acc, dv_acc, dq_blk), _ = lax.scan(
                kv_step, (dk_acc, dv_acc, dq0), jnp.arange(nkv))
        return (dk_acc, dv_acc), dq_blk

    dk0 = jnp.zeros((B, Skv, Hkv, dh), jnp.float32)
    dv0 = jnp.zeros((B, Skv, Hkv, dh), jnp.float32)
    if unroll:
        carry = (dk0, dv0)
        dqs = []
        for iq in range(nq):
            carry, dq_blk = q_block(carry, iq)
            dqs.append(dq_blk)
        dq = jnp.concatenate(dqs, axis=1) if nq > 1 else dqs[0]
        dk, dv = carry
    else:
        (dk, dv), dqs = lax.scan(q_block, (dk0, dv0), jnp.arange(nq))
        dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hkv, G, dh)
    dq = dq.reshape(B, Sq, Hq, dh)[:, :Sq0]
    dk = dk[:, :Skv0]
    dv = dv[:, :Skv0]
    return dq.astype(in_dtype), dk.astype(in_dtype), dv.astype(in_dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10))
def _attn_vjp(q, k, v, causal, window, q_offset, block_q, block_kv, softcap,
              unroll, kv_offset):
    return _attn_fwd_impl(q, k, v, causal, window, q_offset, block_q,
                          block_kv, softcap, unroll, kv_offset)


def _attn_vjp_fwd(q, k, v, causal, window, q_offset, block_q, block_kv,
                  softcap, unroll, kv_offset):
    out, lse = _attn_fwd_impl(q, k, v, causal, window, q_offset, block_q,
                              block_kv, softcap, unroll, kv_offset)
    return (out, lse), (q, k, v, out, lse)


def _attn_vjp_bwd(causal, window, q_offset, block_q, block_kv, softcap,
                  unroll, kv_offset, res, cts):
    q, k, v, out, lse = res
    g, _ = cts
    delta = (out.astype(jnp.float32) * g.astype(jnp.float32)).sum(-1)
    dq, dk, dv = _attn_bwd_impl(q, k, v, lse, delta, g, causal, window,
                                q_offset, block_q, block_kv, softcap,
                                unroll, kv_offset)
    return dq, dk, dv


_attn_vjp.defvjp(_attn_vjp_fwd, _attn_vjp_bwd)


def decode_attention(q, k_cache, v_cache, kv_positions, q_pos, *,
                     window=0, softcap=0.0):
    """Single-token attention over a (ring-buffer) cache.

    q: (B, 1, Hq, dh); caches: (B, W, Hkv, dh); kv_positions: (B, W) actual
    absolute positions stored in each slot (negative = empty); q_pos: (B,).
    """
    B, _, Hq, dh = q.shape
    _, W, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = dh ** -0.5
    qg = q.reshape(B, Hkv, G, dh)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    valid = (kv_positions >= 0) & (kv_positions <= q_pos[:, None])
    if window:
        valid &= kv_positions > (q_pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, dh).astype(q.dtype)


def chunk_attention(q, k_new, v_new, k_cache, v_cache, pos, n_tokens, *,
                    window=0, softcap=0.0, kernel="dense", block_q=32,
                    block_kv=32, interpret=None):
    """Multi-token chunk attention over a ring cache: ONE fused score
    computation instead of C sequential decode steps.

    q/k_new/v_new: (B, C, H*, dh) the chunk's projections; k_cache/v_cache:
    (B, W, Hkv, dh) the ring BEFORE the chunk is written; pos: (B,)
    absolute position of chunk token 0; n_tokens: (B,) in [0, C].

    Query t (position pos+t) attends jointly over [prior ring, chunk keys
    t' <= t] under one softmax.  Scoring the prior ring *pre-write* is what
    makes this exact: a per-token scan would let query t read a slot that a
    LATER chunk token t' > t has not yet overwritten, and that slot
    (position pos+t'-W) is inside t's window — so the fused form must score
    the old contents, not the post-write ring.  Masked entries (idle slots,
    short chunks, out-of-window) go to NEG_INF; a fully-masked row (idle
    stream) degrades to a uniform softmax whose output is discarded.

    ``kernel`` selects the score computation: "dense" materializes the
    (B, H, C, W+C) block below (the reference, priced by
    ``costmodel.prefill_chunk_score_bytes``); "blocked" streams KV in
    (block_q, block_kv) tiles through the Pallas online-softmax kernel
    (``kernels.flash_attention.ops.ring_chunk_attention``) so the live
    transient never exceeds one tile.  Both are exact for chunks wider
    than the ring (C > W): intra-chunk self-eviction is the same band
    test ``kv > q - W`` that evicts prior-ring entries.
    """
    if kernel == "blocked":
        from repro.kernels.flash_attention.ops import ring_chunk_attention
        return ring_chunk_attention(
            q, k_new, v_new, k_cache, v_cache, pos, n_tokens,
            window=window, softcap=softcap, block_q=block_q,
            block_kv=block_kv, interpret=interpret)
    if kernel != "dense":
        raise ValueError(f"unknown chunk kernel {kernel!r}: "
                         "expected 'dense' or 'blocked'")
    B, C, Hq, dh = q.shape
    W, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = dh ** -0.5
    qg = q.reshape(B, C, Hkv, G, dh)
    t = jnp.arange(C)
    q_pos = pos[:, None] + t[None, :]                       # (B, C)
    # prior ring: positions held BEFORE the chunk (pos-1 = last written)
    kv_pos = cache_positions(pos - 1, W)                    # (B, W)
    s_prior = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache,
                         preferred_element_type=jnp.float32) * scale
    s_chunk = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_new,
                         preferred_element_type=jnp.float32) * scale
    if softcap:
        s_prior = softcap * jnp.tanh(s_prior / softcap)
        s_chunk = softcap * jnp.tanh(s_chunk / softcap)
    # the ring width is an IMPLICIT window: sequential stepping overwrites
    # position p-W when writing p, so query t must not see prior entries
    # at kv_pos <= q_pos - W that its own chunk's earlier tokens would
    # already have evicted (exact match with ring-eviction semantics even
    # for full-attention models whose context exceeds the ring)
    vp = (kv_pos[:, None, :] >= 0) \
        & (kv_pos[:, None, :] <= q_pos[:, :, None]) \
        & (kv_pos[:, None, :] > q_pos[:, :, None] - W)
    vc = (t[None, :] <= t[:, None])[None] \
        & (t[None, None, :] < n_tokens[:, None, None])
    # intra-chunk self-eviction: with C > W, chunk token t' <= t - W has
    # been overwritten (by t'+W <= t) before query t runs sequentially —
    # vacuously true when C <= W, the same band as the ring mask above
    vc &= (t[None, :] > t[:, None] - W)[None]
    if window:
        vp &= kv_pos[:, None, :] > q_pos[:, :, None] - window
        vc &= (t[None, :] > t[:, None] - window)[None]
    s_prior = jnp.where(vp[:, None, None], s_prior, NEG_INF)
    s_chunk = jnp.where(vc[:, None, None], s_chunk, NEG_INF)
    s = jnp.concatenate([s_prior, s_chunk], axis=-1)        # (B,Hkv,G,C,W+C)
    p = jax.nn.softmax(s, axis=-1)
    vcat = jnp.concatenate([v_cache, v_new], axis=1)        # (B, W+C, Hkv, dh)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vcat,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, C, Hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Ring-buffer KV cache helpers
# ---------------------------------------------------------------------------

def cache_update(k_cache, v_cache, k_new, v_new, pos):
    """Write one token at ring slot pos % W, per batch element.

    caches: (B, W, Hkv, dh); k_new/v_new: (B, 1, Hkv, dh); pos: (B,).
    """
    W = k_cache.shape[1]
    slot = pos % W

    def upd(c, x, s):
        return lax.dynamic_update_slice_in_dim(c, x, s, axis=0)

    k_cache = jax.vmap(upd)(k_cache, k_new, slot)
    v_cache = jax.vmap(upd)(v_cache, v_new, slot)
    return k_cache, v_cache


def cache_update_chunk(k_cache, v_cache, k_new, v_new, pos, n_tokens):
    """Write up to C tokens per stream at ring slots (pos+t) % W, masked.

    caches: (B, W, Hkv, dh); k_new/v_new: (B, C, Hkv, dh); pos: (B,)
    position of chunk token 0; n_tokens: (B,) in [0, C] — tokens past a
    stream's count leave their slot untouched, so idle and short-chunk
    streams leave the ring as-is.  Works for ANY chunk width, including
    C > W: sequential stepping writes tokens in order, so when several
    chunk tokens map to one slot the LAST active one (largest t < n with
    t % W == (slot - pos) % W) survives — expressed here as a per-slot
    gather instead of a scatter, which would need ordered duplicate-index
    semantics XLA does not guarantee.
    """
    B, C = k_new.shape[:2]
    W = k_cache.shape[1]
    s_idx = jnp.arange(W)[None, :]                          # (1, W)
    t0 = (s_idx - pos[:, None]) % W                         # (B, W)
    # largest active chunk token landing on each slot (last write wins);
    # candidates are t0, t0+W, t0+2W, ... — none active iff t0 >= n
    kmax = (n_tokens[:, None] - 1 - t0) // W
    t_star = t0 + W * kmax                                  # (B, W)
    written = t0 < n_tokens[:, None]
    src = jnp.clip(t_star, 0, C - 1)

    def upd(c, new, sl, wr):
        g = jnp.take(new, sl, axis=0)                       # (W, Hkv, dh)
        return jnp.where(wr[:, None, None], g, c)

    k_cache = jax.vmap(upd)(k_cache, k_new, src, written)
    v_cache = jax.vmap(upd)(v_cache, v_new, src, written)
    return k_cache, v_cache


def cache_positions(pos, W):
    """Absolute position stored at each ring slot after writing ``pos``.

    pos: (B,) current (just-written) position.  Slot s holds the largest
    p <= pos with p % W == s; slots never written hold negative values.
    """
    slots = jnp.arange(W)
    p = pos[:, None] - ((pos[:, None] - slots[None, :]) % W)
    return p  # negative where never written


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_apply(x, params, activation: str):
    """params: {wi: (D, F) or (D, 2F) for GLU, wo: (F, D)}."""
    if activation in ("swiglu", "gelu_glu", "relu_glu"):
        h = jnp.einsum("bsd,dtf->bstf", x,
                       params["wi"],
                       preferred_element_type=jnp.float32)
        gate, up = h[..., 0, :], h[..., 1, :]
        if activation == "swiglu":
            act = jax.nn.silu(gate)
        elif activation == "gelu_glu":
            act = jax.nn.gelu(gate, approximate=True)
        else:
            act = jax.nn.relu(gate)
        h = (act * up).astype(x.dtype)
    else:
        h = jnp.einsum("bsd,df->bsf", x, params["wi"],
                       preferred_element_type=jnp.float32)
        if activation == "squared_relu":
            h = jnp.square(jax.nn.relu(h))
        elif activation == "gelu":
            h = jax.nn.gelu(h, approximate=True)
        else:
            raise ValueError(activation)
        h = h.astype(x.dtype)
    # bf16 output: the TP all-reduce of this partial sum carries bf16
    return jnp.einsum("bsf,fd->bsd", h, params["wo"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Causal depthwise conv (SSM front-ends)
# ---------------------------------------------------------------------------

def causal_conv1d(x, w, bias=None):
    """x: (B, S, C); w: (K, C) depthwise causal conv along S."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        out = out + pad[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def conv1d_step(x_t, conv_state, w, bias=None):
    """One decode step.  x_t: (B, C); conv_state: (B, K-1, C) past inputs."""
    K = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,C)
    out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32))
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    new_state = window[:, 1:, :]
    return out.astype(x_t.dtype), new_state
