"""Model substrate: pure-JAX layer definitions for all assigned families."""
