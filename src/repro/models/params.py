"""Parameter definitions: shapes, logical sharding axes, and initializers.

A single source of truth (``model_def``) yields:
  * ``init_params(cfg, key)``      — concrete arrays (smoke tests, examples)
  * ``abstract_params(cfg)``       — ShapeDtypeStructs (dry-run, no allocation)
  * ``logical_axes(cfg)``          — pytree of logical-axis tuples, mapped to
                                     mesh axes by ``repro.launch.sharding``.

Logical axis names: "vocab", "embed", "heads", "kv_heads", "head_dim", "ff",
"expert", "lru", "ssd_inner", "ssd_bc", "ssd_heads".  ``None`` = replicated.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "fan_in"       # fan_in | zeros | ones | const:<v> | normal:<std>

    def stacked(self, n: int) -> "ParamDef":
        return ParamDef((n,) + self.shape, ("layer",) + self.axes, self.init)


def _attn_def(cfg: ModelConfig) -> Dict[str, ParamDef]:
    D, Hq, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": ParamDef((D, Hq, dh), ("embed", "heads", None)),
        "wk": ParamDef((D, Hkv, dh), ("embed", "kv_heads", None)),
        "wv": ParamDef((D, Hkv, dh), ("embed", "kv_heads", None)),
        "wo": ParamDef((Hq, dh, D), ("heads", None, "embed")),
    }


def _mlp_def(cfg: ModelConfig) -> Dict[str, ParamDef]:
    D, F = cfg.d_model, cfg.d_ff
    glu = cfg.activation in ("swiglu", "gelu_glu", "relu_glu")
    wi = ParamDef((D, 2, F), ("embed", None, "ff")) if glu else \
        ParamDef((D, F), ("embed", "ff"))
    return {"wi": wi, "wo": ParamDef((F, D), ("ff", "embed"))}


def _moe_def(cfg: ModelConfig) -> Dict[str, ParamDef]:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    glu = cfg.activation in ("swiglu", "gelu_glu", "relu_glu")
    wi = ParamDef((E, D, 2, F), ("expert", "embed", None, "ff")) if glu else \
        ParamDef((E, D, F), ("expert", "embed", "ff"))
    return {
        "router": ParamDef((D, E), ("embed", None)),
        "wi": wi,
        "wo": ParamDef((E, F, D), ("expert", "ff", "embed")),
    }


def _rglru_def(cfg: ModelConfig) -> Dict[str, ParamDef]:
    D, W, K = cfg.d_model, cfg.lru_width, cfg.conv_width
    return {
        "w_gate": ParamDef((D, W), ("embed", "lru")),
        "w_in": ParamDef((D, W), ("embed", "lru")),
        "conv_w": ParamDef((K, W), (None, "lru"), "normal:0.05"),
        "conv_b": ParamDef((W,), ("lru",), "zeros"),
        "w_a": ParamDef((W, W), (None, "lru"), "normal:0.01"),
        "w_x": ParamDef((W, W), (None, "lru"), "normal:0.01"),
        "lam": ParamDef((W,), ("lru",), "const:-5.0"),
        "w_out": ParamDef((W, D), ("lru", "embed")),
    }


def _ssd_def(cfg: ModelConfig) -> Dict[str, ParamDef]:
    D, di, K = cfg.d_model, cfg.d_inner, cfg.conv_width
    GN, H = cfg.ssm_groups * cfg.ssm_state, cfg.ssm_heads
    return {
        "wz": ParamDef((D, di), ("embed", "ssd_inner")),
        "wx": ParamDef((D, di), ("embed", "ssd_inner")),
        "wB": ParamDef((D, GN), ("embed", "ssd_bc")),
        "wC": ParamDef((D, GN), ("embed", "ssd_bc")),
        "wdt": ParamDef((D, H), ("embed", "ssd_heads")),
        "conv_x": ParamDef((K, di), (None, "ssd_inner"), "normal:0.05"),
        "bx": ParamDef((di,), ("ssd_inner",), "zeros"),
        "conv_B": ParamDef((K, GN), (None, "ssd_bc"), "normal:0.05"),
        "bB": ParamDef((GN,), ("ssd_bc",), "zeros"),
        "conv_C": ParamDef((K, GN), (None, "ssd_bc"), "normal:0.05"),
        "bC": ParamDef((GN,), ("ssd_bc",), "zeros"),
        "A_log": ParamDef((H,), ("ssd_heads",), "const:0.0"),
        "dt_bias": ParamDef((H,), ("ssd_heads",), "const:-2.0"),
        "D_skip": ParamDef((H,), ("ssd_heads",), "ones"),
        "norm": ParamDef((di,), ("ssd_inner",), "ones"),
        "out_proj": ParamDef((di, D), ("ssd_inner", "embed")),
    }


def layer_def(cfg: ModelConfig, layer_type: str) -> Dict:
    D = cfg.d_model
    ln = lambda: ParamDef((D,), (None,), "ones")
    if layer_type == "attn":
        ffn = {"moe": _moe_def(cfg)} if cfg.n_experts else {"mlp": _mlp_def(cfg)}
        return {"ln1": ln(), "attn": _attn_def(cfg), "ln2": ln(), **ffn}
    if layer_type == "rec":
        return {"ln1": ln(), "rec": _rglru_def(cfg), "ln2": ln(),
                "mlp": _mlp_def(cfg)}
    if layer_type == "ssd":
        return {"ln": ln(), "ssd": _ssd_def(cfg)}
    if layer_type == "enc":
        return {"ln1": ln(), "attn": _attn_def(cfg), "ln2": ln(),
                "mlp": _mlp_def(cfg)}
    if layer_type == "dec":
        return {"ln1": ln(), "attn": _attn_def(cfg),
                "ln2": ln(), "cross": _attn_def(cfg),
                "ln3": ln(), "mlp": _mlp_def(cfg)}
    raise ValueError(layer_type)


def _stack_def(d, n: int):
    return jax.tree.map(lambda p: p.stacked(n), d,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def hybrid_structure(cfg: ModelConfig):
    """(group pattern, n_groups, tail layer types) for pattern-based models."""
    types = cfg.layer_types()
    period = len(cfg.block_pattern)
    n_groups = cfg.n_layers // period
    tail = types[n_groups * period:]
    return cfg.block_pattern, n_groups, tail


def model_def(cfg: ModelConfig) -> Dict:
    D, V = cfg.d_model, cfg.vocab_padded
    out: Dict = {
        "embed": ParamDef((V, D), ("vocab", None), "normal:0.02"),
        "final_norm": ParamDef((D,), (None,), "ones"),
    }
    if not cfg.tie_embeddings:
        out["head"] = ParamDef((D, V), (None, "vocab"))

    if cfg.family == "encdec":
        out["enc_layers"] = _stack_def(layer_def(cfg, "enc"), cfg.enc_layers)
        out["dec_layers"] = _stack_def(layer_def(cfg, "dec"), cfg.dec_layers)
        out["enc_norm"] = ParamDef((D,), (None,), "ones")
        return out

    if cfg.block_pattern:
        pattern, n_groups, tail = hybrid_structure(cfg)
        group = {f"b{i}_{t}": layer_def(cfg, t) for i, t in enumerate(pattern)}
        out["groups"] = _stack_def(group, n_groups)
        out["tail"] = {f"t{i}_{t}": layer_def(cfg, t) for i, t in enumerate(tail)}
        return out

    lt = cfg.layer_types()[0]
    out["layers"] = _stack_def(layer_def(cfg, lt), cfg.n_layers)
    return out


# ---------------------------------------------------------------------------
# Materializers
# ---------------------------------------------------------------------------

def _is_def(x):
    return isinstance(x, ParamDef)


def _init_leaf(p: ParamDef, key, dtype):
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init.startswith("const:"):
        return jnp.full(p.shape, float(p.init.split(":")[1]), dtype)
    if p.init.startswith("normal:"):
        std = float(p.init.split(":")[1])
    else:  # fan_in
        fan_in = p.shape[0] if len(p.shape) == 1 else int(
            math.prod(p.shape[:-1]) if p.axes[-1] == "embed" else p.shape[0])
        # For projection tensors (D, ...out) fan-in is the first dim.
        fan_in = p.shape[0] if len(p.shape) >= 2 else p.shape[0]
        if len(p.shape) >= 3 and p.axes[0] == "expert":
            fan_in = p.shape[1]
        std = fan_in ** -0.5
    return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(dtype)


def init_params(cfg: ModelConfig, key) -> Dict:
    defs = model_def(cfg)
    dtype = jnp.dtype(cfg.param_dtype)
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(p, k, dtype) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(cfg: ModelConfig) -> Dict:
    defs = model_def(cfg)
    dtype = jnp.dtype(cfg.param_dtype)
    return jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, dtype),
                        defs, is_leaf=_is_def)


def logical_axes(cfg: ModelConfig) -> Dict:
    defs = model_def(cfg)
    return jax.tree.map(lambda p: p.axes, defs, is_leaf=_is_def)


def param_bytes(cfg: ModelConfig) -> int:
    defs = model_def(cfg)
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    return sum(math.prod(p.shape) * itemsize
               for p in jax.tree.leaves(defs, is_leaf=_is_def))


def n_params(cfg: ModelConfig) -> int:
    defs = model_def(cfg)
    return sum(math.prod(p.shape)
               for p in jax.tree.leaves(defs, is_leaf=_is_def))
