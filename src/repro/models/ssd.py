"""Mamba2 SSD (state-space duality) block.

TPU adaptation: the SSD *chunked* form recasts the selective-scan recurrence
as dense per-chunk matmuls (MXU-friendly) plus a cheap inter-chunk scan —
exactly the "compact compute, bounded state" structure ARCAS favors.  The
naive per-timestep recurrence lives in ``repro/kernels/ssd_scan/ref.py`` as
the oracle; this module implements the chunked jnp algorithm used by the
models, and the Pallas kernel mirrors the same blocking on TPU.

Projections are kept as separate matrices (not one packed in_proj) so each
can carry its own PartitionSpec without shard-boundary misalignment.

params (per layer):
  wz, wx: (D, di)     wB, wC: (D, G*N)     wdt: (D, H)
  conv_x: (K, di) + bx,  conv_B/conv_C: (K, G*N) + bB/bC
  A_log: (H,)   dt_bias: (H,)   D_skip: (H,)
  norm: (di,)   out_proj: (di, D)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import causal_conv1d, conv1d_step, rms_norm


def segsum(a):
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} a[..., k] (i>=j)."""
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B_, C_, *, chunk: int, unroll: bool = False,
                initial_state=None):
    """Chunked SSD.  x: (B,S,H,P); dt: (B,S,H); A: (H,); B_/C_: (B,S,G,N).

    Returns y: (B,S,H,P) and final state (B,H,P,N).  Math in f32.
    ``initial_state`` seeds the inter-chunk recurrence (serving chunk
    steps resume from a carried state; None = zeros, the prefill case).
    """
    Bb, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = H // G

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(B_.astype(jnp.float32), rep, axis=2)   # (B,S,H,N)
    Cf = jnp.repeat(C_.astype(jnp.float32), rep, axis=2)

    a = dtf * A.astype(jnp.float32)[None, None, :]          # (B,S,H) log-decay
    xdt = xf * dtf[..., None]                               # dt-weighted input

    def r(t):  # (B,S,...) -> (B,nc,chunk,...)
        return t.reshape((Bb, nc, chunk) + t.shape[2:])

    xc, ac, Bc, Cc = r(xdt), r(a), r(Bf), r(Cf)

    # --- intra-chunk (dense, MXU) ---
    L = jnp.exp(segsum(ac.transpose(0, 1, 3, 2)))           # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc)       # (B,nc,H,Q,Q)
    y_intra = jnp.einsum("bchqk,bchqk,bckhp->bcqhp", scores, L, xc)

    # --- per-chunk end states ---
    a_cum = jnp.cumsum(ac, axis=2)                          # (B,nc,Q,H) inclusive
    a_tot = a_cum[:, :, -1, :]                              # (B,nc,H)
    decay_to_end = jnp.exp(a_tot[:, :, None, :] - a_cum)    # (B,nc,Q,H)
    S_c = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bc, decay_to_end, xc)

    # --- inter-chunk recurrence (tiny scan over nc) ---
    def step(h, inp):
        s_c, atot = inp
        h_new = h * jnp.exp(atot)[..., None, None] + s_c
        return h_new, h                                     # emit state *before* chunk

    h0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((Bb, H, P, N), jnp.float32))
    if unroll:
        hs, h = [], h0
        for c in range(nc):
            h, prev = step(h, (S_c[:, c], a_tot[:, c]))
            hs.append(prev)
        h_prev = jnp.stack(hs, axis=1)
        h_final = h
    else:
        h_final, h_prev = lax.scan(
            step, h0, (S_c.transpose(1, 0, 2, 3, 4), a_tot.transpose(1, 0, 2)))
        h_prev = h_prev.transpose(1, 0, 2, 3, 4)            # (B,nc,H,P,N)

    # --- inter-chunk output ---
    decay_from_start = jnp.exp(a_cum)                       # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp", Cc, decay_from_start, h_prev)

    y = (y_intra + y_inter).reshape(Bb, S, H, P)
    return y.astype(x.dtype), h_final


def _proj_conv(x, w, conv_w, conv_b, K):
    """Returns (activated conv output, pre-conv tail for decode state)."""
    h = jnp.einsum("bsd,dk->bsk", x, w,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    tail = h[:, -(K - 1):, :]
    h = causal_conv1d(h, conv_w, conv_b)
    return jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype), tail


def ssd_block_apply(x, params, cfg, *, unroll=False):
    """Full Mamba2 block (train/prefill).  x: (B, S, D) -> (B, S, D)."""
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    Bb, S, _ = x.shape
    K = cfg.conv_width
    z = jnp.einsum("bsd,dk->bsk", x, params["wz"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    xs, tail_x = _proj_conv(x, params["wx"], params["conv_x"], params["bx"], K)
    B_, tail_B = _proj_conv(x, params["wB"], params["conv_B"], params["bB"], K)
    C_, tail_C = _proj_conv(x, params["wC"], params["conv_C"], params["bC"], K)
    dtr = jnp.einsum("bsd,dh->bsh", x, params["wdt"],
                     preferred_element_type=jnp.float32)
    xs = xs.reshape(Bb, S, H, P)
    B_ = B_.reshape(Bb, S, G, N)
    C_ = C_.reshape(Bb, S, G, N)
    dtv = jax.nn.softplus(dtr + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    chunk = min(cfg.ssd_chunk, S)
    pad = (-S) % chunk
    if pad:  # front-pad: zero inputs add nothing to the state (exact)
        xs = jnp.pad(xs, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        dtv = jnp.pad(dtv, ((0, 0), (pad, 0), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    if cfg.use_pallas:
        from repro.kernels.ssd_scan.ops import ssd_with_state
        y, state = ssd_with_state(xs, dtv, A, B_, C_, chunk=chunk)
        y = y.astype(x.dtype)
    else:
        y, state = ssd_chunked(xs, dtv, A, B_, C_, chunk=chunk, unroll=unroll)
    if pad:
        y = y[:, pad:]
        xs = xs[:, pad:]
    y = y + xs * params["D_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(Bb, S, di)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    full_state = {"ssm": state, "conv_x": tail_x, "conv_B": tail_B,
                  "conv_C": tail_C}
    return out, full_state


def ssd_init_state(cfg, batch, dtype=jnp.float32):
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    K = cfg.conv_width
    return {
        "ssm": jnp.zeros((batch, H, cfg.ssm_head_dim, N), jnp.float32),
        "conv_x": jnp.zeros((batch, K - 1, di), dtype),
        "conv_B": jnp.zeros((batch, K - 1, G * N), dtype),
        "conv_C": jnp.zeros((batch, K - 1, G * N), dtype),
    }


def ssd_chunk_step(x, params, cfg, state, n_tokens):
    """Multi-token chunk step from a CARRIED state (serving fused prefill).

    x: (B, C, D); state from ``ssd_init_state``; n_tokens: (B,) in [0, C]
    (active tokens are a prefix).  Runs the same chunked SSD form as
    ``ssd_block_apply`` — dense per-chunk matmuls + the tiny inter-chunk
    scan — but seeded with the carried SSM state and with the three conv
    front-ends resumed from their carried tails.  Inactive tokens are
    masked via dt=0 (decay 1, zero input), so the final state equals the
    state after each stream's last active token; front-padding to the SSD
    chunk multiple is exact for the same reason.  Uses the jnp path (the
    Pallas kernel has no initial-state entry point; serving chunks are
    small).
    """
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    Bb, C, _ = x.shape
    K = cfg.conv_width
    active = jnp.arange(C)[None, :] < n_tokens[:, None]
    z = jnp.einsum("bsd,dk->bsk", x, params["wz"],
                   preferred_element_type=jnp.float32).astype(x.dtype)

    def piece(w, conv_w, conv_b, st):
        h = jnp.einsum("bsd,dk->bsk", x, w,
                       preferred_element_type=jnp.float32).astype(x.dtype)
        ext = jnp.concatenate([st, h], axis=1)          # (B, K-1+C, k)
        idx = n_tokens[:, None] + jnp.arange(K - 1)[None, :]
        tail = jnp.take_along_axis(ext, idx[:, :, None], axis=1)
        hc = causal_conv1d(ext, conv_w, conv_b)[:, K - 1:]
        return jax.nn.silu(hc.astype(jnp.float32)).astype(x.dtype), tail

    xs, cx = piece(params["wx"], params["conv_x"], params["bx"],
                   state["conv_x"])
    B_, cb = piece(params["wB"], params["conv_B"], params["bB"],
                   state["conv_B"])
    C_, cc = piece(params["wC"], params["conv_C"], params["bC"],
                   state["conv_C"])
    dtr = jnp.einsum("bsd,dh->bsh", x, params["wdt"],
                     preferred_element_type=jnp.float32)
    dtv = jax.nn.softplus(dtr + params["dt_bias"].astype(jnp.float32))
    dtv = jnp.where(active[..., None], dtv, 0.0)        # identity step
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xs = xs.reshape(Bb, C, H, P)
    B_ = B_.reshape(Bb, C, G, N)
    C_ = C_.reshape(Bb, C, G, N)
    chunk = min(cfg.ssd_chunk, C)
    pad = (-C) % chunk
    if pad:  # front-pad with dt=0 steps: state passes through unchanged
        xs = jnp.pad(xs, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        dtv = jnp.pad(dtv, ((0, 0), (pad, 0), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    y, h = ssd_chunked(xs, dtv, A, B_, C_, chunk=chunk,
                       initial_state=state["ssm"])
    if pad:
        y = y[:, pad:]
        xs = xs[:, pad:]
    y = y.astype(jnp.float32) + xs.astype(jnp.float32) \
        * params["D_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(Bb, C, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, {"ssm": h, "conv_x": cx, "conv_B": cb, "conv_C": cc}


def ssd_decode_step(x_t, params, cfg, state):
    """One decode step.  x_t: (B, 1, D); state from ``ssd_init_state``."""
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    Bb = x_t.shape[0]
    xt = x_t[:, 0]
    z = jnp.einsum("bd,dk->bk", xt, params["wz"],
                   preferred_element_type=jnp.float32).astype(x_t.dtype)

    def piece(w, conv_w, conv_b, st):
        h = jnp.einsum("bd,dk->bk", xt, w,
                       preferred_element_type=jnp.float32).astype(x_t.dtype)
        h, new_st = conv1d_step(h, st, conv_w, conv_b)
        return jax.nn.silu(h.astype(jnp.float32)).astype(x_t.dtype), new_st

    xs, cx = piece(params["wx"], params["conv_x"], params["bx"], state["conv_x"])
    B_, cb = piece(params["wB"], params["conv_B"], params["bB"], state["conv_B"])
    C_, cc = piece(params["wC"], params["conv_C"], params["bC"], state["conv_C"])
    dtr = jnp.einsum("bd,dh->bh", xt, params["wdt"],
                     preferred_element_type=jnp.float32)
    dtv = jax.nn.softplus(dtr + params["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xs = xs.reshape(Bb, H, P)
    B_ = jnp.repeat(B_.reshape(Bb, G, N), H // G, axis=1)
    C_ = jnp.repeat(C_.reshape(Bb, G, N), H // G, axis=1)
    decay = jnp.exp(dtv * A[None, :])                                   # (B,H)
    ssm = state["ssm"] * decay[..., None, None] + jnp.einsum(
        "bhn,bhp,bh->bhpn", B_.astype(jnp.float32), xs.astype(jnp.float32), dtv)
    y = jnp.einsum("bhn,bhpn->bhp", C_.astype(jnp.float32), ssm)
    y = y + xs.astype(jnp.float32) * params["D_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(Bb, di).astype(x_t.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x_t.dtype)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    out = jnp.einsum("bk,kd->bd", y, params["out_proj"],
                     preferred_element_type=jnp.float32).astype(x_t.dtype)
    return out[:, None, :], {"ssm": ssm, "conv_x": cx, "conv_B": cb, "conv_C": cc}
