"""Model forward / loss / prefill / decode for every assigned family.

Layer stacks run under ``jax.lax.scan`` with stacked parameters (small HLO,
fast SPMD compiles).  Hybrid (RecurrentGemma) models scan over repeating
*groups* of blocks plus an unrolled tail; enc-dec models scan each stack.

The cross-entropy loss is computed in sequence chunks so the (B, S, vocab)
logits tensor is never materialized.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssd as ssd_mod
from repro.models.params import hybrid_structure

LOSS_CHUNK = 1024


def cdt(cfg):
    return jnp.dtype(cfg.compute_dtype)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens):
    e = jnp.take(params["embed"], tokens, axis=0)
    return e.astype(cdt(cfg))


def head_logits(params, cfg: ModelConfig, x):
    """x: (..., D) -> f32 logits (..., V)."""
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["embed"],
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["head"],
                            preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    if cfg.vocab_padded != cfg.vocab:   # mask pad columns (never predicted)
        pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(pad_mask, logits, jnp.float32(-1e30))
    return logits


# ---------------------------------------------------------------------------
# Blocks (full-sequence)
# ---------------------------------------------------------------------------

def _attn_proj(x, p, rope, *, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if rope is not None:
        cos, sin = rope
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    return q, k, v


def _attn_out(o, p, dtype):
    # no f32 preferred type: the cross-shard TP all-reduce of this partial
    # sum should carry bf16 (the MXU still accumulates f32 per shard)
    return jnp.einsum("bshk,hkd->bsd", o.astype(dtype), p["wo"]).astype(dtype)


def _pad_head_groups(q, Hkv, pad_to):
    """Pad Q heads per KV group so total heads divide the model axis.

    24 heads on a 16-wide model axis replicate the ENTIRE attention on
    every shard (measured 16x wasted FLOPs on llama3.2-3b prefill); padding
    each GQA group with zero heads (sliced off after attention) makes heads
    shardable at +33% attention FLOPs -> net ~12x.
    """
    B, S, Hq, dh = q.shape
    if not pad_to or Hq % pad_to == 0:
        return q, Hq
    G = Hq // Hkv
    Gp = G
    while (Hkv * Gp) % pad_to:
        Gp += 1
    qg = q.reshape(B, S, Hkv, G, dh)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, Gp - G), (0, 0)))
    return qg.reshape(B, S, Hkv * Gp, dh), Hq


def _shard_padded_heads(q, cfg):
    """Pin the padded head dim to the model axis (needs mesh context)."""
    from jax.sharding import PartitionSpec as P
    try:
        return lax.with_sharding_constraint(
            q, P(cfg.batch_axes, None, "model", None))
    except Exception:        # no mesh context (single-device tests)
        return q


def _unpad_heads(o, Hkv, Hq, Hq_padded):
    if Hq_padded == Hq:
        return o
    B, S, _, dh = o.shape
    G, Gp = Hq // Hkv, Hq_padded // Hkv
    og = o.reshape(B, S, Hkv, Gp, dh)[:, :, :, :G]
    return og.reshape(B, S, Hq, dh)


def attn_block(x, p, cfg: ModelConfig, rope, *, causal=True, window=0,
               unroll=False, kv=None):
    """Self- (kv=None) or cross- (kv=(K,V) precomputed) attention."""
    q, k, v = _attn_proj(x, p, rope if kv is None else None, cfg=cfg)
    if kv is not None:
        k, v = kv
        if rope is not None:
            cos, sin = rope
            q = L.apply_rope(q, cos, sin)
    Hq = q.shape[2]
    q, Hq_real = _pad_head_groups(q, k.shape[2], cfg.head_pad_to)
    if q.shape[2] != Hq_real:
        q = _shard_padded_heads(q, cfg)
    if cfg.use_pallas:
        from repro.kernels.flash_attention.ops import flash_attention
        o = flash_attention(q, k, v, causal, window,
                            min(cfg.attn_block_q, q.shape[1]),
                            min(cfg.attn_block_kv, k.shape[1]))
    else:
        o = L.blocked_attention(
            q, k, v, causal=causal, window=window,
            block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
            unroll=unroll)
    o = _unpad_heads(o, k.shape[2], Hq_real, q.shape[2])
    return _attn_out(o, p, x.dtype), (k, v)


def _ffn(x, lp, cfg: ModelConfig, unroll=False, dropless=False):
    if "moe" in lp:
        return moe_mod.moe_apply(x, lp["moe"], cfg, unroll=unroll,
                                 dropless=dropless)
    return L.mlp_apply(x, lp["mlp"], cfg.activation), {}


def apply_layer(x, lp, cfg: ModelConfig, layer_type: str, rope, *,
                window=0, unroll=False, causal=True):
    """One block (full-seq).  Returns (x, state_for_decode, aux)."""
    aux = {}
    if layer_type in ("attn", "enc"):
        a, (k, v) = attn_block(L.rms_norm(x, lp["ln1"], cfg.norm_eps), lp["attn"],
                               cfg, rope, causal=causal, window=window,
                               unroll=unroll)
        h = x + a
        f, aux = _ffn(L.rms_norm(h, lp["ln2"], cfg.norm_eps), lp, cfg,
                      unroll=unroll)
        return h + f, {"k": k, "v": v}, aux
    if layer_type == "rec":
        r, state = rglru_mod.rglru_block_apply(
            L.rms_norm(x, lp["ln1"], cfg.norm_eps), lp["rec"], cfg,
            unroll=unroll)
        h = x + r
        f, aux = _ffn(L.rms_norm(h, lp["ln2"], cfg.norm_eps), lp, cfg,
                      unroll=unroll)
        return h + f, state, aux
    if layer_type == "ssd":
        s, state = ssd_mod.ssd_block_apply(
            L.rms_norm(x, lp["ln"], cfg.norm_eps), lp["ssd"], cfg,
            unroll=unroll)
        return x + s, state, aux
    raise ValueError(layer_type)


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


def _wsc_tree(lp, specs):
    """Constrain (GSPMD mode: PartitionSpec leaves) or explicitly gather
    (shard_map mode: callable leaves) a layer-param subtree."""
    if specs is None:
        return lp

    def apply(w, s):
        return s(w) if callable(s) else lax.with_sharding_constraint(w, s)

    return jax.tree.map(apply, lp, specs)


def _seq_gather(x, cfg: ModelConfig):
    """Explicit all-gather of the seq axis at layer entry (SP discipline).

    Without this pin, GSPMD may resolve the seq-sharded carry by
    replicating the *batch* axis instead (observed: a 17 GB fully
    replicated attention operand).
    """
    if not cfg.seq_shard:
        return x
    from jax.sharding import PartitionSpec as P
    return lax.with_sharding_constraint(x, P(cfg.batch_axes, None, None))


def _seq_constrain(x, cfg: ModelConfig):
    """Shard the saved residual stream over 'model' along the seq axis.

    Megatron-SP for the scan carry: the only tensor checkpointed per layer
    under remat is x (B, S, D); constraining its S axis to the model axis
    cuts saved-activation memory by the TP degree.  GSPMD inserts the
    all-gather at the next layer's first use.
    """
    if not cfg.seq_shard:
        return x
    from jax.sharding import PartitionSpec as P
    return lax.with_sharding_constraint(x, P(cfg.batch_axes, "model", None))


# ---------------------------------------------------------------------------
# Forward (decoder-only + VLM)
# ---------------------------------------------------------------------------

def _rope_for(cfg: ModelConfig, positions, extras):
    if cfg.rope_type == "none":
        return None
    if cfg.rope_type == "mrope":
        pid = extras["position_ids"]          # (3, B, S)
        return L.mrope_tables(pid, cfg.head_dim, cfg.rope_theta,
                              cfg.mrope_sections)
    return L.rope_tables(positions, cfg.head_dim, cfg.rope_theta)


def _merge_vlm(params, cfg: ModelConfig, tokens, extras):
    """VLM stub frontend: concat precomputed patch embeds + text embeds."""
    ve = extras["vision_embeds"].astype(cdt(cfg))       # (B, Sv, D)
    te = embed_tokens(params, cfg, tokens)              # (B, St, D)
    return jnp.concatenate([ve, te], axis=1)


def forward(params, cfg: ModelConfig, tokens, extras=None, *, unroll=False,
            return_states=False, gather_specs=None, state_fn=None):
    """Full-sequence forward to final hidden states (B, S, D).

    ``state_fn(state, layer_type)`` transforms per-layer decode states
    BEFORE they are stacked by the scan — prefill passes the ring-arrange
    so sliding-window caches never stack the full sequence.
    """
    sfn = state_fn or (lambda s, t: s)
    extras = extras or {}
    if cfg.family == "vlm":
        x = _merge_vlm(params, cfg, tokens, extras)
    else:
        x = embed_tokens(params, cfg, tokens)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None, :] * jnp.ones((B, 1), jnp.int32)
    rope = _rope_for(cfg, positions, extras)

    states = {}
    if cfg.family == "encdec":
        raise ValueError("use encdec_forward")
    if cfg.block_pattern:
        pattern, n_groups, tail = hybrid_structure(cfg)

        def group_body(x, gp):
            x = _seq_gather(x, cfg)
            gp = _wsc_tree(gp, gather_specs and gather_specs.get("groups"))
            aux_t = jnp.zeros((), jnp.float32)
            st = {}
            for i, t in enumerate(pattern):
                w = cfg.local_window if t == "attn" else 0
                x, s, aux = apply_layer(x, gp[f"b{i}_{t}"], cfg, t, rope,
                                        window=w, unroll=unroll)
                st[f"b{i}_{t}"] = sfn(s, t) if return_states else s
                aux_t = aux_t + aux.get("lb_loss", 0.0)
            ys = (st, aux_t) if return_states else ({}, aux_t)
            return _seq_constrain(x, cfg), ys

        body = _maybe_remat(group_body, cfg)
        x, (gstates, gaux) = lax.scan(body, x, params["groups"])
        aux_total = gaux.sum()
        tail_states = {}
        for name, lp in params["tail"].items():
            t = name.split("_", 1)[1]
            w = cfg.local_window if t == "attn" else 0
            x, s, aux = apply_layer(x, lp, cfg, t, rope, window=w,
                                    unroll=unroll)
            tail_states[name] = sfn(s, t) if return_states else s
            aux_total = aux_total + aux.get("lb_loss", 0.0)
        states = {"groups": gstates, "tail": tail_states}
    else:
        lt = cfg.layer_types()[0]
        window = cfg.window if lt == "attn" else 0

        def layer_body(x, lp):
            x = _seq_gather(x, cfg)
            lp = _wsc_tree(lp, gather_specs and gather_specs.get("layers"))
            x, s, aux = apply_layer(x, lp, cfg, lt, rope, window=window,
                                    unroll=unroll)
            s = sfn(s, lt) if return_states else {}
            return _seq_constrain(x, cfg), (s, aux.get("lb_loss",
                                                       jnp.zeros((), jnp.float32)))

        body = _maybe_remat(layer_body, cfg)
        if unroll:
            sts, auxs = [], []
            xcur = x
            nl = cfg.n_layers
            for i in range(nl):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                xcur, (s, a) = layer_body(xcur, lp)
                sts.append(s); auxs.append(a)
            x = xcur
            states = {"layers": jax.tree.map(lambda *xs: jnp.stack(xs), *sts)}
            aux_total = jnp.stack(auxs).sum()
        else:
            x, (lstates, laux) = lax.scan(body, x, params["layers"])
            states = {"layers": lstates}
            aux_total = laux.sum()

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_states:
        return x, states, aux_total
    return x, aux_total


# ---------------------------------------------------------------------------
# Encoder-decoder
# ---------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frame_embeds, *, unroll=False,
           gather_specs=None):
    """frame_embeds: (B, S_src, D) precomputed by the stub frontend."""
    x = frame_embeds.astype(cdt(cfg))
    B, S = x.shape[:2]
    positions = jnp.arange(S)[None, :] * jnp.ones((B, 1), jnp.int32)
    rope = _rope_for(cfg, positions, {})

    def body(x, lp):
        x = _seq_gather(x, cfg)
        lp = _wsc_tree(lp, gather_specs and gather_specs.get("enc_layers"))
        x, _, _ = apply_layer(x, lp, cfg, "enc", rope, causal=False,
                              unroll=unroll)
        return _seq_constrain(x, cfg), None

    x, _ = lax.scan(_maybe_remat(body, cfg), x, params["enc_layers"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decoder_forward(params, cfg: ModelConfig, tokens, enc_out, *,
                    unroll=False, return_states=False, gather_specs=None):
    x = embed_tokens(params, cfg, tokens)
    B, S = x.shape[:2]
    positions = jnp.arange(S)[None, :] * jnp.ones((B, 1), jnp.int32)
    rope = _rope_for(cfg, positions, {})

    def body_states(x, lp):
        a, (sk, sv) = attn_block(L.rms_norm(x, lp["ln1"], cfg.norm_eps),
                                 lp["attn"], cfg, rope, causal=True,
                                 unroll=unroll)
        h = x + a
        cq = jnp.einsum("bsd,dhk->bshk", L.rms_norm(h, lp["ln2"], cfg.norm_eps),
                        lp["cross"]["wq"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
        ck = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wk"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
        cv = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wv"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
        co = L.blocked_attention(cq, ck, cv, causal=False,
                                 block_q=cfg.attn_block_q,
                                 block_kv=cfg.attn_block_kv, unroll=unroll)
        h = h + _attn_out(co, lp["cross"], x.dtype)
        f, _ = _ffn(L.rms_norm(h, lp["ln3"], cfg.norm_eps), lp, cfg,
                    unroll=unroll)
        return h + f, {"k": sk, "v": sv, "ck": ck, "cv": cv}

    def body(x, lp):
        x = _seq_gather(x, cfg)
        lp = _wsc_tree(lp, gather_specs and gather_specs.get("dec_layers"))
        a, _ = attn_block(L.rms_norm(x, lp["ln1"], cfg.norm_eps), lp["attn"],
                          cfg, rope, causal=True, unroll=unroll)
        h = x + a
        cq = jnp.einsum("bsd,dhk->bshk", L.rms_norm(h, lp["ln2"], cfg.norm_eps),
                        lp["cross"]["wq"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
        ck = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wk"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
        cv = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wv"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
        co = L.blocked_attention(cq, ck, cv, causal=False,
                                 block_q=cfg.attn_block_q,
                                 block_kv=cfg.attn_block_kv, unroll=unroll)
        h = h + _attn_out(co, lp["cross"], x.dtype)
        f, _ = _ffn(L.rms_norm(h, lp["ln3"], cfg.norm_eps), lp, cfg,
                    unroll=unroll)
        return _seq_constrain(h + f, cfg), None

    if return_states:
        x, states = lax.scan(body_states, x, params["dec_layers"])
        return L.rms_norm(x, params["final_norm"], cfg.norm_eps), states
    x, _ = lax.scan(_maybe_remat(body, cfg), x, params["dec_layers"])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def encdec_forward(params, cfg: ModelConfig, tokens, extras, *, unroll=False,
                   gather_specs=None):
    enc_out = encode(params, cfg, extras["frame_embeds"], unroll=unroll,
                     gather_specs=gather_specs)
    x = decoder_forward(params, cfg, tokens, enc_out, unroll=unroll,
                        gather_specs=gather_specs)
    return x, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Loss (chunked cross-entropy)
# ---------------------------------------------------------------------------

def chunked_ce_loss(params, cfg: ModelConfig, x, targets, mask, *,
                    unroll=False):
    """x: (B,S,D) final hiddens; never materializes (B,S,V)."""
    B, S, D = x.shape
    chunk = min(LOSS_CHUNK, S)
    assert S % chunk == 0
    nch = S // chunk
    xr = x.reshape(B, nch, chunk, D).transpose(1, 0, 2, 3)
    tr = targets.reshape(B, nch, chunk).transpose(1, 0, 2)
    mr = mask.reshape(B, nch, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        xc, tc, mc = inp
        logits = head_logits(params, cfg, xc)                 # (B,chunk,V) f32
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * mc
        return (carry[0] + nll.sum(), carry[1] + mc.sum()), None

    init = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    if unroll:
        carry = init
        for i in range(nch):
            carry, _ = body(carry, (xr[i], tr[i], mr[i]))
    else:
        carry, _ = lax.scan(body, init, (xr, tr, mr))
    total, count = carry
    return total / jnp.maximum(count, 1.0)


def loss_fn(params, cfg: ModelConfig, batch, *, unroll=False,
            aux_weight: float = 0.01, gather_specs=None):
    """batch: tokens/targets/mask (+ per-family extras)."""
    extras = {k: v for k, v in batch.items()
              if k not in ("tokens", "targets", "mask")}
    if cfg.family == "encdec":
        x, aux = encdec_forward(params, cfg, batch["tokens"], extras,
                                unroll=unroll, gather_specs=gather_specs)
    else:
        x, aux = forward(params, cfg, batch["tokens"], extras, unroll=unroll,
                         gather_specs=gather_specs)
    ce = chunked_ce_loss(params, cfg, x, batch["targets"], batch["mask"],
                         unroll=unroll)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}
