"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Recurrence (per channel):
    r_t = sigmoid(x_t @ W_a)            # recurrence gate
    i_t = sigmoid(x_t @ W_x)            # input gate
    log_a_t = -c * softplus(Lambda) * r_t
    h_t = exp(log_a_t) * h_{t-1} + sqrt(1 - exp(2*log_a_t)) * (i_t * x_t)

Train/prefill uses an associative scan (log-depth; the Pallas kernel in
``repro/kernels/rglru_scan`` implements the chunked sequential-parallel
version for TPU).  Decode is a single fused step.

params (per recurrent layer):
  w_gate:  (D, W)          # gelu branch
  w_in:    (D, W)          # recurrence branch in-projection
  conv_w:  (K, W), conv_b: (W,)
  w_a:     (W, W), w_x: (W, W)
  lam:     (W,)            # Lambda (softplus-parameterized decay)
  w_out:   (W, D)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import causal_conv1d, conv1d_step

RG_LRU_C = 8.0


def _gates(u, params):
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u.astype(jnp.float32),
                                  params["w_a"].astype(jnp.float32)))
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u.astype(jnp.float32),
                                  params["w_x"].astype(jnp.float32)))
    log_a = -RG_LRU_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    return log_a, i


def rglru_scan_ref(u, log_a, i_gate):
    """Associative scan over time.  u: (B,S,W) f32; returns h (B,S,W) f32."""
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 0.0))
    b = beta * (i_gate * u)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_init_state(cfg, batch, dtype=jnp.float32):
    W, K = cfg.lru_width, cfg.conv_width
    return {"h": jnp.zeros((batch, W), jnp.float32),
            "conv": jnp.zeros((batch, K - 1, W), dtype)}


def rglru_block_apply(x, params, cfg, *, unroll=False):
    """Full recurrent block.  x: (B,S,D) -> (B,S,D), state for decode."""
    K = cfg.conv_width
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, params["w_gate"],
                   preferred_element_type=jnp.float32), approximate=True)
    u_raw = jnp.einsum("bsd,dw->bsw", x, params["w_in"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    u = causal_conv1d(u_raw, params["conv_w"], params["conv_b"])
    log_a, i_gate = _gates(u, params)
    if cfg.use_pallas:
        from repro.kernels.rglru_scan.ops import lru
        a = jnp.exp(log_a)
        beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 0.0))
        b = beta * (i_gate * u.astype(jnp.float32))
        h = lru(a, b)
    else:
        h = rglru_scan_ref(u.astype(jnp.float32), log_a, i_gate)
    out = (h * gate).astype(x.dtype)
    state = {"h": h[:, -1, :],
             "conv": u_raw[:, -(K - 1):, :].astype(x.dtype)}
    y = jnp.einsum("bsw,wd->bsd", out, params["w_out"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y, state


def rglru_chunk_step(x, params, cfg, state, n_tokens):
    """Multi-token chunk step from a CARRIED state (serving fused prefill).

    x: (B, C, D); state as in ``rglru_decode_step``; n_tokens: (B,) in
    [0, C] — active tokens are a prefix of the chunk.  The recurrence is
    the same associative scan ``rglru_block_apply`` uses, seeded with the
    carried ``h`` as a virtual timestep (a=1, b=h0); inactive tokens are
    forced to identity (log_a=0 -> a=1, beta=0) so the final carry equals
    the state after each stream's LAST active token, and the conv state is
    gathered at the per-stream active length.  One layer pass for C tokens
    instead of C sequential ``rglru_decode_step`` calls.
    """
    B, C, _ = x.shape
    K = cfg.conv_width
    active = jnp.arange(C)[None, :] < n_tokens[:, None]         # (B, C)
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, params["w_gate"],
                   preferred_element_type=jnp.float32), approximate=True)
    u_raw = jnp.einsum("bsd,dw->bsw", x, params["w_in"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    # conv over [carried K-1 inputs, chunk]: each chunk position sees the
    # true K-token history, exactly like C conv1d_step calls
    ext = jnp.concatenate([state["conv"], u_raw], axis=1)       # (B, K-1+C, W)
    u = causal_conv1d(ext, params["conv_w"], params["conv_b"])[:, K - 1:]
    log_a, i_gate = _gates(u, params)
    log_a = jnp.where(active[..., None], log_a, 0.0)            # identity step
    i_gate = jnp.where(active[..., None], i_gate, 0.0)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 0.0))
    b = beta * (i_gate * u.astype(jnp.float32))
    a_ext = jnp.concatenate([jnp.ones_like(b[:, :1]), a], axis=1)
    b_ext = jnp.concatenate([state["h"][:, None, :], b], axis=1)

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    _, h_ext = lax.associative_scan(combine, (a_ext, b_ext), axis=1)
    h = h_ext[:, 1:]                                            # (B, C, W) f32
    out = (h * gate).astype(x.dtype)
    y = jnp.einsum("bsw,wd->bsd", out, params["w_out"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    # conv carry = last K-1 inputs of [old state, active prefix]
    idx = n_tokens[:, None] + jnp.arange(K - 1)[None, :]        # (B, K-1)
    new_conv = jnp.take_along_axis(ext, idx[:, :, None], axis=1)
    return y, {"h": h[:, -1], "conv": new_conv}


def rglru_decode_step(x_t, params, cfg, state):
    """x_t: (B,1,D); state: {"h": (B,W) f32, "conv": (B,K-1,W)}."""
    gate = jax.nn.gelu(
        jnp.einsum("bd,dw->bw", x_t[:, 0], params["w_gate"],
                   preferred_element_type=jnp.float32), approximate=True)
    u = jnp.einsum("bd,dw->bw", x_t[:, 0], params["w_in"],
                   preferred_element_type=jnp.float32).astype(x_t.dtype)
    u, conv_state = conv1d_step(u, state["conv"], params["conv_w"],
                                params["conv_b"])
    log_a, i_gate = _gates(u, params)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 0.0))
    h = a * state["h"] + beta * (i_gate * u.astype(jnp.float32))
    out = (h * gate).astype(x_t.dtype)
    y = jnp.einsum("bw,wd->bd", out, params["w_out"],
                   preferred_element_type=jnp.float32).astype(x_t.dtype)
    return y[:, None, :], {"h": h, "conv": conv_state}
