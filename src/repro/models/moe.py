"""Mixture-of-Experts layer, TPU-native.

The paper's lesson (adapted): keep dispatch as dense, MXU-friendly einsums
rather than a GPU-style scatter/sort.  Tokens are processed in fixed-size
blocks (``cfg.moe_block``) so the one-hot dispatch tensors stay small and the
working set per step is bounded (the ARCAS "LocalCache" discipline applied to
VMEM/HBM).

params:
  router: (D, E)
  wi:     (E, D, 2, F) for GLU activations, else (E, D, F)
  wo:     (E, F, D)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _expert_ffn(xin, params, activation: str):
    """xin: (B, E, C, D) -> (B, E, C, D) (weights broadcast over batch)."""
    if activation in ("swiglu", "gelu_glu", "relu_glu"):
        h = jnp.einsum("becd,edtf->bectf", xin, params["wi"],
                       preferred_element_type=jnp.float32)
        gate, up = h[..., 0, :], h[..., 1, :]
        if activation == "swiglu":
            act = jax.nn.silu(gate)
        elif activation == "gelu_glu":
            act = jax.nn.gelu(gate, approximate=True)
        else:
            act = jax.nn.relu(gate)
        h = (act * up).astype(xin.dtype)
    else:
        h = jnp.einsum("becd,edf->becf", xin, params["wi"],
                       preferred_element_type=jnp.float32)
        if activation == "squared_relu":
            h = jnp.square(jax.nn.relu(h))
        else:
            h = jax.nn.gelu(h, approximate=True)
        h = h.astype(xin.dtype)
    return jnp.einsum("becf,efd->becd", h, params["wo"]).astype(xin.dtype)


def moe_block_apply(xblk, params, *, n_experts: int, top_k: int,
                    capacity_factor: float, activation: str,
                    dropless: bool = False):
    """One token block, batched form.  xblk: (B, T, D) -> (B, T, D), aux.

    Routing/dispatch/combine keep the BATCH dimension: every einsum either
    contracts an unsharded dim (t, d) or batches over b, so under MANUAL
    data parallelism (shard_map) the whole dispatch is shard-local.  Under
    plain GSPMD this form makes the per-block expert weight-gradient psum
    explicit (worse); use the flattened form there (cfg.moe_batched=False).

    ``dropless=True`` sets capacity = T (serving semantics: no token drops,
    at the cost of reading every expert — the right trade at decode batch
    sizes, where expert weights dominate HBM traffic anyway).
    """
    B, T, D = xblk.shape
    E, K = n_experts, top_k
    C = T if dropless else int(max(1, (T * K * capacity_factor) // E))
    C = min(C, T)

    logits = jnp.einsum("btd,de->bte", xblk, params["router"],
                        preferred_element_type=jnp.float32)
    gates_all = jax.nn.softmax(logits, axis=-1)            # (B, T, E) f32
    top_vals, top_idx = lax.top_k(logits, K)               # (B, T, K)
    top_gates = jax.nn.softmax(top_vals, axis=-1)          # renormalized over K

    # position of each (token, k) claim within its expert's capacity
    sel = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)    # (B, T, K, E)
    flat = sel.reshape(B, T * K, E)                        # claims in (t, k) order
    pos = jnp.cumsum(flat, axis=1) - flat                  # (B, T*K, E)
    pos = jnp.einsum("bxe,bxe->bx", pos, flat).reshape(B, T, K)
    keep = (pos < C).astype(jnp.float32)

    # combine[b, t, e, c] = gate weight of token t at expert e, slot c
    slot_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)
    combine = jnp.einsum("btk,btke,btkc->btec",
                         top_gates * keep, sel, slot_oh)   # (B, T, E, C)
    dispatch = (combine > 0).astype(xblk.dtype)

    # bf16 dispatch: entries are one-hot selections, exact in bf16; the
    # flattened form's cross-shard psum of xin then carries half the bytes
    xin = jnp.einsum("btec,btd->becd", dispatch, xblk).astype(xblk.dtype)
    y = _expert_ffn(xin, params, activation)
    out = jnp.einsum("btec,becd->btd", combine.astype(xblk.dtype), y,
                     preferred_element_type=jnp.float32).astype(xblk.dtype)

    # Switch-style load-balance auxiliary loss terms
    me = gates_all.mean(axis=(0, 1))                       # (E,)
    ce = sel.sum(axis=2).mean(axis=(0, 1))                 # fraction routed
    aux = {"lb_loss": E * jnp.sum(me * ce),
           "dropped": 1.0 - keep.mean()}
    return out, aux


def moe_block_apply_flat(xblk, params, *, n_experts: int, top_k: int,
                         capacity_factor: float, activation: str,
                         dropless: bool = False):
    """Flattened-token form: xblk (B, T, D) -> routing over B*T jointly.

    GSPMD default: the expert weight gradients are computed redundantly per
    shard (no explicit per-block psum), which the partitioner handles far
    better than the batched form's per-block (E,C,D) reductions.
    """
    B, T, D = xblk.shape
    y, aux = moe_block_apply(
        xblk.reshape(1, B * T, D), params, n_experts=n_experts, top_k=top_k,
        capacity_factor=capacity_factor, activation=activation,
        dropless=dropless)
    return y.reshape(B, T, D), aux


def moe_apply(x, params, cfg, *, unroll=False, dropless=False):
    """x: (B, S, D) -> (B, S, D).  Scans blocks of ~cfg.moe_block tokens.

    Blocks are cut along the SEQUENCE axis (seq-block x full batch), never
    along the batch axis: the scan slices its xs dim 0, and slicing a
    data-sharded dimension forces GSPMD into involuntary replication of the
    whole token stream (observed on grok-1: 13 GB/device).  The sequence
    axis is unsharded, so scanning seq blocks keeps tokens batch-sharded.
    """
    B, S, D = x.shape
    blk_s = max(1, min(max(1, cfg.moe_block // B), S))
    S_pad = ((S + blk_s - 1) // blk_s) * blk_s
    if S_pad != S:  # pad sequence; padded outputs discarded
        x = jnp.pad(x, ((0, 0), (0, S_pad - S), (0, 0)))
    nb = S_pad // blk_s
    # (B, nb, blk_s, D) -> (nb, B, blk_s, D): scan over UNSHARDED seq blocks
    xt = x.reshape(B, nb, blk_s, D).transpose(1, 0, 2, 3)

    # nested remat: without it, vjp-of-scan stores every block's dispatch/
    # combine tensors (f32, stacked over blocks) before the backward sweep
    apply = moe_block_apply if cfg.moe_batched else moe_block_apply_flat

    @jax.checkpoint
    def block_fn(xb, params):
        y, aux = apply(
            xb, params, n_experts=cfg.n_experts,
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            activation=cfg.activation, dropless=dropless)
        return y, aux

    def body(_, xb):
        y, aux = block_fn(xb, params)
        return None, (y, aux["lb_loss"], aux["dropped"])

    if unroll or nb == 1:
        ys, lbs, drops = [], [], []
        for i in range(nb):
            _, (y, lb, dr) = body(None, xt[i])
            ys.append(y); lbs.append(lb); drops.append(dr)
        y = jnp.stack(ys)
        lb = jnp.stack(lbs).mean()
        dropped = jnp.stack(drops).mean()
    else:
        _, (y, lb, dropped) = lax.scan(body, None, xt)
        lb, dropped = lb.mean(), dropped.mean()
    y = y.transpose(1, 0, 2, 3).reshape(B, S_pad, D)[:, :S]
    return y, {"lb_loss": lb, "dropped": dropped}
