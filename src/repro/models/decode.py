"""Autoregressive serving path: cache init, prefill, single-token decode.

Caches use ring buffers of width W = min(max_len, attention window), so
sliding-window / recurrent / SSM architectures serve 500k+ contexts with a
bounded working set — the property that makes their ``long_500k`` cells
runnable (and the ARCAS "compact" policy attractive for them).

Cache pytrees mirror the parameter stacking so layer loops are
``lax.scan``s over (stacked params, stacked cache).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssd as ssd_mod
from repro.models.params import hybrid_structure
from repro.models.transformer import (
    _attn_out, _attn_proj, _ffn, cdt, embed_tokens, forward, head_logits,
    _rope_for)


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def _attn_cache_width(cfg: ModelConfig, max_len: int, layer_type="attn",
                      hybrid=False) -> int:
    w = cfg.local_window if hybrid else cfg.window
    return min(max_len, w) if w else max_len


def _attn_cache(cfg: ModelConfig, B: int, W: int):
    dtype = cdt(cfg)
    shape = (B, W, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _layer_cache(cfg: ModelConfig, lt: str, B: int, max_len: int,
                 hybrid=False):
    if lt == "attn":
        return _attn_cache(cfg, B, _attn_cache_width(cfg, max_len, hybrid=hybrid))
    if lt == "rec":
        return rglru_mod.rglru_init_state(cfg, B, cdt(cfg))
    if lt == "ssd":
        return ssd_mod.ssd_init_state(cfg, B, cdt(cfg))
    raise ValueError(lt)


def _stack_cache(c, n):
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), c)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               src_len: int = 0) -> Dict:
    """Zero cache for ``batch`` streams with context capacity ``max_len``."""
    if cfg.family == "encdec":
        self_c = _stack_cache(_attn_cache(cfg, batch, max_len), cfg.dec_layers)
        dt = cdt(cfg)
        cshape = (cfg.dec_layers, batch, src_len, cfg.n_kv_heads, cfg.head_dim)
        return {"self": self_c,
                "cross_k": jnp.zeros(cshape, dt),
                "cross_v": jnp.zeros(cshape, dt)}
    if cfg.block_pattern:
        pattern, n_groups, tail = hybrid_structure(cfg)
        group = {f"b{i}_{t}": _layer_cache(cfg, t, batch, max_len, hybrid=True)
                 for i, t in enumerate(pattern)}
        return {"groups": _stack_cache(group, n_groups),
                "tail": {f"t{i}_{t}": _layer_cache(cfg, t, batch, max_len,
                                                   hybrid=True)
                         for i, t in enumerate(tail)}}
    lt = cfg.layer_types()[0]
    return {"layers": _stack_cache(_layer_cache(cfg, lt, batch, max_len),
                                   cfg.n_layers)}


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int,
                   src_len: int = 0):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, src_len))


# ---------------------------------------------------------------------------
# Paged cache views (the KV block pool's device-side layout)
# ---------------------------------------------------------------------------
#
# A *block pool* stores the same pytree structure as ``init_cache`` but with
# the stream axis replaced by a physical-block axis:
#
#   token leaves  (.., B, W, rest)  ->  (.., n_blocks, block_tokens, rest)
#   state leaves  (.., B, rest)     ->  (.., n_states, rest)
#
# A stream is then a *block table* — ``W / block_tokens`` physical block ids
# (its ring-buffer pages, in ring order) plus one state slot — and the
# batched cache the decode step consumes is materialized by gathering the
# active streams' tables into a (.., B, W, rest) view and scattered back
# after the step.  Leaf classification is structural: a leaf whose shape
# changes with ``max_len`` has a token (ring) axis; one whose shape only
# changes with ``batch`` is per-stream state (recurrent/SSD states, enc-dec
# cross-attention KV).

@dataclasses.dataclass(frozen=True)
class CacheLeafSpec:
    batch_axis: int
    token_axis: Optional[int]       # None = per-stream state leaf
    width: int                      # ring width at the probed max_len (tokens)


@dataclasses.dataclass(frozen=True)
class CacheViewSpec:
    """Per-leaf layout of the serving cache, in ``jax.tree`` leaf order."""
    leaves: Tuple[CacheLeafSpec, ...]
    treedef: Any
    width: int                      # shared ring width of all token leaves

    @property
    def has_token_leaves(self) -> bool:
        return any(s.token_axis is not None for s in self.leaves)


def cache_view_specs(cfg: ModelConfig, max_len: int,
                     src_len: int = 0) -> CacheViewSpec:
    """Classify every cache leaf by probing ``init_cache`` shapes.

    Probes with batch 1 vs 2 locate the stream axis; probes with max_len 1
    vs 2 locate the token (ring) axis.  Token axes are required to sit
    immediately after the stream axis (true for every family) so a gathered
    (block, token) pair can be reshaped into the contiguous (B, W) view.
    """
    b1 = jax.tree.leaves(abstract_cache(cfg, 1, max_len, src_len))
    b2 = jax.tree.leaves(abstract_cache(cfg, 2, max_len, src_len))
    t1, tdef = jax.tree.flatten(abstract_cache(cfg, 1, 1, src_len))
    t2 = jax.tree.leaves(abstract_cache(cfg, 1, 2, src_len))
    specs = []
    for lb1, lb2, lt1, lt2 in zip(b1, b2, t1, t2):
        baxes = [i for i, (a, b) in enumerate(zip(lb1.shape, lb2.shape))
                 if a != b]
        assert len(baxes) == 1, f"ambiguous stream axis: {lb1.shape}"
        taxes = [i for i, (a, b) in enumerate(zip(lt1.shape, lt2.shape))
                 if a != b]
        assert len(taxes) <= 1, f"ambiguous token axis: {lt1.shape}"
        tax = taxes[0] if taxes else None
        if tax is not None:
            assert tax == baxes[0] + 1, \
                f"token axis must follow stream axis: {lb1.shape}"
        width = lb1.shape[tax] if tax is not None else 0
        specs.append(CacheLeafSpec(baxes[0], tax, width))
    widths = {s.width for s in specs if s.token_axis is not None}
    assert len(widths) <= 1, f"token leaves disagree on ring width: {widths}"
    return CacheViewSpec(tuple(specs), tdef,
                         widths.pop() if widths else 0)


def init_block_pool(cfg: ModelConfig, spec: CacheViewSpec, n_blocks: int,
                    n_states: int, block_tokens: int, max_len: int,
                    src_len: int = 0):
    """Zeroed physical storage for ``n_blocks`` KV pages + ``n_states``
    per-stream state slots (index 0 of each is the engine's null slot)."""
    base = jax.tree.leaves(abstract_cache(cfg, 1, max_len, src_len))
    out = []
    for leaf, s in zip(base, spec.leaves):
        if s.token_axis is not None:
            shape = (leaf.shape[:s.batch_axis] + (n_blocks, block_tokens)
                     + leaf.shape[s.token_axis + 1:])
        else:
            shape = (leaf.shape[:s.batch_axis] + (n_states,)
                     + leaf.shape[s.batch_axis + 1:])
        out.append(jnp.zeros(shape, leaf.dtype))
    return jax.tree.unflatten(spec.treedef, out)


def gather_cache_view(pool, spec: CacheViewSpec, tables, state_slots):
    """Materialize the batched cache for ``decode_step``.

    tables: (B, P) int32 physical block ids (ring order, null-padded);
    state_slots: (B,) int32 state slot ids.  Returns a cache pytree shaped
    exactly like ``init_cache(cfg, B, max_len)``.
    """
    B, P = tables.shape
    flat = tables.reshape(-1)
    out = []
    for leaf, s in zip(jax.tree.leaves(pool), spec.leaves):
        ax = s.batch_axis
        if s.token_axis is None:
            out.append(jnp.take(leaf, state_slots, axis=ax))
            continue
        bt = leaf.shape[ax + 1]
        g = jnp.take(leaf, flat, axis=ax)            # (.., B*P, bt, rest)
        shape = leaf.shape[:ax] + (B, P * bt) + leaf.shape[ax + 2:]
        out.append(g.reshape(shape))
    return jax.tree.unflatten(spec.treedef, out)


def scatter_cache_view(pool, spec: CacheViewSpec, tables, state_slots, view):
    """Write a (possibly updated) batched cache view back into the pool.

    Inverse of ``gather_cache_view``: each stream's W-token ring is split
    back into P pages and written to its table's physical blocks.  Streams
    MAY share real blocks (prefix-shared pages, refcount > 1) only under
    the pool's copy-on-write invariant — a shared page is never written by
    the model step (the engine forks it first), so the duplicate scatter
    indices all carry the page's unchanged gathered bytes and last-write-
    wins is exact.  Null-padded table entries all point at the engine's
    null block, whose contents are never read.
    """
    B, P = tables.shape
    flat = tables.reshape(-1)
    out = []
    for leaf, vleaf, s in zip(jax.tree.leaves(pool), jax.tree.leaves(view),
                              spec.leaves):
        ax = s.batch_axis
        idx = (slice(None),) * ax
        if s.token_axis is None:
            out.append(leaf.at[idx + (state_slots,)].set(vleaf))
            continue
        bt = leaf.shape[ax + 1]
        shape = vleaf.shape[:ax] + (B * P, bt) + vleaf.shape[ax + 2:]
        out.append(leaf.at[idx + (flat,)].set(vleaf.reshape(shape)))
    return jax.tree.unflatten(spec.treedef, out)


def copy_pool_entries(pool, spec: CacheViewSpec, src_blocks, dst_blocks,
                      src_state=None, dst_state=None):
    """Copy physical pages (and optionally a state slot) inside the pool —
    the device-side half of a cross-domain block migration.

    The block lists are padded to a pow-2 bucket with null-block
    self-copies (block 0 -> block 0, bit-identical values, so duplicate
    scatter indices are exact regardless of write order): migrations and
    prefix forks copy arbitrary page counts, and an unbucketed gather/
    scatter dispatches a fresh XLA module per distinct count."""
    src_blocks, dst_blocks = list(src_blocks), list(dst_blocks)
    if src_blocks:
        bucket = 1 << (len(src_blocks) - 1).bit_length()
        pad = bucket - len(src_blocks)
        src_blocks = src_blocks + [0] * pad
        dst_blocks = dst_blocks + [0] * pad
    src_b = jnp.asarray(src_blocks, jnp.int32)
    dst_b = jnp.asarray(dst_blocks, jnp.int32)
    out = []
    for leaf, s in zip(jax.tree.leaves(pool), spec.leaves):
        ax = s.batch_axis
        idx = (slice(None),) * ax
        if s.token_axis is not None:
            if src_b.size:
                vals = jnp.take(leaf, src_b, axis=ax)
                leaf = leaf.at[idx + (dst_b,)].set(vals)
        elif src_state is not None:
            vals = jnp.take(leaf, jnp.asarray([src_state]), axis=ax)
            leaf = leaf.at[idx + (jnp.asarray([dst_state]),)].set(vals)
        out.append(leaf)
    return jax.tree.unflatten(spec.treedef, out)


def fork_state_slot(pool, spec: CacheViewSpec, src_state, dst_state):
    """Copy ONE stream's carried-state leaves (rgLRU / SSD states) from
    ``src_state`` into ``dst_state``, token pages untouched.

    This is the state half of a prefix-cache hit: ring pages can be
    attached by reference, but the per-stream state slot is POSITION-
    dependent — the new stream needs the donor's state exactly at the
    match boundary, forked into its own slot so the two streams diverge
    freely afterwards.  Registration uses the same copy in the other
    direction to snapshot a checkpoint at a page boundary."""
    return copy_pool_entries(pool, spec, [], [],
                             src_state=src_state, dst_state=dst_state)


def zero_state_slot(pool, spec: CacheViewSpec, state_slot: int):
    """Clear ONE state slot's carried-state leaves to the init (zero)
    state.  A freed slot still holds its dead stream's FINAL rgLRU/SSD
    state; the recurrence reads the slot at the new stream's first token,
    so reusing a slot without clearing it corrupts the new stream's
    tokens.  (Ring pages need no such scrub: attention masks them past
    ``pos``.)"""
    idx = jnp.asarray([state_slot])
    out = []
    for leaf, s in zip(jax.tree.leaves(pool), spec.leaves):
        if s.token_axis is None:
            ax = s.batch_axis
            leaf = leaf.at[(slice(None),) * ax + (idx,)].set(0)
        out.append(leaf)
    return jax.tree.unflatten(spec.treedef, out)


def extract_pool_entries(pool, spec: CacheViewSpec, blocks,
                         state_slot: Optional[int] = None):
    """Gather physical pages (and optionally a state slot) out of the pool
    into HOST (numpy) arrays — the device->host half of a swap-tier spill.

    Returns a flat leaf list in ``jax.tree`` order; entries are None where
    a leaf contributes nothing (token leaves when ``blocks`` is empty,
    state leaves when ``state_slot`` is None).  On a real fleet this is the
    D2H DMA of exactly the stream's used pages; ``insert_pool_entries`` is
    its inverse."""
    import numpy as np
    blk = jnp.asarray(list(blocks), jnp.int32)
    out = []
    for leaf, s in zip(jax.tree.leaves(pool), spec.leaves):
        ax = s.batch_axis
        if s.token_axis is not None:
            out.append(np.asarray(jnp.take(leaf, blk, axis=ax))
                       if blk.size else None)
        else:
            out.append(np.asarray(jnp.take(leaf, jnp.asarray([state_slot]),
                                           axis=ax))
                       if state_slot is not None else None)
    return out


def insert_pool_entries(pool, spec: CacheViewSpec, blocks, host_leaves,
                        state_slot: Optional[int] = None):
    """Scatter host arrays from ``extract_pool_entries`` back into the pool
    at (freshly reserved) ``blocks`` / ``state_slot`` — the host->device
    half of a swap-tier restore.  Page COUNT must match the extract; the
    physical ids may differ (the restore's reservation is new)."""
    blk = jnp.asarray(list(blocks), jnp.int32)
    out = []
    for leaf, host, s in zip(jax.tree.leaves(pool), host_leaves, spec.leaves):
        ax = s.batch_axis
        idx = (slice(None),) * ax
        if s.token_axis is not None:
            if blk.size and host is not None:
                assert host.shape[ax] == blk.size, \
                    f"spill holds {host.shape[ax]} pages, restoring {blk.size}"
                leaf = leaf.at[idx + (blk,)].set(jnp.asarray(host))
        elif state_slot is not None and host is not None:
            leaf = leaf.at[idx + (jnp.asarray([state_slot]),)].set(
                jnp.asarray(host))
        out.append(leaf)
    return jax.tree.unflatten(spec.treedef, out)


def extract_pool_entries_async(pool, spec: CacheViewSpec, blocks,
                               state_slot: Optional[int] = None):
    """Gather physical pages (and optionally a state slot) out of the pool
    as DEVICE arrays — the issue half of an asynchronous swap-tier spill.

    Same leaf-list contract as ``extract_pool_entries`` but without the
    blocking ``np.asarray``: the gather dispatches and returns immediately
    (JAX async dispatch), so decode ticks keep running while the copy
    drains.  The gather snapshots the pool's CURRENT leaf values — the
    functional storage update means later pool writes land in NEW arrays,
    so the payload stays exactly the issue-time bytes.  Poll completion
    with ``.is_ready()`` per leaf; ``np.asarray`` after that is the cheap
    landed-copy read (on TPU, stage through a pinned-host buffer)."""
    blk = jnp.asarray(list(blocks), jnp.int32)
    out = []
    for leaf, s in zip(jax.tree.leaves(pool), spec.leaves):
        ax = s.batch_axis
        if s.token_axis is not None:
            out.append(jnp.take(leaf, blk, axis=ax) if blk.size else None)
        else:
            out.append(jnp.take(leaf, jnp.asarray([state_slot]), axis=ax)
                       if state_slot is not None else None)
    return out


def gather_pool_rows(pool, spec: CacheViewSpec, blocks, state_slots=()):
    """ONE batched device gather of many streams' pages + state slots —
    the spec-decode checkpoint path (every drafted row snapshots its
    write-touched pages per tick; per-row gathers cost a host round-trip
    each).  ``blocks`` is the concatenation of all rows' page ids,
    ``state_slots`` one slot per hybrid row.  Returns device arrays (no
    host copy — rollback scatters them straight back; most checkpoints
    are dropped untouched when the draft fully accepts).  Blocks are
    padded to a pow-2 bucket with null-block gathers so the compiled-
    shape count stays bounded; callers slice rows by offset and never
    read the pad."""
    blocks = list(blocks)
    n_real = len(blocks)
    if blocks:
        bucket = 1 << (n_real - 1).bit_length()
        blocks = blocks + [0] * (bucket - n_real)
    blk = jnp.asarray(blocks, jnp.int32)
    slots = jnp.asarray(list(state_slots), jnp.int32)
    out = []
    for leaf, s in zip(jax.tree.leaves(pool), spec.leaves):
        ax = s.batch_axis
        if s.token_axis is not None:
            out.append(jnp.take(leaf, blk, axis=ax) if blk.size else None)
        else:
            out.append(jnp.take(leaf, slots, axis=ax) if slots.size
                       else None)
    return out


def scatter_pool_rows(pool, spec: CacheViewSpec, blocks, leaves,
                      state_slots=()):
    """Inverse of ``gather_pool_rows`` for the rows that ROLL BACK: one
    batched scatter of the rejected rows' pages (``leaves`` token entries
    sized exactly ``len(blocks)`` at the block axis — the caller slices
    real rows out of the bucketed gather) and their state slots."""
    blk = jnp.asarray(list(blocks), jnp.int32)
    slots = jnp.asarray(list(state_slots), jnp.int32)
    out = []
    for leaf, vals, s in zip(jax.tree.leaves(pool), leaves, spec.leaves):
        ax = s.batch_axis
        idx = (slice(None),) * ax
        if s.token_axis is not None:
            if blk.size and vals is not None:
                leaf = leaf.at[idx + (blk,)].set(jnp.asarray(vals))
        elif slots.size and vals is not None:
            leaf = leaf.at[idx + (slots,)].set(jnp.asarray(vals))
        out.append(leaf)
    return jax.tree.unflatten(spec.treedef, out)


def place_block_pool(pool, spec: CacheViewSpec, devices=None):
    """Commit pool storage onto physical devices — the placement half of
    the two-tier hierarchy.

    Single device (CPU CI, one-chip dev box): a committed ``device_put``
    — placement is explicit rather than inherited from whatever the first
    jit happened to choose.  Multiple devices: shard every leaf's
    block/slot axis across the chiplet group's devices when it divides
    evenly (domain block-id ranges are contiguous, so each group's pages
    land on its own devices), replicating leaves that don't divide."""
    devices = list(devices if devices is not None else jax.devices())
    if len(devices) <= 1:
        return jax.device_put(pool, devices[0])
    from jax.sharding import Mesh, NamedSharding, PartitionSpec
    import numpy as np
    mesh = Mesh(np.array(devices), ("groups",))
    out = []
    for leaf, s in zip(jax.tree.leaves(pool), spec.leaves):
        ax = s.batch_axis
        if leaf.shape[ax] % len(devices) == 0:
            ps = PartitionSpec(*((None,) * ax + ("groups",)))
        else:
            ps = PartitionSpec()
        out.append(jax.device_put(leaf, NamedSharding(mesh, ps)))
    return jax.tree.unflatten(spec.treedef, out)


def select_streams(spec: CacheViewSpec, mask, new_cache, old_cache):
    """Per-stream cache select: leaves of ``new_cache`` where ``mask`` (B,)
    is True, ``old_cache`` elsewhere — broadcast along each leaf's stream
    axis from ``spec``.  This is what makes a masked multi-token step exact:
    an inactive stream's cache (and ring write pointer) passes through
    bit-unchanged, so a decode stream inside a mixed prefill/decode chunk
    computes exactly what a plain single-token step would."""
    out = []
    for ln, lo, s in zip(jax.tree.leaves(new_cache),
                         jax.tree.leaves(old_cache), spec.leaves):
        shape = [1] * ln.ndim
        shape[s.batch_axis] = mask.shape[0]
        out.append(jnp.where(mask.reshape(shape), ln, lo))
    return jax.tree.unflatten(spec.treedef, out)


def next_token_ids(logits, n_tokens):
    """Greedy next token per stream, HARDENED against idle slots: a slot
    that consumed no tokens this tick (``n_tokens == 0``) yields the -1
    sentinel — never an argmax-able token id.  Both chunk steps also
    poison idle rows to NEG_INF, but the engine must not trust a bare
    ``argmax`` over them (argmax of a constant row is token 0)."""
    return jnp.where(jnp.asarray(n_tokens) > 0,
                     jnp.argmax(logits, axis=-1).astype(jnp.int32),
                     jnp.int32(-1))


def chunk_decode_step(params, cfg: ModelConfig, spec: CacheViewSpec, cache,
                      tokens, pos, n_tokens, extras=None, all_logits=False):
    """One continuous-batching tick: every stream consumes UP TO C tokens.

    tokens: (B, C) int32 — stream i's next ``n_tokens[i]`` tokens (prefill
    chunks put a prompt slice here, decode streams put [last_token, ...]);
    pos: (B,) absolute position of tokens[:, 0]; n_tokens: (B,) in [0, C]
    (0 = idle slot: nothing is computed into its cache and its logits row
    stays poisoned at NEG_INF — see ``next_token_ids``).

    Scans ``decode_step`` over the chunk axis with per-stream masking, so a
    stream's math is bit-identical to feeding its tokens one per tick —
    mixing prefill chunks with single-token decode streams in ONE batched
    model step is then purely a scheduling decision.  This is the
    REFERENCE path: C sequential model steps per tick.  The fused
    ``prefill_chunk_step`` computes the same chunk in one forward.
    Returns (logits (B, V) after each stream's LAST active token, new
    cache).  With ``all_logits=True`` (speculative verification) returns
    the PER-POSITION logits (B, C, V) instead — row [i, t] is the
    distribution after stream i consumed tokens[i, t], positions at or
    past ``n_tokens[i]`` poisoned to NEG_INF.
    """
    B, C = tokens.shape
    logits0 = jnp.full((B, cfg.vocab), L.NEG_INF, jnp.float32)

    def body(carry, t):
        cache, pos_c, logits = carry
        active = t < n_tokens
        tok = lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)   # (B, 1)
        lg, new_cache = decode_step(params, cfg, cache, tok, pos_c, extras)
        cache = select_streams(spec, active, new_cache, cache)
        logits = jnp.where(active[:, None], lg, logits)
        pos_c = pos_c + active.astype(pos_c.dtype)
        return (cache, pos_c, logits), (lg if all_logits else None)

    (cache, _, logits), ys = lax.scan(
        body, (cache, pos, logits0), jnp.arange(C))
    if all_logits:
        la = jnp.transpose(ys, (1, 0, 2))                      # (B, C, V)
        active = jnp.arange(C)[None, :] < jnp.asarray(n_tokens)[:, None]
        return jnp.where(active[:, :, None], la, L.NEG_INF), cache
    return logits, cache


# ---------------------------------------------------------------------------
# Single-token decode layers
# ---------------------------------------------------------------------------

def _decode_attn_layer(x, lp, lc, cfg: ModelConfig, rope1, pos, *, window):
    xin = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", xin, lp["attn"]["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", xin, lp["attn"]["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", xin, lp["attn"]["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if rope1 is not None:
        cos, sin = rope1
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    kc, vc = L.cache_update(lc["k"], lc["v"], k, v, pos)
    W = kc.shape[1]
    kv_pos = L.cache_positions(pos, W)
    o = L.decode_attention(q, kc, vc, kv_pos, pos, window=window)
    h = x + _attn_out(o, lp["attn"], x.dtype)
    f, _ = _ffn(L.rms_norm(h, lp["ln2"], cfg.norm_eps), lp, cfg,
                dropless=True)
    return h + f, {"k": kc, "v": vc}


def _decode_layer(x, lp, lc, cfg: ModelConfig, lt: str, rope1, pos, *,
                  hybrid=False):
    if lt == "attn":
        w = cfg.local_window if hybrid else cfg.window
        return _decode_attn_layer(x, lp, lc, cfg, rope1, pos, window=w)
    if lt == "rec":
        r, st = rglru_mod.rglru_decode_step(
            L.rms_norm(x, lp["ln1"], cfg.norm_eps), lp["rec"], cfg, lc)
        h = x + r
        f, _ = _ffn(L.rms_norm(h, lp["ln2"], cfg.norm_eps), lp, cfg,
                    dropless=True)
        return h + f, st
    if lt == "ssd":
        s, st = ssd_mod.ssd_decode_step(
            L.rms_norm(x, lp["ln"], cfg.norm_eps), lp["ssd"], cfg, lc)
        return x + s, st
    raise ValueError(lt)


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, extras=None,
                gather_specs=None):
    """One token for every stream.  tokens: (B,1); pos: (B,) absolute.

    Returns (logits (B, V) f32, new cache).
    """
    from repro.models.transformer import _wsc_tree
    extras = extras or {}
    x = embed_tokens(params, cfg, tokens)
    if cfg.rope_type == "mrope":
        pid = extras.get("position_ids",
                         jnp.broadcast_to(pos[None, :, None], (3,) + tokens.shape))
        rope1 = L.mrope_tables(pid, cfg.head_dim, cfg.rope_theta,
                               cfg.mrope_sections)
    elif cfg.rope_type == "none":
        rope1 = None
    else:
        rope1 = L.rope_tables(pos[:, None], cfg.head_dim, cfg.rope_theta)

    if cfg.family == "encdec":
        def body(x, inp):
            lp, lc = inp
            lp = _wsc_tree(lp, gather_specs and gather_specs.get("dec_layers"))
            # 1. self-attention (ln1) with ring cache
            xin = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", xin, lp["attn"]["wq"],
                           preferred_element_type=jnp.float32).astype(x.dtype)
            k = jnp.einsum("bsd,dhk->bshk", xin, lp["attn"]["wk"],
                           preferred_element_type=jnp.float32).astype(x.dtype)
            v = jnp.einsum("bsd,dhk->bshk", xin, lp["attn"]["wv"],
                           preferred_element_type=jnp.float32).astype(x.dtype)
            if rope1 is not None:
                cos, sin = rope1
                q = L.apply_rope(q, cos, sin)
                k = L.apply_rope(k, cos, sin)
            kc, vc = L.cache_update(lc["self_c"]["k"], lc["self_c"]["v"],
                                    k, v, pos)
            W = kc.shape[1]
            kv_pos = L.cache_positions(pos, W)
            o = L.decode_attention(q, kc, vc, kv_pos, pos)
            h = x + _attn_out(o, lp["attn"], x.dtype)
            # 2. cross-attention (ln2) over static encoder KV
            xin = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
            cq = jnp.einsum("bsd,dhk->bshk", xin, lp["cross"]["wq"],
                            preferred_element_type=jnp.float32).astype(x.dtype)
            S_src = lc["ck"].shape[1]
            src_pos = jnp.broadcast_to(jnp.arange(S_src)[None],
                                       (x.shape[0], S_src))
            co = L.decode_attention(cq, lc["ck"], lc["cv"], src_pos,
                                    jnp.full((x.shape[0],), 2**30, jnp.int32))
            h = h + _attn_out(co, lp["cross"], x.dtype)
            # 3. FFN (ln3)
            f, _ = _ffn(L.rms_norm(h, lp["ln3"], cfg.norm_eps), lp, cfg,
                        dropless=True)
            return h + f, {"k": kc, "v": vc}

        xs = (params["dec_layers"],
              {"self_c": cache["self"], "ck": cache["cross_k"],
               "cv": cache["cross_v"]})
        x, new_self = lax.scan(body, x, xs)
        new_cache = dict(cache, self=new_self)
    elif cfg.block_pattern:
        pattern, n_groups, tail = hybrid_structure(cfg)

        def gbody(x, inp):
            gp, gc = inp
            gp = _wsc_tree(gp, gather_specs and gather_specs.get("groups"))
            new_gc = {}
            for i, t in enumerate(pattern):
                nm = f"b{i}_{t}"
                x, st = _decode_layer(x, gp[nm], gc[nm], cfg, t, rope1, pos,
                                      hybrid=True)
                new_gc[nm] = st
            return x, new_gc

        x, new_groups = lax.scan(gbody, x, (params["groups"], cache["groups"]))
        new_tail = {}
        for nm, lp in params["tail"].items():
            t = nm.split("_", 1)[1]
            x, st = _decode_layer(x, lp, cache["tail"][nm], cfg, t, rope1, pos,
                                  hybrid=True)
            new_tail[nm] = st
        new_cache = {"groups": new_groups, "tail": new_tail}
    else:
        lt = cfg.layer_types()[0]

        def body(x, inp):
            lp, lc = inp
            lp = _wsc_tree(lp, gather_specs and gather_specs.get("layers"))
            x, st = _decode_layer(x, lp, lc, cfg, lt, rope1, pos)
            return x, st

        x, new_layers = lax.scan(body, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": new_layers}

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = head_logits(params, cfg, x[:, 0])
    return logits, new_cache


# ---------------------------------------------------------------------------
# Fused multi-token chunk forward (the PARALLEL prefill path)
# ---------------------------------------------------------------------------
#
# ``chunk_decode_step`` above is exact but SEQUENTIAL: a C-token prompt
# chunk costs C batched model steps inside one tick.  The functions below
# process the whole chunk in ONE forward — queries (B, C) attend jointly
# against the pre-chunk ring cache plus the chunk's own keys under an
# intra-chunk causal mask, and rgLRU/SSD layers run their existing chunk
# scans over the C axis inside one layer pass.  Per-stream ``n_tokens``
# masking keeps mixed ticks exact: a decode stream is just a chunk of 1, an
# idle slot a chunk of 0 (no cache leaf moves, logits poisoned to NEG_INF).

def _chunk_attn_layer(x, lp, lc, cfg: ModelConfig, rope1, pos, n_tokens, *,
                      window, chunk_kernel="dense"):
    xin = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = _attn_proj(xin, lp["attn"], rope1, cfg=cfg)
    o = L.chunk_attention(q, k, v, lc["k"], lc["v"], pos, n_tokens,
                          window=window, kernel=chunk_kernel)
    kc, vc = L.cache_update_chunk(lc["k"], lc["v"], k, v, pos, n_tokens)
    h = x + _attn_out(o, lp["attn"], x.dtype)
    f, _ = _ffn(L.rms_norm(h, lp["ln2"], cfg.norm_eps), lp, cfg,
                dropless=True)
    return h + f, {"k": kc, "v": vc}


def _chunk_layer(x, lp, lc, cfg: ModelConfig, lt: str, rope1, pos, n_tokens,
                 *, hybrid=False, chunk_kernel="dense"):
    if lt == "attn":
        w = cfg.local_window if hybrid else cfg.window
        return _chunk_attn_layer(x, lp, lc, cfg, rope1, pos, n_tokens,
                                 window=w, chunk_kernel=chunk_kernel)
    if lt == "rec":
        r, st = rglru_mod.rglru_chunk_step(
            L.rms_norm(x, lp["ln1"], cfg.norm_eps), lp["rec"], cfg, lc,
            n_tokens)
        h = x + r
        f, _ = _ffn(L.rms_norm(h, lp["ln2"], cfg.norm_eps), lp, cfg,
                    dropless=True)
        return h + f, st
    if lt == "ssd":
        s, st = ssd_mod.ssd_chunk_step(
            L.rms_norm(x, lp["ln"], cfg.norm_eps), lp["ssd"], cfg, lc,
            n_tokens)
        return x + s, st
    raise ValueError(lt)


def prefill_chunk_step(params, cfg: ModelConfig, spec: CacheViewSpec, cache,
                       tokens, pos, n_tokens, extras=None, gather_specs=None,
                       chunk_kernel="dense", all_logits=False):
    """One continuous-batching tick as ONE fused multi-token forward.

    Same contract as ``chunk_decode_step`` (tokens (B, C), pos (B,),
    n_tokens (B,) in [0, C]; returns (last-active-token logits, new
    cache)) but every stream's chunk is processed in a single model pass:
    attention scores the whole chunk against [prior ring, intra-chunk
    causal] jointly (``layers.chunk_attention``), recurrent and SSD layers
    run their chunk-parallel scans from the carried state.  ~C× fewer
    sequential model steps per prefill tick, at the cost of a (B, C, W+C)
    score transient (``costmodel.prefill_chunk_score_bytes``) and numerics
    that match the scan path to tolerance rather than bit-exactly — the
    scan stays available as the reference (``prefill_mode="scan"``).
    ``chunk_kernel="blocked"`` swaps the dense score block for the Pallas
    online-softmax ring kernel, shrinking the attention transient to one
    (block_q, block_kv) tile; "dense" keeps the einsum reference.

    Masking invariants: active tokens are a per-stream PREFIX of the
    chunk; an inactive token updates no cache leaf (ring writes are
    masked, recurrent/SSD steps degrade to identity), and an idle slot
    (n_tokens == 0) passes its cache through bit-unchanged and gets a
    NEG_INF-poisoned logits row — ``next_token_ids`` maps it to -1, so an
    idle slot can never emit a token.  Chunks wider than the ring are
    supported: attention masks each query to its surviving span and the
    ring write keeps the last W active tokens (last-write-wins).

    With ``all_logits=True`` (speculative verification) returns the
    PER-POSITION logits (B, C, V): row [i, t] is the distribution after
    stream i's token t — the intra-chunk causal mask makes it independent
    of every later token in the chunk, which is what lets greedy
    acceptance keep a verified prefix and discard the rest.  Positions at
    or past ``n_tokens[i]`` are poisoned to NEG_INF.
    """
    from repro.models.transformer import _wsc_tree
    extras = extras or {}
    B, C = tokens.shape
    x = embed_tokens(params, cfg, tokens)
    q_pos = pos[:, None] + jnp.arange(C)[None, :]
    if cfg.rope_type == "mrope":
        pid = extras.get("position_ids",
                         jnp.broadcast_to(q_pos[None], (3, B, C)))
        rope1 = L.mrope_tables(pid, cfg.head_dim, cfg.rope_theta,
                               cfg.mrope_sections)
    elif cfg.rope_type == "none":
        rope1 = None
    else:
        rope1 = L.rope_tables(q_pos, cfg.head_dim, cfg.rope_theta)

    if cfg.family == "encdec":
        def body(x, inp):
            lp, lc = inp
            lp = _wsc_tree(lp, gather_specs and gather_specs.get("dec_layers"))
            # 1. self-attention (ln1): fused chunk over the ring cache
            xin = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = _attn_proj(xin, lp["attn"], rope1, cfg=cfg)
            o = L.chunk_attention(q, k, v, lc["self_c"]["k"],
                                  lc["self_c"]["v"], pos, n_tokens,
                                  kernel=chunk_kernel)
            kc, vc = L.cache_update_chunk(lc["self_c"]["k"],
                                          lc["self_c"]["v"], k, v, pos,
                                          n_tokens)
            h = x + _attn_out(o, lp["attn"], x.dtype)
            # 2. cross-attention (ln2): all C queries over static encoder KV
            xin = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
            cq = jnp.einsum("bsd,dhk->bshk", xin, lp["cross"]["wq"],
                            preferred_element_type=jnp.float32).astype(x.dtype)
            co = L.blocked_attention(cq, lc["ck"], lc["cv"], causal=False,
                                     block_q=cfg.attn_block_q,
                                     block_kv=cfg.attn_block_kv)
            h = h + _attn_out(co, lp["cross"], x.dtype)
            # 3. FFN (ln3)
            f, _ = _ffn(L.rms_norm(h, lp["ln3"], cfg.norm_eps), lp, cfg,
                        dropless=True)
            return h + f, {"k": kc, "v": vc}

        xs = (params["dec_layers"],
              {"self_c": cache["self"], "ck": cache["cross_k"],
               "cv": cache["cross_v"]})
        x, new_self = lax.scan(body, x, xs)
        new_cache = dict(cache, self=new_self)
    elif cfg.block_pattern:
        pattern, n_groups, tail = hybrid_structure(cfg)

        def gbody(x, inp):
            gp, gc = inp
            gp = _wsc_tree(gp, gather_specs and gather_specs.get("groups"))
            new_gc = {}
            for i, t in enumerate(pattern):
                nm = f"b{i}_{t}"
                x, st = _chunk_layer(x, gp[nm], gc[nm], cfg, t, rope1, pos,
                                     n_tokens, hybrid=True,
                                     chunk_kernel=chunk_kernel)
                new_gc[nm] = st
            return x, new_gc

        x, new_groups = lax.scan(gbody, x, (params["groups"], cache["groups"]))
        new_tail = {}
        for nm, lp in params["tail"].items():
            t = nm.split("_", 1)[1]
            x, st = _chunk_layer(x, lp, cache["tail"][nm], cfg, t, rope1, pos,
                                 n_tokens, hybrid=True,
                                 chunk_kernel=chunk_kernel)
            new_tail[nm] = st
        new_cache = {"groups": new_groups, "tail": new_tail}
    else:
        lt = cfg.layer_types()[0]

        def body(x, inp):
            lp, lc = inp
            lp = _wsc_tree(lp, gather_specs and gather_specs.get("layers"))
            x, st = _chunk_layer(x, lp, lc, cfg, lt, rope1, pos, n_tokens,
                                 chunk_kernel=chunk_kernel)
            return x, st

        x, new_layers = lax.scan(body, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": new_layers}

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if all_logits:
        la = head_logits(params, cfg, x.reshape(B * C, x.shape[-1]))
        la = la.reshape(B, C, -1)
        active = jnp.arange(C)[None, :] < n_tokens[:, None]
        return jnp.where(active[:, :, None], la, L.NEG_INF), new_cache
    last = jnp.clip(n_tokens - 1, 0, C - 1)
    xl = jnp.take_along_axis(
        x, jnp.broadcast_to(last[:, None, None], (B, 1, x.shape[-1])),
        axis=1)[:, 0]
    logits = head_logits(params, cfg, xl)
    logits = jnp.where((n_tokens > 0)[:, None], logits, L.NEG_INF)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill: full-sequence forward that also materializes the decode cache
# ---------------------------------------------------------------------------

def _ring_arrange(kv, W):
    """kv: (B, S, H, dh) full-seq keys/values -> ring cache (B, W, H, dh)."""
    S = kv.shape[1]
    if S <= W:
        pad = [(0, 0), (0, W - S), (0, 0), (0, 0)]
        return jnp.pad(kv, pad)
    last = kv[:, -W:]
    return jnp.roll(last, shift=(S - W) % W, axis=1)


def _state_to_cache(cfg, st, lt, max_len, hybrid=False):
    if lt in ("attn", "enc"):
        W = _attn_cache_width(cfg, max_len, hybrid=hybrid)
        return {"k": _ring_arrange(st["k"], W), "v": _ring_arrange(st["v"], W)}
    return st  # rec/ssd states already in decode form


def prefill(params, cfg: ModelConfig, tokens, extras=None, *, max_len: int,
            gather_specs=None):
    """Process the prompt; return (last-token logits (B,V), cache).

    Ring-arranging happens INSIDE the layer scan (state_fn), so a
    sliding-window cache never stacks (L, B, S_full, ...) — only
    (L, B, W, ...)."""
    extras = extras or {}
    if cfg.family == "encdec":
        return encdec_prefill(params, cfg, extras["frame_embeds"], tokens,
                              max_len=max_len)
    hybrid = bool(cfg.block_pattern)

    def sfn(s, t):
        return _state_to_cache(cfg, s, t, max_len, hybrid=hybrid)

    x, states, _ = forward(params, cfg, tokens, extras, return_states=True,
                           state_fn=sfn, gather_specs=gather_specs)
    if cfg.block_pattern:
        cache = {"groups": states["groups"], "tail": states["tail"]}
    else:
        cache = {"layers": states["layers"]}
    logits = head_logits(params, cfg, x[:, -1])
    return logits, cache


def encdec_prefill(params, cfg: ModelConfig, frame_embeds, tokens, *,
                   max_len: int):
    """Encode source; prefill decoder on target prefix; build caches."""
    from repro.models.transformer import decoder_forward, encode

    enc_out = encode(params, cfg, frame_embeds)
    x, states = decoder_forward(params, cfg, tokens, enc_out,
                                return_states=True)
    self_c = jax.vmap(lambda s: {
        "k": _ring_arrange(s["k"], max_len),
        "v": _ring_arrange(s["v"], max_len)})(
            {"k": states["k"], "v": states["v"]})
    logits = head_logits(params, cfg, x[:, -1])
    cache = {"self": self_c, "cross_k": states["ck"], "cross_v": states["cv"]}
    return logits, cache
