"""SeamlessM4T large v2 — encoder-decoder multimodal (audio) backbone.

[arXiv:2308.11596; hf] 24L d_model=1024 16H (kv=16, MHA) d_ff=8192
vocab=256206.  The speech frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, S_src, d_model) for the encoder; the decoder
autoregresses over text tokens with self- + cross-attention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,              # 24 encoder + 24 decoder (see __post_init__)
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,             # not divisible by 16: GSPMD pads vocab shards
    activation="gelu",
    rope_theta=10_000.0,
)
