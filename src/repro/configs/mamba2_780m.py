"""Mamba2 780M — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060; unverified] 48L d_model=1536 (attn-free) vocab=50280,
ssm_state=128.  d_inner = 2*d_model = 3072, head_dim 64 -> 48 SSD heads.
Constant-size decode state: long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_groups=1,
    expand=2,
    conv_width=4,
    ssd_chunk=256,
    rope_type="none",
    tie_embeddings=True,
)
