"""Architecture registry: ``get_config(arch_id)`` and reduced smoke configs."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shape_applicable
from repro.configs import (
    mixtral_8x22b,
    grok_1_314b,
    llama3_8b,
    llama3_2_3b,
    starcoder2_15b,
    nemotron_4_15b,
    qwen2_vl_2b,
    recurrentgemma_9b,
    mamba2_780m,
    seamless_m4t_large_v2,
)

_MODULES = (
    mixtral_8x22b,
    grok_1_314b,
    llama3_8b,
    llama3_2_3b,
    starcoder2_15b,
    nemotron_4_15b,
    qwen2_vl_2b,
    recurrentgemma_9b,
    mamba2_780m,
    seamless_m4t_large_v2,
)

REGISTRY: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}

ARCH_IDS = tuple(REGISTRY)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


def reduced_config(cfg: ModelConfig, *, layers: int = 0) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (shapes + no-NaN only).

    Keeps the family, block pattern, activation, GQA ratio and MoE/SSM
    structure; shrinks widths, depth, vocab and expert count.
    """
    pat = len(cfg.block_pattern) or 1
    n_layers = layers or max(2, pat + (1 if cfg.block_pattern else 0))
    if cfg.block_pattern:
        n_layers = pat + 2  # one full pattern group + a 2-layer tail
    n_heads = 4 if cfg.n_heads else 0
    n_kv = max(1, n_heads // max(1, cfg.q_per_kv)) if cfg.n_heads else 0
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16 if cfg.n_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        # dropless capacity (C = block) so decode == forward exactly in tests
        capacity_factor=(min(cfg.n_experts, 4) / max(1, min(cfg.top_k, 2))
                         if cfg.n_experts else cfg.capacity_factor),
        moe_block=64,
        window=min(cfg.window, 64) if cfg.window else 0,
        local_window=min(cfg.local_window, 32) if cfg.local_window else 0,
        lru_width=64 if cfg.lru_width else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssd_chunk=32,
        mrope_sections=(4, 2, 2) if cfg.rope_type == "mrope" else (),
        enc_layers=2 if cfg.family == "encdec" else 0,
        dec_layers=2 if cfg.family == "encdec" else 0,
        attn_block_q=32,
        attn_block_kv=32,
        param_dtype="float32",
        compute_dtype="float32",
    )


__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "shape_applicable",
    "REGISTRY", "ARCH_IDS", "get_config", "reduced_config",
]
