"""StarCoder2 15B — dense, GQA kv=4, RoPE, GeLU MLP.

[arXiv:2402.19173; hf] 40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    activation="gelu",
    rope_theta=100_000.0,
)
