"""RecurrentGemma 9B — Griffin hybrid: RG-LRU recurrence + local attention, 1:2.

[arXiv:2402.19427; unverified] 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000.  Repeating pattern (rec, rec, attn); bounded decode state
(LRU state + 2048-token local window) so long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,             # MQA on the local-attention layers
    d_ff=12288,
    vocab=256000,
    activation="gelu_glu",    # GeGLU, as in the Gemma family
    block_pattern=("rec", "rec", "attn"),
    lru_width=4096,
    local_window=2048,
    rope_theta=10_000.0,
    tie_embeddings=True,
)
