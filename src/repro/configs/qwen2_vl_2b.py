"""Qwen2-VL 2B — VLM backbone, M-RoPE, dynamic-resolution frontend STUBBED.

[arXiv:2409.12191; hf] 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

Per the assignment the modality frontend is a stub: ``input_specs()`` provides
precomputed patch embeddings occupying ``vision_frac`` of the sequence, plus
(3, B, S) M-RoPE position ids (temporal/height/width components).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,               # 12 % 16 != 0: heads replicated on model axis
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    activation="swiglu",
    rope_type="mrope",
    mrope_sections=(16, 24, 24),   # sums to head_dim // 2 = 64
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    vision_frac=0.25,
)
