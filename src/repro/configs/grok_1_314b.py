"""Grok-1 314B — MoE 8 experts top-2, GQA, full attention.

[hf:xai-org/grok-1; unverified] 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    activation="gelu",        # grok uses (approx) GeLU expert MLPs
    n_experts=8,
    top_k=2,
    window=0,                 # full attention -> long_500k skipped
    rope_theta=10_000.0,
    logit_softcap=30.0,
)
