"""Model configuration dataclasses.

Every assigned architecture is expressed as a single ``ModelConfig`` covering
dense / MoE / SSM / hybrid / enc-dec LM families.  Configs are frozen and
hashable so they can be closed over by jitted functions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- activation / ffn ---
    activation: str = "swiglu"       # swiglu | squared_relu | gelu | relu_glu

    # --- mixture of experts ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_block: int = 2048            # token-block size for dense dispatch

    # --- attention ---
    window: int = 0                  # sliding-window size; 0 = full attention
    rope_theta: float = 10000.0
    rope_type: str = "rope"          # rope | mrope | none
    mrope_sections: Tuple[int, ...] = ()

    # --- hybrid (RG-LRU, RecurrentGemma / Griffin) ---
    block_pattern: Tuple[str, ...] = ()   # repeating pattern, e.g. ("rec","rec","attn")
    lru_width: int = 0
    local_window: int = 0            # window of the hybrid's local-attention layers

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    expand: int = 2
    conv_width: int = 4
    ssd_chunk: int = 256

    # --- encoder-decoder ---
    enc_layers: int = 0
    dec_layers: int = 0

    # --- embeddings / misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    vision_frac: float = 0.0         # VLM: fraction of sequence that is patch embeds
    logit_softcap: float = 0.0

    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # --- runtime knobs (not architecture) ---
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    remat: str = "none"              # none | block | full
    seq_shard: bool = False          # shard layer-scan residuals over "model"
                                     # (Megatron-SP style; needs mesh context)
    batch_axes: Tuple[str, ...] = ("data",)   # mesh axes carrying batch
    moe_batched: bool = False        # per-example dispatch (shard_map mode);
                                     # flattened dispatch is GSPMD-friendlier
    head_pad_to: int = 0             # pad Q head-groups so heads shard on the
                                     # model axis (24H/12H vs a 16-wide axis)
    use_pallas: bool = False         # pure-jnp path for dry-run/CPU; Pallas on TPU
    scan_layers: bool = True

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family == "encdec" and not (self.enc_layers or self.dec_layers):
            object.__setattr__(self, "enc_layers", self.n_layers)
            object.__setattr__(self, "dec_layers", self.n_layers)

    # ------------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to 256 so embed/head shard on any mesh axis."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def q_per_kv(self) -> int:
        return max(1, self.n_heads // max(1, self.n_kv_heads))

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def is_subquadratic(self) -> bool:
        """True if decode state is bounded (can serve 500k+ contexts)."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return True
        # pure sliding-window attention also bounds the KV working set
        return self.window > 0

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs autoregress (enc-dec via its decoder)

    def layer_types(self) -> Tuple[str, ...]:
        """Concrete per-layer block types for hybrid models."""
        if not self.block_pattern:
            if self.family == "ssm":
                return ("ssd",) * self.n_layers
            return ("attn",) * self.n_layers
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    def param_count(self) -> int:
        """Exact parameter count (embedding + blocks + head)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        Hq, Hkv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        total = V * D  # embedding
        if not self.tie_embeddings:
            total += V * D  # lm head

        def attn_params():
            return D * Hq * dh + 2 * D * Hkv * dh + Hq * dh * D

        def ffn_params():
            mult = 3 if self.activation in ("swiglu", "relu_glu") else 2
            return mult * D * F

        def moe_ffn_params():
            mult = 3 if self.activation in ("swiglu", "relu_glu") else 2
            return self.n_experts * mult * D * F + D * self.n_experts

        def rglru_params():
            W = self.lru_width or D
            # two in-projections, conv, gates (a/x), lambda, out proj
            return 2 * D * W + self.conv_width * W + 2 * W * W // 1 + W + W * D

        def ssd_params():
            di, ns, ng = self.d_inner, self.ssm_state, self.ssm_groups
            nh = self.ssm_heads
            in_proj = D * (2 * di + 2 * ng * ns + nh)
            conv = self.conv_width * (di + 2 * ng * ns)
            out = di * D
            return in_proj + conv + out + nh + di  # + A, D params + norm

        if self.family == "encdec":
            enc = self.enc_layers * (attn_params() + ffn_params() + 2 * D)
            dec = self.dec_layers * (2 * attn_params() + ffn_params() + 3 * D)
            return total + enc + dec

        per_layer = []
        for lt in self.layer_types():
            if lt == "attn":
                ffn = moe_ffn_params() if self.n_experts else ffn_params()
                per_layer.append(attn_params() + ffn + 2 * D)
            elif lt == "rec":
                ffn = ffn_params()
                per_layer.append(rglru_params() + ffn + 2 * D)
            elif lt == "ssd":
                per_layer.append(ssd_params() + 2 * D)
        return total + sum(per_layer)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        dense_like = dataclasses.replace(self, n_experts=0, top_k=0)
        mult = 3 if self.activation in ("swiglu", "relu_glu") else 2
        expert_per_layer = mult * self.d_model * self.d_ff
        n_attn = sum(1 for t in self.layer_types() if t == "attn")
        return (dense_like.param_count()
                + (self.top_k - 1) * 0  # router negligible
                + n_attn * (self.top_k - 1) * expert_per_layer)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: what gets lowered in the dry-run."""
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The assigned LM shape set (identical across the 10 archs).
SHAPES = {
    "train_4k":    ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a cell runs, and if not, why (recorded in EXPERIMENTS.md)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "full quadratic attention: 500k decode needs sub-quadratic arch"
    return True, ""
