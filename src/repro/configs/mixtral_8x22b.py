"""Mixtral 8x22B — MoE 8 experts top-2, GQA, sliding-window attention.

[arXiv:2401.04088; hf] 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    activation="swiglu",
    n_experts=8,
    top_k=2,
    window=4096,              # SWA -> bounded decode state, long_500k runs
    rope_theta=1_000_000.0,
)
