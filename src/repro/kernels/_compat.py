"""Version-compat shims for the Pallas TPU API surface."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names it TPUCompilerParams; newer releases renamed it
CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
if CompilerParams is None:  # pragma: no cover - future-jax guard
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; update repro.kernels._compat for this jax "
        "version")
