"""Pallas TPU kernels for the perf-critical compute hot spots.

Each kernel subpackage has: kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd public wrapper, custom_vjp where trained through), and
ref.py (pure-jnp oracle used by the allclose test sweeps).
"""
