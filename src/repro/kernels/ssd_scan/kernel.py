"""Pallas TPU kernel: Mamba2 SSD chunked scan.

TPU adaptation of the SSD algorithm: per (batch*head, chunk) the kernel does
three MXU matmuls — C B^T (Q,Q scores), the masked-decay weighted intra-chunk
product, and the inter-chunk C @ state — plus a rank-Q state update, with the
running (N, P) state held in VMEM scratch across chunk grid steps.  One HBM
pass over x/B/C; states never touch HBM (vs. the XLA scan which spills the
(H, P, N) state every chunk).

Layout: head-major.  x: (BH, S, P); a(=dt*A): (BH, S); B/C: (BG, S, N) with
the head->group mapping folded into the BlockSpec index maps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, hT_ref, state_scr, *,
                nc, Q):
    jc = pl.program_id(1)

    @pl.when(jc == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    xc = x_ref[0].astype(jnp.float32)            # (Q, P) already dt-weighted
    ac = a_ref[0]                                # (Q,) log-decay, f32
    Bc = b_ref[0].astype(jnp.float32)            # (Q, N)
    Cc = c_ref[0].astype(jnp.float32)            # (Q, N)

    a_cum = jnp.cumsum(ac)                       # inclusive (Q,)
    a_tot = a_cum[-1]

    # intra-chunk: y[q] += sum_{k<=q} exp(acum_q - acum_k) (C_q.B_k) xdt_k
    seg = a_cum[:, None] - a_cum[None, :]        # (Q, Q)
    iq = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ik = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(iq >= ik, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores * L, xc, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y[q] += exp(acum_q) C_q @ state   (state: (N, P))
    y += jnp.exp(a_cum)[:, None] * jax.lax.dot_general(
        Cc, state_scr[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    # state' = exp(a_tot) state + B^T (decay_to_end * xdt)
    decay_end = jnp.exp(a_tot - a_cum)           # (Q,)
    state_scr[...] = (jnp.exp(a_tot) * state_scr[...] +
                      jax.lax.dot_general(
                          Bc, decay_end[:, None] * xc,
                          (((0,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))

    @pl.when(jc == nc - 1)
    def _write_state():
        hT_ref[0] = state_scr[...]


def ssd_scan(xdt, a, B_, C_, *, chunk=128, hq_per_group=1, interpret=True):
    """xdt: (BH, S, P) dt-weighted inputs; a: (BH, S) log-decays;
    B_/C_: (BG, S, N) with BH = BG * hq_per_group.

    Returns (y (BH, S, P) f32, h_final (BH, N, P) f32).
    """
    BH, S, P = xdt.shape
    N = B_.shape[2]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    G = hq_per_group

    y, hT = pl.pallas_call(
        functools.partial(_ssd_kernel, nc=nc, Q=Q),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda b, jc: (b, jc, 0)),
            pl.BlockSpec((1, Q), lambda b, jc: (b, jc)),
            pl.BlockSpec((1, Q, N), lambda b, jc: (b // G, jc, 0)),
            pl.BlockSpec((1, Q, N), lambda b, jc: (b // G, jc, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda b, jc: (b, jc, 0)),
            pl.BlockSpec((1, N, P), lambda b, jc: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xdt, a, B_, C_)
    return y, hT
