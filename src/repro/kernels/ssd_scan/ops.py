"""Public wrapper for the SSD kernel, model layout in/out.

Forward runs the Pallas kernel; backward recomputes through the equivalent
differentiable jnp chunked algorithm (``repro.models.ssd.ssd_chunked``) —
the standard fused-forward / XLA-backward trade for scan kernels.  Both the
sequence output and the final state are differentiable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan


def _to_head_major(x):
    # (B, S, H, P) -> (B*H, S, P)
    B, S, H, P = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, P)


def _ssd_pallas(x, dt, A, B_, C_, chunk, interpret):
    Bb, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    a = (dt.astype(jnp.float32) * A.astype(jnp.float32)[None, None, :])
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    xf = _to_head_major(xdt)
    af = a.transpose(0, 2, 1).reshape(Bb * H, S)
    Bf = _to_head_major(B_)
    Cf = _to_head_major(C_)
    y, hT = ssd_scan(xf, af, Bf, Cf, chunk=chunk, hq_per_group=H // G,
                     interpret=interpret)
    y = y.reshape(Bb, H, S, P).transpose(0, 2, 1, 3).astype(x.dtype)
    # hT: (BH, N, P) -> (B, H, P, N) to match the model/ref state layout
    hT = hT.reshape(Bb, H, N, P).transpose(0, 1, 3, 2)
    return y, hT


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def ssd_with_state(x, dt, A, B_, C_, chunk=128, interpret=True):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); B_/C_: (B,S,G,N).

    Returns (y (B,S,H,P), h_final (B,H,P,N) f32)."""
    return _ssd_pallas(x, dt, A, B_, C_, chunk, interpret)


def _fwd(x, dt, A, B_, C_, chunk, interpret):
    out = _ssd_pallas(x, dt, A, B_, C_, chunk, interpret)
    return out, (x, dt, A, B_, C_)


def _bwd(chunk, interpret, res, cts):
    from repro.models.ssd import ssd_chunked
    x, dt, A, B_, C_ = res

    def recompute(x, dt, A, B_, C_):
        return ssd_chunked(x.astype(jnp.float32), dt.astype(jnp.float32),
                           A.astype(jnp.float32), B_, C_, chunk=chunk)

    _, vjp = jax.vjp(recompute, x, dt, A, B_, C_)
    g_y, g_h = cts
    return vjp((g_y.astype(jnp.float32), g_h.astype(jnp.float32)))


ssd_with_state.defvjp(_fwd, _bwd)


def ssd(x, dt, A, B_, C_, chunk=128, interpret=True):
    """Sequence output only."""
    return ssd_with_state(x, dt, A, B_, C_, chunk, interpret)[0]
