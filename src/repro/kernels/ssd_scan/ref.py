"""Pure-jnp oracle for the SSD selective scan: naive per-timestep recurrence.

State h: (B, H, P, N);  h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t
Output  y_t = C_t . h_t  (+ D skip handled by the model, not here).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def ssd_scan_ref(x, dt, A, B_, C_, h0=None):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); B_/C_: (B,S,G,N) -> y, h_final."""
    Bb, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    Bf = jnp.repeat(B_.astype(jnp.float32), rep, axis=2)
    Cf = jnp.repeat(C_.astype(jnp.float32), rep, axis=2)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    h = jnp.zeros((Bb, H, P, N), jnp.float32) if h0 is None else h0

    def step(h, inp):
        xt, dtt, Bt, Ct = inp                     # (B,H,P), (B,H), (B,H,N) x2
        decay = jnp.exp(dtt * Af[None])           # (B,H)
        h = h * decay[..., None, None] + jnp.einsum(
            "bhn,bhp,bh->bhpn", Bt, xt, dtt)
        y = jnp.einsum("bhn,bhpn->bhp", Ct, h)
        return h, y

    hT, ys = lax.scan(step, h, (xf.transpose(1, 0, 2, 3),
                                dtf.transpose(1, 0, 2),
                                Bf.transpose(1, 0, 2, 3),
                                Cf.transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3), hT
