from repro.kernels.ssd_scan.ops import ssd, ssd_with_state
from repro.kernels.ssd_scan.kernel import ssd_scan
