"""Pallas TPU flash-attention kernels (forward + backward).

Layout: head-major (BH, S, dh) so the trailing two dims map onto TPU
(sublane, lane) tiles; dh is expected to be a multiple of 128 (MXU lane
width) for the assigned architectures (dh=128 or 256; smoke shapes are
smaller and run in interpret mode).

Grid (forward): (BH_q, n_q_blocks, n_kv_blocks) with the KV dimension
innermost ("arbitrary" semantics) so the online-softmax state lives in VMEM
scratch across KV steps.  GQA is expressed entirely in the BlockSpec index
maps: the q-head grid coordinate selects the matching kv head row, so no
repeated KV tensor is ever materialized in HBM.

Causal / sliding-window blocks that are fully masked are skipped with
``pl.when`` (no MXU work), which is where the kernel beats a dense
attention on TPU for long sequences.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30
LANES = 128


def _block_visible(iq, jk, bq, bkv, causal, window):
    """Whether (q block iq, kv block jk) contains any unmasked element."""
    q_lo = iq * bq
    q_hi = q_lo + bq - 1
    kv_lo = jk * bkv
    kv_hi = kv_lo + bkv - 1
    vis = jnp.bool_(True)
    if causal:
        vis &= kv_lo <= q_hi
    if window:
        vis &= kv_hi > q_lo - window
    return vis


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr, *, scale, causal, window,
                bq, bkv, n_kv):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(_block_visible(iq, jk, bq, bkv, causal, window))
    def _compute():
        q = q_ref[0]                                   # (bq, dh)
        k = k_ref[0]                                   # (bkv, dh)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bkv)
        q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kv_pos = jk * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            mask &= kv_pos <= q_pos
        if window:
            mask &= kv_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0]                           # (bq,)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_scr[:, 0] * alpha + p.sum(axis=-1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None] +
                        jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)

    @pl.when(jk == n_kv - 1)
    def _finalize():
        l = l_scr[:, 0]
        denom = jnp.where(l > 0, l, 1.0)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)
        lse_ref[0] = (m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30)))


def flash_attention_fwd(q, k, v, *, causal=True, window=0,
                        block_q=256, block_kv=256, hq_per_kv=1,
                        interpret=False):
    """q: (BHq, Sq, dh); k/v: (BHkv, Skv, dh) with BHq = BHkv * hq_per_kv.

    Returns (out (BHq, Sq, dh), lse (BHq, Sq, LANES) — lse broadcast on lanes).
    """
    BH, Sq, dh = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0
    n_q, n_kv = Sq // bq, Skv // bkv
    scale = dh ** -0.5
    G = hq_per_kv

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bkv=bkv, n_kv=n_kv)

    out, lse = pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, iq, jk: (b, iq, 0)),
            pl.BlockSpec((1, bkv, dh), lambda b, iq, jk: (b // G, jk, 0)),
            pl.BlockSpec((1, bkv, dh), lambda b, iq, jk: (b // G, jk, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, iq, jk: (b, iq, 0)),
            pl.BlockSpec((1, bq, LANES), lambda b, iq, jk: (b, iq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
            jax.ShapeDtypeStruct((BH, Sq, LANES), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# Ring-chunk forward (serving fused-prefill path; no VJP)
# ---------------------------------------------------------------------------

def _ring_block_visible(iq, jk, bq, bkv, ring):
    """Static skip for the ring-chunk grid: only the chunk segment of the
    concatenated KV axis (indices >= ring) is statically causal — a block
    whose lowest chunk offset exceeds the q block's highest offset can never
    contain a visible entry.  Ring-slot blocks are data-dependent (per-stream
    positions) and are always entered; their masking is per-element."""
    q_hi = iq * bq + bq - 1
    kv_lo = jk * bkv
    return (kv_lo < ring) | (kv_lo - ring <= q_hi)


def _ring_fwd_kernel(q_ref, k_ref, v_ref, qpos_ref, kpos_ref, o_ref,
                     m_scr, l_scr, acc_scr, *, scale, ring, window, softcap,
                     bq, bkv, n_kv):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(_ring_block_visible(iq, jk, bq, bkv, ring))
    def _compute():
        q = q_ref[0]                                   # (bq, dh)
        k = k_ref[0]                                   # (bkv, dh)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bkv)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        # absolute positions carried in by the wrapper: q rows are pos+t,
        # KV entries are the slot's held position (ring segment, negative =
        # never written), pos+t' for live chunk keys, or a sentinel far
        # below zero for idle/short-chunk keys and block padding.  One band
        # test then expresses all three dense masks: causality (kp <= qp),
        # ring eviction incl. intra-chunk self-eviction for C > W
        # (kp > qp - ring), and never-written slots (kp >= 0).
        qp = qpos_ref[0][:, None].astype(jnp.int32)    # (bq, 1)
        kp = kpos_ref[0][None, :].astype(jnp.int32)    # (1, bkv)
        mask = (kp >= 0) & (kp <= qp) & (kp > qp - ring)
        if window:
            mask &= kp > qp - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0]                           # (bq,)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_scr[:, 0] * alpha + p.sum(axis=-1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None] +
                        jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)

    @pl.when(jk == n_kv - 1)
    def _finalize():
        l = l_scr[:, 0]
        denom = jnp.where(l > 0, l, 1.0)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def ring_chunk_attention_fwd(q, k, v, q_pos, kv_pos, *, ring, window=0,
                             softcap=0.0, block_q=32, block_kv=32,
                             hq_per_kv=1, interpret=False):
    """Forward-only blocked attention over [prior ring, chunk keys].

    q: (BHq, Cp, dh) chunk queries, head-major, padded to a block_q
    multiple; k/v: (BHkv, Lp, dh) the concatenated [ring, chunk] KV, padded
    to a block_kv multiple; q_pos: (B, Cp) int32 absolute query positions;
    kv_pos: (B, Lp) int32 absolute KV positions with negative sentinels for
    never-written slots, masked chunk keys, and padding.  ``ring`` is the
    ring width W (the implicit eviction window).  Returns (BHq, Cp, dh).

    The live transient per grid step is one (block_q, block_kv) f32 score
    block plus the online-softmax state — never the dense (C, W+C) block.
    """
    BH, Cp, dh = q.shape
    Lp = k.shape[1]
    B = q_pos.shape[0]
    heads = BH // B
    bq = min(block_q, Cp)
    bkv = min(block_kv, Lp)
    assert Cp % bq == 0 and Lp % bkv == 0
    n_q, n_kv = Cp // bq, Lp // bkv
    scale = dh ** -0.5
    G = hq_per_kv

    kernel = functools.partial(
        _ring_fwd_kernel, scale=scale, ring=ring, window=window,
        softcap=softcap, bq=bq, bkv=bkv, n_kv=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, iq, jk: (b, iq, 0)),
            pl.BlockSpec((1, bkv, dh), lambda b, iq, jk: (b // G, jk, 0)),
            pl.BlockSpec((1, bkv, dh), lambda b, iq, jk: (b // G, jk, 0)),
            pl.BlockSpec((1, bq), lambda b, iq, jk: (b // heads, iq)),
            pl.BlockSpec((1, bkv), lambda b, iq, jk: (b // heads, jk)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, iq, jk: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Cp, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, q_pos, kv_pos)
    return out


# ---------------------------------------------------------------------------
# Backward: dq kernel (grid over q blocks, scan kv) and dkv kernel
# ---------------------------------------------------------------------------

def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               dq_scr, *, scale, causal, window, bq, bkv, n_kv):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    @pl.when(_block_visible(iq, jk, bq, bkv, causal, window))
    def _compute():
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0][:, 0]                          # (bq,)
        delta = delta_ref[0][:, 0]                      # (bq,)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kv_pos = jk * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            mask &= kv_pos <= q_pos
        if window:
            mask &= kv_pos > q_pos - window
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v.astype(jnp.float32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dq_scr[...] += jax.lax.dot_general(
            ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jk == n_kv - 1)
    def _finalize():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *, scale, causal, window,
                bq, bkv, n_q, hq_per_kv):
    jk = pl.program_id(1)
    g = pl.program_id(2)
    iq = pl.program_id(3)
    first = (g == 0) & (iq == 0)
    last = (g == hq_per_kv - 1) & (iq == n_q - 1)

    @pl.when(first)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    @pl.when(_block_visible(iq, jk, bq, bkv, causal, window))
    def _compute():
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0][:, 0]
        delta = delta_ref[0][:, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kv_pos = jk * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            mask &= kv_pos <= q_pos
        if window:
            mask &= kv_pos > q_pos - window
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dv_scr[...] += jax.lax.dot_general(
            p.astype(do_ref.dtype), do_ref[0], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v.astype(jnp.float32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = (p * (dp - delta[:, None]) * scale).astype(q.dtype)
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(last)
    def _finalize():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, out, lse, do, *, causal=True, window=0,
                        block_q=256, block_kv=256, hq_per_kv=1,
                        interpret=False):
    """Returns (dq, dk, dv) with GQA reduction over the q-head group."""
    BH, Sq, dh = q.shape
    BHkv, Skv, _ = k.shape
    G = hq_per_kv
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    n_q, n_kv = Sq // bq, Skv // bkv
    scale = dh ** -0.5
    delta = (out.astype(jnp.float32) * do.astype(jnp.float32)).sum(-1)
    delta = jnp.broadcast_to(delta[..., None], delta.shape + (LANES,))

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bkv=bkv, n_kv=n_kv),
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, iq, jk: (b, iq, 0)),
            pl.BlockSpec((1, bkv, dh), lambda b, iq, jk: (b // G, jk, 0)),
            pl.BlockSpec((1, bkv, dh), lambda b, iq, jk: (b // G, jk, 0)),
            pl.BlockSpec((1, bq, dh), lambda b, iq, jk: (b, iq, 0)),
            pl.BlockSpec((1, bq, LANES), lambda b, iq, jk: (b, iq, 0)),
            pl.BlockSpec((1, bq, LANES), lambda b, iq, jk: (b, iq, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, iq, jk: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, dh), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bkv=bkv, n_q=n_q,
                          hq_per_kv=G),
        grid=(BHkv, n_kv, G, n_q),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, jk, g, iq: (b * G + g, iq, 0)),
            pl.BlockSpec((1, bkv, dh), lambda b, jk, g, iq: (b, jk, 0)),
            pl.BlockSpec((1, bkv, dh), lambda b, jk, g, iq: (b, jk, 0)),
            pl.BlockSpec((1, bq, dh), lambda b, jk, g, iq: (b * G + g, iq, 0)),
            pl.BlockSpec((1, bq, LANES), lambda b, jk, g, iq: (b * G + g, iq, 0)),
            pl.BlockSpec((1, bq, LANES), lambda b, jk, g, iq: (b * G + g, iq, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bkv, dh), lambda b, jk, g, iq: (b, jk, 0)),
            pl.BlockSpec((1, bkv, dh), lambda b, jk, g, iq: (b, jk, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BHkv, Skv, dh), k.dtype),
            jax.ShapeDtypeStruct((BHkv, Skv, dh), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bkv, dh), jnp.float32),
            pltpu.VMEM((bkv, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
