"""Jit'd public wrappers: GQA flash attention with custom VJP, plus the
forward-only ring-chunk attention used by the serving fused-prefill path.

``flash_attention(q, k, v)`` takes model-layout tensors (B, S, H, dh) and
handles head-major reshaping, GQA head mapping, and the Pallas fwd/bwd
kernels.  ``interpret=None`` (the default) resolves per backend: TPU
compiles the real kernel, everything else runs the kernel bodies in
interpret mode for validation.  Pass an explicit bool to override.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K

# sentinel "position" for KV entries that must never win a mask test:
# never-written ring slots already carry small negatives, this marks
# masked chunk keys and block padding (far enough below zero that
# ``kp > qp - W`` can never resurrect it)
_NEVER = -(2 ** 30)


def _default_interpret() -> bool:
    """Interpret Pallas kernel bodies everywhere except real TPU."""
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret):
    return _default_interpret() if interpret is None else interpret


def _to_head_major(x):
    B, S, H, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, dh)


def _from_head_major(x, B, H):
    BH, S, dh = x.shape
    return x.reshape(B, H, S, dh).transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, window=0, block_q=256,
                    block_kv=256, interpret=None):
    """q: (B,S,Hq,dh); k/v: (B,Skv,Hkv,dh) -> (B,S,Hq,dh)."""
    out, _ = _fwd(q, k, v, causal, window, block_q, block_kv, interpret)
    return out


def _fwd(q, k, v, causal, window, block_q, block_kv, interpret):
    B, Sq, Hq, dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qf = _to_head_major(q)
    kf = _to_head_major(k)
    vf = _to_head_major(v)
    out, lse = K.flash_attention_fwd(
        qf, kf, vf, causal=causal, window=window, block_q=block_q,
        block_kv=block_kv, hq_per_kv=G,
        interpret=_resolve_interpret(interpret))
    return _from_head_major(out, B, Hq), (qf, kf, vf, out, lse, B, Hq, Hkv)


def _fwd_rule(q, k, v, causal, window, block_q, block_kv, interpret):
    out, res = _fwd(q, k, v, causal, window, block_q, block_kv, interpret)
    return out, res


def _bwd_rule(causal, window, block_q, block_kv, interpret, res, g):
    qf, kf, vf, outf, lse, B, Hq, Hkv = res
    G = Hq // Hkv
    gf = _to_head_major(g)
    dq, dk, dv = K.flash_attention_bwd(
        qf, kf, vf, outf, lse, gf, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, hq_per_kv=G,
        interpret=_resolve_interpret(interpret))
    return (_from_head_major(dq, B, Hq),
            _from_head_major(dk, B, Hkv),
            _from_head_major(dv, B, Hkv))


flash_attention.defvjp(_fwd_rule, _bwd_rule)


def _pad_axis1(x, to):
    n = to - x.shape[1]
    if n <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[1] = (0, n)
    return jnp.pad(x, widths)


def ring_chunk_attention(q, k_new, v_new, k_cache, v_cache, pos, n_tokens, *,
                         window=0, softcap=0.0, block_q=32, block_kv=32,
                         interpret=None):
    """Blocked (online-softmax) drop-in for ``layers.chunk_attention``.

    Same contract as the dense reference — q/k_new/v_new: (B, C, H*, dh),
    k_cache/v_cache: (B, W, Hkv, dh) pre-write ring, pos: (B,) absolute
    position of chunk token 0, n_tokens: (B,) in [0, C] — but the score
    transient is one (block_q, block_kv) tile per grid step instead of the
    dense (C, W+C) block.  All three dense masks collapse into one band
    test on absolute positions computed here: ring keys carry the slot's
    held position (``cache_positions`` on the pre-chunk ring), chunk key
    t' carries pos+t' while t' < n_tokens and a -2^30 sentinel otherwise,
    and ``kp > qp - W`` expresses both ring eviction and intra-chunk
    self-eviction, so chunks wider than the ring (C > W) score exactly.
    Rows with no visible key (idle streams at pos 0, q-block padding)
    return 0 instead of the dense path's discarded uniform-softmax row.
    """
    B, C, Hq, dh = q.shape
    W, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    L = W + C
    bq = max(1, min(block_q, C))
    bkv = max(1, min(block_kv, L))
    Cp = -(-C // bq) * bq
    Lp = -(-L // bkv) * bkv

    pos = pos.astype(jnp.int32)
    n_tokens = n_tokens.astype(jnp.int32)
    q_pos = pos[:, None] + jnp.arange(Cp, dtype=jnp.int32)[None, :]
    # prior ring: positions held BEFORE the chunk (pos-1 = last written);
    # never-written slots come out negative, same as cache_positions
    slots = jnp.arange(W, dtype=jnp.int32)
    last = pos[:, None] - 1
    ring_pos = last - ((last - slots[None, :]) % W)
    tc = jnp.arange(C, dtype=jnp.int32)
    chunk_pos = jnp.where(tc[None, :] < n_tokens[:, None],
                          pos[:, None] + tc[None, :], _NEVER)
    kv_pos = jnp.concatenate([ring_pos, chunk_pos], axis=1)
    if Lp > L:
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, Lp - L)),
                         constant_values=_NEVER)

    kcat = _pad_axis1(jnp.concatenate([k_cache, k_new], axis=1), Lp)
    vcat = _pad_axis1(jnp.concatenate([v_cache, v_new], axis=1), Lp)
    qp = _pad_axis1(q, Cp)

    out = K.ring_chunk_attention_fwd(
        _to_head_major(qp), _to_head_major(kcat), _to_head_major(vcat),
        q_pos, kv_pos, ring=W, window=window, softcap=softcap,
        block_q=bq, block_kv=bkv, hq_per_kv=G,
        interpret=_resolve_interpret(interpret))
    return _from_head_major(out, B, Hq)[:, :C]
