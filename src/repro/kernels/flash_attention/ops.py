"""Jit'd public wrapper: GQA flash attention with custom VJP.

``flash_attention(q, k, v)`` takes model-layout tensors (B, S, H, dh) and
handles head-major reshaping, GQA head mapping, and the Pallas fwd/bwd
kernels.  ``interpret=True`` (default on CPU) runs the kernel bodies in
interpret mode for validation; on TPU pass ``interpret=False``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K


def _to_head_major(x):
    B, S, H, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, dh)


def _from_head_major(x, B, H):
    BH, S, dh = x.shape
    return x.reshape(B, H, S, dh).transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, window=0, block_q=256,
                    block_kv=256, interpret=True):
    """q: (B,S,Hq,dh); k/v: (B,Skv,Hkv,dh) -> (B,S,Hq,dh)."""
    out, _ = _fwd(q, k, v, causal, window, block_q, block_kv, interpret)
    return out


def _fwd(q, k, v, causal, window, block_q, block_kv, interpret):
    B, Sq, Hq, dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qf = _to_head_major(q)
    kf = _to_head_major(k)
    vf = _to_head_major(v)
    out, lse = K.flash_attention_fwd(
        qf, kf, vf, causal=causal, window=window, block_q=block_q,
        block_kv=block_kv, hq_per_kv=G, interpret=interpret)
    return _from_head_major(out, B, Hq), (qf, kf, vf, out, lse, B, Hq, Hkv)


def _fwd_rule(q, k, v, causal, window, block_q, block_kv, interpret):
    out, res = _fwd(q, k, v, causal, window, block_q, block_kv, interpret)
    return out, res


def _bwd_rule(causal, window, block_q, block_kv, interpret, res, g):
    qf, kf, vf, outf, lse, B, Hq, Hkv = res
    G = Hq // Hkv
    gf = _to_head_major(g)
    dq, dk, dv = K.flash_attention_bwd(
        qf, kf, vf, outf, lse, gf, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, hq_per_kv=G,
        interpret=interpret)
    return (_from_head_major(dq, B, Hq),
            _from_head_major(dk, B, Hkv),
            _from_head_major(dv, B, Hkv))


flash_attention.defvjp(_fwd_rule, _bwd_rule)
