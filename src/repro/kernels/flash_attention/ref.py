"""Pure-jnp oracle for the flash-attention kernel (materializes scores)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q: (BH, Sq, dh); k/v: (BH, Skv, dh) — heads pre-flattened & pre-mapped.

    Returns (out (BH, Sq, dh) in q.dtype, lse (BH, Sq) f32).
    """
    BH, Sq, dh = q.shape
    Skv = k.shape[1]
    scale = dh ** -0.5 if scale is None else scale
    s = jnp.einsum("bqd,bkd->bqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    q_pos = jnp.arange(Sq)
    kv_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= kv_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    lse = m + jnp.log(l)
    out = jnp.einsum("bqk,bkd->bqd", p / l[..., None], v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype), lse


def gqa_flatten(q, k, v):
    """(B,S,Hq,dh)/(B,S,Hkv,dh) -> head-major (B*Hq,S,dh) with kv repeated."""
    B, Sq, Hq, dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * Hq, -1, dh)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * Hq, -1, dh)
    return qf, kf, vf


def gqa_attention_ref(q, k, v, *, causal=True, window=0):
    """(B,S,Hq,dh) GQA oracle returning (B,S,Hq,dh)."""
    B, Sq, Hq, dh = q.shape
    qf, kf, vf = gqa_flatten(q, k, v)
    out, _ = attention_ref(qf, kf, vf, causal=causal, window=window)
    return out.reshape(B, Hq, Sq, dh).transpose(0, 2, 1, 3)
