from repro.kernels.rglru_scan.ops import lru
from repro.kernels.rglru_scan.kernel import lru_scan
