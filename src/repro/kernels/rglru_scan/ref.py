"""Pure-jnp oracle for the RG-LRU linear recurrence.

h_t = a_t * h_{t-1} + b_t,  naive sequential scan over time (exact).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def lru_scan_ref(a, b, h0=None):
    """a, b: (B, S, W) f32 -> h: (B, S, W); returns (h, h_final)."""
    B, S, W = a.shape
    h0 = jnp.zeros((B, W), jnp.float32) if h0 is None else h0

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    hT, hs = lax.scan(step, h0, (a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2), hT
