"""Pallas TPU kernel: chunked linear recurrence h_t = a_t h_{t-1} + b_t.

TPU adaptation of the RG-LRU scan: instead of a log-depth global
associative scan (which makes log2(S) full passes over HBM), the kernel
makes a SINGLE pass: the sequence is cut into VMEM-resident chunks; within
a chunk the recurrence is solved with an in-register Blelloch-style doubling
scan (log2(chunk) vector ops, no HBM traffic); the chunk-to-chunk carry
lives in VMEM scratch across grid steps.

Grid: (B, W // bw, S // bs) — sequence innermost ("arbitrary"), channel
blocks parallel.  One HBM read of (a, b) and one write of h per element:
memory-optimal for this memory-bound op.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _chunk_scan(a, b):
    """Doubling scan within a chunk.  a, b: (bs, bw) -> h (bs, bw).

    After k steps, (a, b)[t] composes the affine map of steps t-2^k+1 .. t.
    """
    bs = a.shape[0]
    n = 1
    while n < bs:
        a_shift = jnp.pad(a, ((n, 0), (0, 0)), constant_values=1.0)[:bs]
        b_shift = jnp.pad(b, ((n, 0), (0, 0)))[:bs]
        b = a * b_shift + b
        a = a * a_shift
        n *= 2
    return a, b   # a[t] = prod(a_0..t), b[t] = h_t given h_{-1}=0


def _lru_kernel(a_ref, b_ref, h_ref, carry_scr, *, n_s):
    js = pl.program_id(2)

    @pl.when(js == 0)
    def _init():
        carry_scr[...] = jnp.zeros_like(carry_scr)

    a = a_ref[0].astype(jnp.float32)        # (bs, bw)
    b = b_ref[0].astype(jnp.float32)
    a_cum, h_local = _chunk_scan(a, b)
    h = h_local + a_cum * carry_scr[...]    # inject carry from prior chunks
    h_ref[0] = h.astype(h_ref.dtype)
    carry_scr[...] = h[-1:, :]              # (1, bw) final state of the chunk


def lru_scan(a, b, *, block_s=256, block_w=512, interpret=True):
    """a, b: (B, S, W) -> h: (B, S, W) (f32 out).  Single-pass chunked scan."""
    B, S, W = a.shape
    bs = min(block_s, S)
    bw = min(block_w, W)
    assert S % bs == 0 and W % bw == 0, (S, bs, W, bw)
    n_s = S // bs

    h = pl.pallas_call(
        functools.partial(_lru_kernel, n_s=n_s),
        grid=(B, W // bw, n_s),
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda ib, iw, js: (ib, js, iw)),
            pl.BlockSpec((1, bs, bw), lambda ib, iw, js: (ib, js, iw)),
        ],
        out_specs=pl.BlockSpec((1, bs, bw), lambda ib, iw, js: (ib, js, iw)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
    return h
