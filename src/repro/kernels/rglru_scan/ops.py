"""Public wrapper for the RG-LRU chunked-scan kernel.

Training gradients flow through a custom VJP that exploits the recurrence
structure: with y_t = a_t y_{t-1} + b_t,
    db_t = g_t + a_{t+1} db_{t+1}   (reverse scan with the same kernel)
    da_t = db_t * y_{t-1}
so both passes reuse ``lru_scan``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.kernel import lru_scan


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def lru(a, b, block_s=256, block_w=512, interpret=True):
    """h_t = a_t h_{t-1} + b_t over axis 1.  a, b: (B, S, W)."""
    return lru_scan(a, b, block_s=block_s, block_w=block_w,
                    interpret=interpret)


def _fwd(a, b, block_s, block_w, interpret):
    h = lru_scan(a, b, block_s=block_s, block_w=block_w, interpret=interpret)
    return h, (a, h)


def _bwd(block_s, block_w, interpret, res, g):
    a, h = res
    # reverse-time scan: db_t = g_t + a_{t+1} * db_{t+1}
    a_next = jnp.concatenate([a[:, 1:], jnp.zeros_like(a[:, :1])], axis=1)
    db = lru_scan(a_next[:, ::-1], g[:, ::-1].astype(jnp.float32),
                  block_s=block_s, block_w=block_w,
                  interpret=interpret)[:, ::-1]
    h_prev = jnp.concatenate([jnp.zeros_like(h[:, :1]), h[:, :-1]], axis=1)
    da = db * h_prev
    return da.astype(a.dtype), db.astype(a.dtype)


lru.defvjp(_fwd, _bwd)
