"""Data substrate: deterministic synthetic corpus + sharded loader.

* ``SyntheticCorpus`` — seeded Zipf-ish token stream with document structure
  (EOS-delimited docs of geometric length), reproducible per (seed, shard).
* ``write_corpus_shards`` / memmap readers — on-disk int32 shards so the
  loader exercises a real file path (checkpoint/restart resumes mid-shard).
* ``ShardedLoader`` — per-host sharding (host h of H reads shards h::H),
  sequence packing, and background prefetch driven by the ARCAS coroutine
  runtime (a prefetch task yields between shard reads, so the profiler sees
  data-stall time).

Batches are host-local numpy; the training loop assembles global arrays via
jax.device_put with the batch NamedSharding.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.tasks import TaskRuntime


class SyntheticCorpus:
    """Deterministic Zipf token documents."""

    def __init__(self, vocab: int, seed: int = 0, *, eos: int = 1,
                 mean_doc_len: int = 512, zipf_a: float = 1.2):
        self.vocab = vocab
        self.seed = seed
        self.eos = eos
        self.mean_doc_len = mean_doc_len
        self.zipf_a = zipf_a

    def shard_tokens(self, shard: int, n_tokens: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, shard))
        toks = rng.zipf(self.zipf_a, size=int(n_tokens * 1.05)) % self.vocab
        toks = np.clip(toks, 2, self.vocab - 1).astype(np.int32)
        # insert EOS at geometric document boundaries
        p = 1.0 / self.mean_doc_len
        eos_mask = rng.random(toks.shape[0]) < p
        toks[eos_mask] = self.eos
        return toks[:n_tokens]


def write_corpus_shards(path: str, corpus: SyntheticCorpus, *,
                        n_shards: int, tokens_per_shard: int) -> List[str]:
    os.makedirs(path, exist_ok=True)
    files = []
    for s in range(n_shards):
        f = os.path.join(path, f"shard_{s:05d}.npy")
        if not os.path.exists(f):
            np.save(f, corpus.shard_tokens(s, tokens_per_shard))
        files.append(f)
    return files


@dataclasses.dataclass
class LoaderState:
    shard_idx: int = 0
    offset: int = 0
    step: int = 0


class ShardedLoader:
    """Packing loader over memmap shards with coroutine prefetch."""

    def __init__(self, files: List[str], *, host: int = 0, n_hosts: int = 1,
                 seq_len: int, batch: int, runtime: Optional[TaskRuntime] = None,
                 prefetch: int = 2):
        self.files = files[host::n_hosts]
        if not self.files:
            raise ValueError("no shards for this host")
        self.seq_len = seq_len
        self.batch = batch
        self.state = LoaderState()
        self.runtime = runtime
        self._queue: List[np.ndarray] = []
        self._prefetch = prefetch

    # -- core read ---------------------------------------------------------
    def _read_block(self) -> np.ndarray:
        need = self.batch * (self.seq_len + 1)
        out = np.empty(need, np.int32)
        got = 0
        st = self.state
        while got < need:
            arr = np.load(self.files[st.shard_idx % len(self.files)],
                          mmap_mode="r")
            take = min(need - got, arr.shape[0] - st.offset)
            out[got:got + take] = arr[st.offset:st.offset + take]
            got += take
            st.offset += take
            if st.offset >= arr.shape[0]:
                st.shard_idx += 1
                st.offset = 0
        st.step += 1
        return out.reshape(self.batch, self.seq_len + 1)

    # -- coroutine prefetch (§4.4: tasks with yield points) -----------------
    def _prefetch_task(self):
        while len(self._queue) < self._prefetch:
            self._queue.append(self._read_block())
            yield  # yield point: profiler hook runs, task may migrate

    def next(self) -> np.ndarray:
        if self.runtime is not None:
            self.runtime.spawn(self._prefetch_task(), name="prefetch")
            self.runtime.barrier()
        if self._queue:
            return self._queue.pop(0)
        return self._read_block()

    # -- checkpointable position --------------------------------------------
    def state_dict(self) -> Dict:
        return dataclasses.asdict(self.state)

    def load_state_dict(self, d: Dict):
        self.state = LoaderState(**d)


def make_batch(cfg: ModelConfig, block: np.ndarray, *, pad_id: int = 0
               ) -> Dict[str, np.ndarray]:
    """block: (B, S+1) int32 -> model batch dict (numpy, host-local)."""
    B, S1 = block.shape
    S = S1 - 1
    tokens = block[:, :-1]
    targets = block[:, 1:].astype(np.int32)
    mask = np.ones((B, S), np.float32)
    if cfg.family == "vlm":
        sv = int(S * cfg.vision_frac)
        rng = np.random.default_rng(int(block[0, 0]) + 17)
        return {
            "tokens": tokens[:, :S - sv].astype(np.int32),
            "vision_embeds": (rng.standard_normal((B, sv, cfg.d_model))
                              * 0.02).astype(np.float32),
            "position_ids": np.broadcast_to(np.arange(S, dtype=np.int32),
                                            (3, B, S)).copy(),
            "targets": targets, "mask": mask,
        }
    if cfg.family == "encdec":
        st = S // 2
        rng = np.random.default_rng(int(block[0, 0]) + 29)
        return {
            "frame_embeds": (rng.standard_normal((B, st, cfg.d_model))
                             * 0.02).astype(np.float32),
            "tokens": tokens[:, :st].astype(np.int32),
            "targets": targets[:, :st], "mask": mask[:, :st],
        }
    return {"tokens": tokens.astype(np.int32), "targets": targets,
            "mask": mask}
