from repro.data.pipeline import (SyntheticCorpus, ShardedLoader, make_batch,
                                 write_corpus_shards)
