"""Checkpointing: atomic, async, elastic (reshard-on-load).

Layout: <dir>/step_<N>/  with one .npy per pytree leaf (path-flattened
names) + manifest.json (paths, shapes, dtypes, step, user metadata).
Writes go to <dir>/.tmp_step_<N> then os.rename — a crashed writer never
corrupts the latest checkpoint (restart-safe).  ``async_save`` runs the
serialization on a writer thread; ``wait()`` joins before the next save.

Elastic restore: leaves are loaded host-side and ``jax.device_put`` onto
whatever mesh/shardings the *restoring* job uses — a checkpoint written on
a (data=16, model=16) layout restores onto (data=8, model=32), a different
spread_rate, or a degraded 255-chip sub-mesh without conversion.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize/cast bf16 natively: store as a uint16 view
_VIEW_AS = {"bfloat16": np.uint16}
_VIEW_BACK = {"bfloat16": ml_dtypes.bfloat16}


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        out.append((name, leaf))
    return out


def save_pytree(path: str, tree, *, metadata: Optional[Dict] = None):
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"leaves": [], "metadata": metadata or {}}
    for name, leaf in _flatten_with_paths(tree):
        arr = np.asarray(leaf)
        fname = name.replace("/", "__") + ".npy"
        dtype_name = str(arr.dtype)
        if dtype_name in _VIEW_AS:
            np.save(os.path.join(tmp, fname), arr.view(_VIEW_AS[dtype_name]))
        else:
            np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"path": name, "file": fname, "shape": list(arr.shape),
             "dtype": dtype_name})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def load_pytree(path: str, like, *, shardings=None):
    """Restore into the structure of ``like`` (values replaced).

    ``shardings``: optional matching pytree of NamedShardings — leaves are
    device_put directly to their (possibly different) target layout.
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    names = [n for n, _ in _flatten_with_paths(like)]
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_like))
    out = []
    for name, leaf, shd in zip(names, leaves_like, shard_leaves):
        e = by_path[name]
        arr = np.load(os.path.join(path, e["file"]))
        if e["dtype"] in _VIEW_BACK:
            arr = arr.view(_VIEW_BACK[e["dtype"]])
        tgt_dtype = getattr(leaf, "dtype", arr.dtype)
        if str(tgt_dtype) in _VIEW_BACK and str(arr.dtype) not in _VIEW_BACK:
            arr = arr.astype(np.float32)
        arr = arr.astype(tgt_dtype)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["metadata"]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            m = re.match(r"step_(\d+)$", d)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, *, metadata: Optional[Dict] = None,
             blocking: bool = True):
        meta = dict(metadata or {}, step=step)
        # pull to host before handing to the writer thread
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if blocking:
            save_pytree(self._step_dir(step), host_tree, metadata=meta)
            self._gc()
        else:
            self.wait()

            def _run():
                save_pytree(self._step_dir(step), host_tree, metadata=meta)
                self._gc()

            self._thread = threading.Thread(target=_run, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, like, *, step: Optional[int] = None, shardings=None):
        self.wait()
        step = step if step is not None else self.latest()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return load_pytree(self._step_dir(step), like, shardings=shardings)

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
