"""ARCAS core: the paper's contribution adapted to TPU pods.

topology   — chiplet-group model of the fleet (Fig. 2/3)
counters   — §4.5 profiler (libpfm -> HLO/step-clock)
controller — Algorithm 1 + approaches/policies (§4.1-4.3)
layout     — Algorithm 2 + mesh/PartitionSpec synthesis
costmodel  — three-term roofline objective
tasks      — §4.4 coroutines + chiplet-first work stealing
scheduler  — global scheduler (migration via device_put)
api        — §4.6 developer API (ARCAS_Init/run/all_do/call/barrier)
"""
from repro.core.topology import ChipletTopology, HardwareSpec, production_topology
from repro.core.counters import PerfCounters
from repro.core.layout import Layout, layout_family, update_location
from repro.core.controller import AdaptiveController, ControllerConfig
from repro.core.costmodel import estimate, best_layout, StepCost
from repro.core.tasks import BLOCK, Task, TaskRuntime
from repro.core.scheduler import (GlobalScheduler, MigrationEvent,
                                  RelayoutHandler, TieredQueues,
                                  migrate_pytree)
