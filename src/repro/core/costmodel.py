"""Three-term roofline cost model — the controller's objective function.

For a (ModelConfig, ShapeConfig, Layout) this estimates, per step:

  compute_s  — FLOPs / (chips * peak)
  memory_s   — HBM traffic / (chips * hbm_bw)
  ici/dcn_s  — collective bytes / link bandwidth, split by link class
               (intra-group / cross-group / cross-pod)

and the byte counters the ARCAS profiler feeds to Algorithm 1
(local_bytes / remote_bytes / dcn_bytes), plus the per-replica working set
for the capacity guard (the Fig. 5 "does it fit in s groups' HBM" test).

These are *napkin* numbers for placement decisions and the paper-figure
simulations; the §Roofline deliverable derives its terms from the compiled
HLO (launch/dryrun.py) and uses this module only as a cross-check.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.layout import Layout


@dataclasses.dataclass(frozen=True)
class StepCost:
    compute_s: float
    memory_s: float
    ici_local_s: float           # intra-group collective time
    ici_remote_s: float          # cross-group collective time
    dcn_s: float
    local_bytes: float           # per-chip HBM bytes (counter feed)
    remote_bytes: float          # per-chip cross-group bytes (counter feed)
    dcn_bytes: float
    working_set: float           # per-replica resident bytes
    fits: bool

    @property
    def collective_s(self) -> float:
        return self.ici_local_s + self.ici_remote_s + self.dcn_s

    @property
    def total_s(self) -> float:
        """No-overlap upper bound."""
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def overlap_s(self) -> float:
        """Perfect-overlap lower bound (max of the three terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)


# ---------------------------------------------------------------------------
# FLOPs / bytes primitives
# ---------------------------------------------------------------------------

def fwd_flops_per_token(cfg: ModelConfig, seq_len: int, *,
                        decode: bool = False) -> float:
    """Forward FLOPs per token (matmuls + attention/ssd terms)."""
    D, F = cfg.d_model, cfg.d_ff
    flops = 0.0
    kv_span = min(seq_len, cfg.window) if cfg.window else seq_len
    for lt in cfg.layer_types():
        if lt == "attn":
            Hq, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            flops += 2 * D * (Hq + 2 * Hkv) * dh          # qkv proj
            flops += 2 * Hq * dh * D                      # out proj
            span = kv_span if decode else kv_span / 2      # causal avg
            flops += 2 * 2 * Hq * dh * span               # qk + pv
            if cfg.n_experts:
                mult = 3 if cfg.activation in ("swiglu", "gelu_glu") else 2
                flops += 2 * mult * D * F * cfg.top_k     # active experts
                flops += 2 * D * cfg.n_experts            # router
            else:
                mult = 3 if cfg.activation in ("swiglu", "gelu_glu",
                                               "relu_glu") else 2
                flops += 2 * mult * D * F
        elif lt == "rec":
            W = cfg.lru_width
            flops += 2 * D * W * 2 + 2 * W * W * 2 + 2 * W * D   # projections+gates
            mult = 3 if cfg.activation in ("swiglu", "gelu_glu") else 2
            flops += 2 * mult * D * F
        elif lt == "ssd":
            di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
            GN = cfg.ssm_groups * N
            flops += 2 * D * (2 * di + 2 * GN + H)        # in projections
            flops += 2 * di * D                           # out proj
            Q = cfg.ssd_chunk
            if decode:
                flops += 2 * H * cfg.ssm_head_dim * N * 2  # state update + C.h
            else:
                # intra-chunk QxQ scores + two (Q,N)x(N,P) products per token
                flops += 2 * N * Q + 2 * 2 * N * cfg.ssm_head_dim * H / max(H, 1) * H
    # embedding gather is O(D); head matmul:
    head_tokens = 1.0  # per token
    flops += 2 * D * cfg.vocab * head_tokens
    if cfg.family == "encdec":
        flops *= 1.0  # enc+dec both included via layer_types? encdec uses n_layers
        # add cross-attention per decoder layer
        Hq, dh = cfg.n_heads, cfg.head_dim
        flops += cfg.dec_layers * (2 * D * Hq * dh * 3 + 2 * 2 * Hq * dh *
                                   (seq_len / 2))
    return flops


def step_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        remat_factor = {"none": 3.0, "block": 4.0, "full": 4.0}[cfg.remat]
        return remat_factor * fwd_flops_per_token(cfg, shape.seq_len) * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return fwd_flops_per_token(cfg, shape.seq_len) * tokens
    # decode: one token per stream against a seq_len-deep cache
    return fwd_flops_per_token(cfg, shape.seq_len, decode=True) * shape.global_batch


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """The 6*N*D convention (6*N_active*D for MoE) for the §Roofline ratio."""
    from repro.models.params import n_params
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch


def kv_cache_bytes(cfg: ModelConfig, shape: ShapeConfig, batch: int) -> float:
    """Decode-state bytes for ``batch`` streams at context shape.seq_len."""
    itemsize = 2  # bf16
    total = 0.0
    S = shape.seq_len
    for lt in cfg.layer_types():
        if lt == "attn":
            W = min(S, cfg.window) if cfg.window else S
            if cfg.family == "hybrid":
                W = min(S, cfg.local_window)
            total += 2 * batch * W * cfg.n_kv_heads * cfg.head_dim * itemsize
        elif lt == "rec":
            total += batch * cfg.lru_width * 4
        elif lt == "ssd":
            total += batch * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
    if cfg.family == "encdec":
        total += 2 * cfg.dec_layers * batch * 4096 * cfg.n_kv_heads * \
            cfg.head_dim * itemsize  # cross-attn KV at S_src=4096
    return total


def kv_token_bytes(cfg: ModelConfig) -> float:
    """Ring-cache bytes ONE stream commits per context token (the slope of
    ``kv_cache_bytes`` in ``seq_len`` below the window cap)."""
    itemsize = 2  # bf16
    total = 0.0
    for lt in cfg.layer_types():
        if lt == "attn":
            total += 2 * cfg.n_kv_heads * cfg.head_dim * itemsize
    return total


def kv_state_bytes(cfg: ModelConfig) -> float:
    """Per-stream decode-state bytes with NO token dependence (recurrent /
    SSD states, enc-dec cross-attention KV) — the intercept of
    ``kv_cache_bytes``."""
    total = 0.0
    for lt in cfg.layer_types():
        if lt == "rec":
            total += cfg.lru_width * 4
        elif lt == "ssd":
            total += cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
    if cfg.family == "encdec":
        total += 2 * cfg.dec_layers * 4096 * cfg.n_kv_heads * cfg.head_dim * 2
    return total


def kv_spill_bytes(cfg: ModelConfig, pages: int, block_tokens: int,
                   with_state: bool = True) -> float:
    """Host bytes ONE spilled stream parks in the swap tier: its used ring
    pages (``pages`` pages of ``block_tokens`` tokens) plus its per-stream
    state.  This is also the D2H+H2D traffic one spill/restore cycle costs
    — the number to weigh against ``recompute`` FLOPs when deciding
    whether swapping beats restart-eviction."""
    return (pages * block_tokens * kv_token_bytes(cfg)
            + (kv_state_bytes(cfg) if with_state else 0.0))


def kv_transfer_seconds(n_bytes: float, bw: float) -> float:
    """Wall-clock seconds one swap-tier transfer of ``n_bytes`` occupies
    the host link at bandwidth ``bw`` (``HardwareSpec.d2h_bw`` /
    ``h2d_bw``).  This is the window the async transfer engine has to hide
    behind decode ticks: a spill is "free" when the victim's line wait
    exceeds ``kv_transfer_seconds(kv_spill_bytes(...), d2h_bw)``."""
    return float(n_bytes) / max(float(bw), 1.0)


def kv_spill_transfer_seconds(cfg: ModelConfig, pages: int,
                              block_tokens: int, bw: float,
                              with_state: bool = True) -> float:
    """One spill (or restore) priced on the host link: the swap-tier
    payload of ``kv_spill_bytes`` moved at ``bw``."""
    return kv_transfer_seconds(
        kv_spill_bytes(cfg, pages, block_tokens, with_state), bw)


def kv_bypass_floor_bytes(cfg: ModelConfig, head_need_pages: int,
                          block_tokens: int,
                          with_state: bool = False) -> float:
    """Device bytes a size-aware bypass grant must leave FREE for the
    blocked head of the admission wait line — the bypass-safety bound.

    The head's provable need is a page count (its next grow chunk, a
    whole-table migrate, or its spill-restore footprint); this prices it
    at the same per-token ring rate as :func:`kv_spill_bytes` — by
    construction: a floor large enough to restore the head from the swap
    tier is large enough for every cheaper regrant path.  ``with_state``
    adds the per-stream state slot a spilled hybrid head re-takes on
    restore.  A bypass is safe only when the granting domain keeps this
    floor free, so the head's time-to-grant is never delayed."""
    return (max(head_need_pages, 0) * block_tokens * kv_token_bytes(cfg)
            + (kv_state_bytes(cfg) if with_state else 0.0))


def spec_rejected_bytes(cfg: ModelConfig, rejected_tokens: int) -> float:
    """HBM bytes the speculative verify forward moved for draft tokens
    greedy acceptance then threw away — the honest cost of optimism.

    Per rejected token: its activation row streamed through every layer
    (read + write of a ``d_model`` bf16 vector per layer) plus the ring-KV
    write the masked cache update committed before rollback restored the
    page (``kv_token_bytes``).  Napkin bound like the rest of this module:
    weights stream once per CHUNK regardless of width, so the marginal
    token pays only its activation and cache traffic."""
    act = 2.0 * cfg.d_model * len(cfg.layer_types()) * 2.0
    return rejected_tokens * (act + kv_token_bytes(cfg))


def spec_rollback_bytes(cfg: ModelConfig, ckpt_pages: int,
                        restored_pages: int, block_tokens: int, *,
                        ckpts: int = 0, rollbacks: int = 0) -> float:
    """Host round-trip bytes the optimistic-commit rollback protocol pays:
    every speculative tick snapshots its write-touched pages (+ state
    slot) D2H (``ckpt_pages`` over ``ckpts`` checkpoints) and every
    partial accept restores them H2D (``restored_pages`` over
    ``rollbacks``), priced with the same per-page formula as the swap
    tier."""
    return (kv_spill_bytes(cfg, ckpt_pages, block_tokens, with_state=False)
            + ckpts * kv_state_bytes(cfg)
            + kv_spill_bytes(cfg, restored_pages, block_tokens,
                             with_state=False)
            + rollbacks * kv_state_bytes(cfg))


def kv_dedup_bytes(cfg: ModelConfig, shared_extra_refs: int,
                   block_tokens: int) -> float:
    """Ring-cache bytes prefix sharing keeps OFF the device right now:
    every table->page reference beyond a shared page's first holder
    (``shared_extra_refs``) is a page-sized footprint served without a
    resident copy of its own.  Logical KV bytes = resident + this; the
    benchmark reports both so capacity claims stay honest."""
    return shared_extra_refs * block_tokens * kv_token_bytes(cfg)


def prefill_chunk_score_bytes(cfg: ModelConfig, chunk_tokens: int,
                              max_len: int = 0, kernel: str = "dense",
                              block_q: int = 32, block_kv: int = 32) -> float:
    """f32 attention-score transient ONE stream materializes in the
    PARALLEL (fused) chunk forward.

    ``kernel="dense"`` (the einsum reference): per query head, TWO live
    (C, W + C) buffers — the joint score block over [W-slot prior ring,
    intra-chunk causal] and its softmax probabilities (the per-source
    partial scores fuse into the concatenation).  ``kernel="blocked"``
    (the Pallas online-softmax ring kernel): the same two buffers but
    clipped to ONE (block_q, block_kv) tile — the live transient per grid
    step, independent of W and C once both exceed the block sizes.

    Layers run under ``lax.scan``, so only the widest layer's buffers are
    live at once.  Enc-dec cross-attention runs through BLOCKED (flash)
    attention either way, so it adds one (C, block_kv) score block — never
    the full (C, S_src) matrix (the S_src=4096 convention caps the block).
    Zero for pure-state models and for the scan path (whose per-token
    score rows are negligible)."""
    if kernel not in ("dense", "blocked"):
        raise ValueError(f"unknown chunk kernel {kernel!r}")
    if max_len:
        chunk_tokens = min(chunk_tokens, max_len)
    C = float(chunk_tokens)
    hybrid = cfg.family == "hybrid"
    per_layer = [0.0]
    for lt in cfg.layer_types():
        if lt != "attn":
            continue
        w = cfg.local_window if hybrid else cfg.window
        W = min(max_len, w) if (w and max_len) else (w or max_len)
        if kernel == "blocked":
            b = (2.0 * cfg.n_heads * min(block_q, C)
                 * min(block_kv, W + C) * 4.0)
        else:
            b = 2.0 * cfg.n_heads * C * (W + C) * 4.0
        if cfg.family == "encdec":
            b += cfg.n_heads * C * min(cfg.attn_block_kv, 4096) * 4.0
        per_layer.append(b)
    return max(per_layer)


def prefill_chunk_bytes(cfg: ModelConfig, chunk_tokens: int,
                        max_len: int = 0, mode: str = "scan",
                        kernel: str = "dense") -> float:
    """Byte-accurate transient footprint of ONE chunked-prefill step: the
    ring KV written for ``chunk_tokens`` new tokens plus the per-stream
    state carried between chunks.  This bounds the outside-the-pool prefill
    buffer regardless of prompt length — the number to compare against the
    ``kv_cache_bytes(prompt)`` single-stream cache that whole-prompt
    prefill materializes before scattering.  ``mode="parallel"`` adds the
    fused path's attention-score transient
    (``prefill_chunk_score_bytes``) for the given ``kernel``, so chunk-size
    sweeps compare honest footprints across compiled paths AND kernels."""
    if max_len:
        chunk_tokens = min(chunk_tokens, max_len)
    base = chunk_tokens * kv_token_bytes(cfg) + kv_state_bytes(cfg)
    if mode == "parallel":
        base += prefill_chunk_score_bytes(cfg, chunk_tokens, max_len,
                                          kernel=kernel)
    return base


# ---------------------------------------------------------------------------
# Full step cost
# ---------------------------------------------------------------------------

def estimate(cfg: ModelConfig, shape: ShapeConfig, layout: Layout,
             *, optimizer_bytes_per_param: float = 8.0,
             chiplet_agnostic: bool = False) -> StepCost:
    """``chiplet_agnostic=True`` models a NUMA-aware-but-chiplet-blind
    runtime (the RING/Shoal baselines): same (replicas x shards)
    factorization, but device order stripes TP rings across chiplet groups,
    so ALL tensor-parallel traffic crosses group boundaries."""
    from repro.models.params import param_bytes

    t = layout.topology
    hw = t.hw
    chips = t.total_chips
    m = layout.model_degree
    R = layout.replicas
    pbytes = param_bytes(cfg)
    n_par = pbytes / 2  # bf16 params

    flops = step_flops(cfg, shape)
    compute_s = flops / (chips * hw.peak_flops_bf16)

    # --- HBM traffic per chip ---
    if shape.kind == "train":
        # params read + grad write + optimizer read/write + activations
        act = 2.0 * shape.global_batch * shape.seq_len * cfg.d_model * \
            cfg.n_layers * 2 / chips
        hbm = (pbytes / m) * 3 + (n_par * optimizer_bytes_per_param) / m + act
    elif shape.kind == "prefill":
        act = 2.0 * shape.global_batch * shape.seq_len * cfg.d_model * \
            cfg.n_layers * 2 / chips
        hbm = pbytes / m + act
    else:
        batch_per_replica = max(1, shape.global_batch // R)
        kv = kv_cache_bytes(cfg, shape, batch_per_replica) / m
        hbm = pbytes / m + kv
    memory_s = hbm / hw.hbm_bw

    # --- collectives ---
    tokens_per_replica = (shape.global_batch // max(R, 1)) * (
        1 if shape.is_decode else shape.seq_len)
    tokens_per_replica = max(tokens_per_replica, 1)
    act_bytes = tokens_per_replica * cfg.d_model * 2

    # TP: ~2 all-reduces of the activations per layer (Megatron pattern)
    tp_bytes_per_chip = (cfg.n_layers * 2 * 2 * act_bytes * (m - 1) / m)
    tp_cross = layout.spread_rate > 1 or chiplet_agnostic
    ici_local_b = 0.0 if tp_cross else tp_bytes_per_chip
    ici_remote_b = tp_bytes_per_chip if tp_cross else 0.0

    dcn_b = 0.0
    dp_bytes_per_chip = 0.0
    if shape.kind == "train" and R > 1:
        # DP grad all-reduce over replicas: always crosses groups
        dp_bytes_per_chip = 2 * (pbytes / m) * (R - 1) / R
        if t.n_pods > 1:
            # hierarchical: intra-pod reduce-scatter + cross-pod exchange
            dcn_b = 2 * (pbytes / m) / t.n_pods
            dp_bytes_per_chip *= (1 - 1 / t.n_pods)
        ici_remote_b += dp_bytes_per_chip

    # latency floors (the Fig. 3 hierarchy): every TP collective pays the
    # link-class latency — decode steps are small-message latency-bound,
    # which is what makes compact placement win for small working sets
    n_tp_coll = 2 * cfg.n_layers
    tp_lat = n_tp_coll * (t.hw.lat_intra_pod if tp_cross
                          else t.hw.lat_intra_group)
    ici_local_s = ici_local_b / t.bandwidth("intra_group") + \
        (0.0 if tp_cross else tp_lat)
    ici_remote_s = ici_remote_b / t.bandwidth("intra_pod") + \
        (tp_lat if tp_cross else 0.0)
    dcn_s = dcn_b / t.bandwidth("cross_pod")

    # --- capacity ---
    if shape.kind == "train":
        ws = pbytes + n_par * (2.0 + optimizer_bytes_per_param)  # p+g+opt
        ws += 2.0 * (shape.global_batch / max(R, 1)) * shape.seq_len * \
            cfg.d_model * 2 * (2 if cfg.remat == "none" else 0.3) * \
            math.sqrt(cfg.n_layers)
    else:
        bpr = max(1, shape.global_batch // max(R, 1))
        ws = pbytes + kv_cache_bytes(cfg, shape, bpr)

    return StepCost(
        compute_s=compute_s, memory_s=memory_s,
        ici_local_s=ici_local_s, ici_remote_s=ici_remote_s, dcn_s=dcn_s,
        local_bytes=hbm + ici_local_b,
        remote_bytes=ici_remote_b,
        dcn_bytes=dcn_b,
        working_set=ws,
        fits=layout.fits(ws),
    )


def best_layout(cfg: ModelConfig, shape: ShapeConfig, layouts) -> Layout:
    """argmin modeled step time over feasible layouts (model_guided policy)."""
    feasible = [(estimate(cfg, shape, l), l) for l in layouts]
    ok = [(c, l) for c, l in feasible if c.fits]
    pool = ok or feasible
    return min(pool, key=lambda cl: cl[0].overlap_s)[1]
