"""The ARCAS developer API (paper §4.6), faithful surface:

    ARCAS_Init() / ARCAS_Finalize()
    run(fn)              — spawn a coroutine task
    all_do(fn)           — execute a task on every worker ("all cores")
    call(group, fn)      — remote procedure call to a chiplet group
                           (sync or async)
    barrier()            — coordinate task completion across groups

Backed by the coroutine runtime of ``repro.core.tasks``.
"""
from __future__ import annotations

import types
from typing import Any, Callable, Generator, List, Optional

from repro.core.counters import PerfCounters
from repro.core.tasks import Task, TaskRuntime
from repro.core.topology import ChipletTopology, production_topology

_RUNTIME: Optional[TaskRuntime] = None
_TOPOLOGY: Optional[ChipletTopology] = None


def _as_gen(fn: Callable) -> Generator:
    """Wrap a plain callable into a single-yield coroutine."""
    if isinstance(fn, types.GeneratorType):
        return fn
    def gen():
        yield
        return fn()
    return gen()


def ARCAS_Init(topology: Optional[ChipletTopology] = None,
               workers_per_group: int = 1, seed: int = 0) -> TaskRuntime:
    global _RUNTIME, _TOPOLOGY
    _TOPOLOGY = topology or production_topology()
    _RUNTIME = TaskRuntime(
        n_pods=_TOPOLOGY.n_pods, groups_per_pod=_TOPOLOGY.groups_per_pod,
        workers_per_group=workers_per_group, seed=seed)
    return _RUNTIME


def ARCAS_Finalize():
    global _RUNTIME, _TOPOLOGY
    if _RUNTIME is not None:
        _RUNTIME.barrier()
    _RUNTIME, _TOPOLOGY = None, None


def _rt() -> TaskRuntime:
    if _RUNTIME is None:
        raise RuntimeError("call ARCAS_Init() first")
    return _RUNTIME


def run(fn: Callable | Generator, *, group: Optional[int] = None,
        name: str = "") -> Task:
    return _rt().spawn(_as_gen(fn), group=group, name=name)


def all_do(fn: Callable[[int], Any]) -> List[Task]:
    """Execute ``fn(worker_group)`` on every worker."""
    return [_rt().spawn(_as_gen(lambda g=w.group: fn(g)), group=w.group)
            for w in _rt().workers]


def call(group: int, fn: Callable, *, sync: bool = True) -> Any:
    """RPC to a chiplet group; sync returns the result."""
    task = _rt().spawn(_as_gen(fn), group=group)
    if sync:
        _rt().barrier()
        return task.result
    return task


def barrier():
    _rt().barrier()
