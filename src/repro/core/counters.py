"""Performance counters — the paper's §4.5 profiler, libpfm replaced by
compiler-derived traffic classes + wall-clock step timing.

Counter names mirror Tab. 1/2 of the paper:
  local_bytes   — HBM traffic served within the replica's own chiplet groups
  remote_bytes  — collective bytes crossing group boundaries within a pod
                  (the "remote NUMA chiplet" / cache-fill event analogue;
                  this is what Algorithm 1 thresholds on)
  dcn_bytes     — cross-pod traffic (the "main memory" analogue)

Counters are cheap (plain floats), support scoped segments (the paper's
"profile only specific code segments"), and keep a ring buffer of recent
step samples for rate estimation.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Deque, Dict, Optional


@dataclasses.dataclass
class StepSample:
    t: float
    step_time: float
    local_bytes: float
    remote_bytes: float
    dcn_bytes: float
    flops: float
    # KV block-pool health (serving): fraction of pool blocks in use, parks
    # (allocation failures) since the previous sample, and blocks copied
    # between chiplet-group domains since the previous sample.
    kv_occupancy: float = 0.0
    kv_parks: float = 0.0
    kv_blocks_migrated: float = 0.0
    # Continuous-batching loop health: pages committed lazily as streams
    # crossed a page boundary, streams parked MID-DECODE on domain
    # exhaustion, and prefill chunks processed — all deltas since the
    # previous sample.
    kv_lazy_grows: float = 0.0
    kv_mid_decode_parks: float = 0.0
    prefill_chunks: float = 0.0
    # Swap-tier eviction health: pages spilled to the host tier, spilled
    # streams restored mid-decode, and tokens thrown away by restart
    # evictions (the wasted-recompute metric the swap tier drives to 0) —
    # deltas since the previous sample.
    kv_spilled_pages: float = 0.0
    kv_restores: float = 0.0
    recompute_tokens: float = 0.0
    # Split mixed ticks: masked prefill-query rows decode streams did NOT
    # execute because the tick ran as a compacted chunk step + a single-
    # token step ((C-1) x decode streams per split tick) — delta since the
    # previous sample.
    mixed_tick_decode_rows_saved: float = 0.0
    # Prefix sharing: admissions that attached shared prompt pages and
    # prompt tokens whose prefill chunks were skipped entirely (deltas),
    # plus the pool's current shared-page footprint — pages with refcount
    # > 1 and the HBM bytes deduplication is saving right now (gauges).
    kv_prefix_hits: float = 0.0
    prefill_tokens_skipped: float = 0.0
    kv_shared_pages: float = 0.0
    kv_shared_bytes: float = 0.0
    # Speculative decoding: draft tokens proposed / accepted by greedy
    # verification and partial-accept rollbacks (deltas since the previous
    # sample), plus the engine's running acceptance-rate gauge
    # (accepted / drafted over the whole run so far).
    spec_tokens_drafted: float = 0.0
    spec_tokens_accepted: float = 0.0
    spec_rollbacks: float = 0.0
    spec_accept_rate: float = 0.0
    # SLO-tiered admission: requests granted PAST a blocked line head
    # (size-aware bypass, provably without delaying the head) and rounds
    # the wait line spent non-empty — deltas since the previous sample.
    kv_bypass_grants: float = 0.0
    kv_head_wait_ticks: float = 0.0
    # Async swap tier: pages/bytes with a D2H spill issued but not yet
    # fenced (gauges at sample time), decode ticks that ran with at least
    # one transfer outstanding, and fences that actually had to wait
    # (deltas) — the overlap-efficiency surface of the transfer engine.
    kv_spill_inflight_pages: float = 0.0
    kv_spill_inflight_bytes: float = 0.0
    kv_ticks_while_inflight: float = 0.0
    kv_fence_waits: float = 0.0


class PerfCounters:
    def __init__(self, window: int = 64, clock=time.monotonic):
        self._clock = clock
        self._window = window
        self.reset()

    # -- event API ----------------------------------------------------------
    def reset(self):
        self.totals: Dict[str, float] = collections.defaultdict(float)
        self.samples: Deque[StepSample] = collections.deque(maxlen=self._window)
        self._epoch = self._clock()
        self._last_reset = self._clock()

    def add(self, name: str, value: float):
        self.totals[name] += value

    def set(self, name: str, value: float):
        """Gauge semantics: overwrite instead of accumulate (e.g. pool
        occupancy)."""
        self.totals[name] = value

    def record_step(self, *, step_time: float, local_bytes: float = 0.0,
                    remote_bytes: float = 0.0, dcn_bytes: float = 0.0,
                    flops: float = 0.0, kv_occupancy: float = 0.0,
                    kv_parks: float = 0.0, kv_blocks_migrated: float = 0.0,
                    kv_lazy_grows: float = 0.0,
                    kv_mid_decode_parks: float = 0.0,
                    prefill_chunks: float = 0.0,
                    kv_spilled_pages: float = 0.0,
                    kv_restores: float = 0.0,
                    recompute_tokens: float = 0.0,
                    mixed_tick_decode_rows_saved: float = 0.0,
                    kv_prefix_hits: float = 0.0,
                    prefill_tokens_skipped: float = 0.0,
                    kv_shared_pages: float = 0.0,
                    kv_shared_bytes: float = 0.0,
                    spec_tokens_drafted: float = 0.0,
                    spec_tokens_accepted: float = 0.0,
                    spec_rollbacks: float = 0.0,
                    spec_accept_rate: float = 0.0,
                    kv_bypass_grants: float = 0.0,
                    kv_head_wait_ticks: float = 0.0,
                    kv_spill_inflight_pages: float = 0.0,
                    kv_spill_inflight_bytes: float = 0.0,
                    kv_ticks_while_inflight: float = 0.0,
                    kv_fence_waits: float = 0.0):
        self.add("steps", 1)
        self.add("local_bytes", local_bytes)
        self.add("remote_bytes", remote_bytes)
        self.add("dcn_bytes", dcn_bytes)
        self.add("flops", flops)
        self.samples.append(StepSample(self._clock(), step_time, local_bytes,
                                       remote_bytes, dcn_bytes, flops,
                                       kv_occupancy, kv_parks,
                                       kv_blocks_migrated, kv_lazy_grows,
                                       kv_mid_decode_parks, prefill_chunks,
                                       kv_spilled_pages, kv_restores,
                                       recompute_tokens,
                                       mixed_tick_decode_rows_saved,
                                       kv_prefix_hits,
                                       prefill_tokens_skipped,
                                       kv_shared_pages, kv_shared_bytes,
                                       spec_tokens_drafted,
                                       spec_tokens_accepted,
                                       spec_rollbacks, spec_accept_rate,
                                       kv_bypass_grants,
                                       kv_head_wait_ticks,
                                       kv_spill_inflight_pages,
                                       kv_spill_inflight_bytes,
                                       kv_ticks_while_inflight,
                                       kv_fence_waits))

    # -- Algorithm 1 inputs ---------------------------------------------------
    def event_counter(self, name: str = "remote_bytes") -> float:
        """Value accumulated since the last ``reset_events`` (Alg.1 line 5)."""
        return self.totals[name] - self.totals.get(name + "__mark", 0.0)

    def reset_events(self, name: str = "remote_bytes"):
        self.totals[name + "__mark"] = self.totals[name]

    def elapsed(self) -> float:
        return self._clock() - self._last_reset

    def mark_time(self):
        self._last_reset = self._clock()

    # -- derived metrics ------------------------------------------------------
    def ema_step_time(self, alpha: float = 0.25) -> Optional[float]:
        if not self.samples:
            return None
        ema = self.samples[0].step_time
        for s in self.samples:
            ema = alpha * s.step_time + (1 - alpha) * ema
        return ema

    def rates(self) -> Dict[str, float]:
        if len(self.samples) < 2:
            return {}
        dt = max(self.samples[-1].t - self.samples[0].t, 1e-9)
        n = len(self.samples)
        return {
            "steps_per_s": n / dt,
            "remote_bytes_per_s": sum(s.remote_bytes for s in self.samples) / dt,
            "local_bytes_per_s": sum(s.local_bytes for s in self.samples) / dt,
            "dcn_bytes_per_s": sum(s.dcn_bytes for s in self.samples) / dt,
        }

    # -- scoped segment profiling (paper: "monitor only specific segments") ---
    @contextlib.contextmanager
    def segment(self, name: str):
        t0 = self._clock()
        try:
            yield
        finally:
            self.add(f"segment/{name}/time", self._clock() - t0)
            self.add(f"segment/{name}/calls", 1)

    def snapshot(self) -> Dict[str, float]:
        return dict(self.totals)
