"""Global scheduler (paper §4.1 component 4) — the single owner of the
adaptive controller, the coroutine task runtime and the current Layout.

Both the Trainer and the ServeEngine run on this substrate.  The control
loop is ``tick()``-driven: each tick advances the task runtime one round (a
yield-point boundary for every running coroutine) and then evaluates
Algorithm 1.  When the controller moves the spread rate, every registered
``RelayoutHandler`` is invoked with the new Layout — handlers perform the
actual state movement (``jax.device_put`` of param / optimizer / KV-cache
pytrees onto the new mesh for training, replica-group merge/split with KV
slot migration for serving: the TPU analogue of moving threads and
rebinding memory).

``TieredQueues`` exposes the §4.4 tier-ordered steal path for
*request-level* objects (serving requests, IO work items), not just
coroutines: pop drains the local queue first, then steals oldest-first from
same-pod queues, then cross-pod — feeding the same remote-traffic counters
Algorithm 1 thresholds on.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, \
    Tuple

import jax

from repro.core.controller import AdaptiveController, ControllerConfig, Decision
from repro.core.counters import PerfCounters
from repro.core.layout import Layout
from repro.core.tasks import TaskRuntime
from repro.core.topology import ChipletTopology

# Called with (new_layout, decision) when Algorithm 1 moves the spread rate.
RelayoutHandler = Callable[[Layout, Decision], None]


@dataclasses.dataclass
class MigrationEvent:
    step: int
    decision: Decision
    seconds: float


class GlobalScheduler:
    def __init__(self, topology: ChipletTopology,
                 controller_cfg: Optional[ControllerConfig] = None,
                 *, spread_rate: int = 1, pod_axis: bool = False,
                 cost_fn=None, working_set_fn=None,
                 counters: Optional[PerfCounters] = None, seed: int = 0,
                 control_enabled: bool = True):
        self.topology = topology
        self.counters = counters or PerfCounters()
        self.controller = AdaptiveController(
            topology, controller_cfg or ControllerConfig(),
            spread_rate=spread_rate, pod_axis=pod_axis,
            cost_fn=cost_fn, working_set_fn=working_set_fn)
        self.tasks = TaskRuntime(
            n_pods=topology.n_pods, groups_per_pod=topology.groups_per_pod,
            seed=seed, counters=self.counters)
        self.control_enabled = control_enabled
        self.migrations: List[MigrationEvent] = []
        self.last_active = 0            # tasks advanced by the latest tick
        self._handlers: List[RelayoutHandler] = []
        self._step = 0

    # ------------------------------------------------------------------
    def layout(self) -> Layout:
        return self.controller.layout()

    def spawn(self, gen, **kw):
        """Spawn a coroutine on the shared task runtime."""
        return self.tasks.spawn(gen, **kw)

    def pending(self) -> bool:
        return self.tasks.pending()

    def register_relayout(self, handler: RelayoutHandler) -> RelayoutHandler:
        """Register a handler invoked (new_layout, decision) on relayout."""
        self._handlers.append(handler)
        return handler

    # ------------------------------------------------------------------
    def tick(self, *, step_metrics: Optional[Dict[str, float]] = None,
             run_tasks: bool = True) -> Optional[Decision]:
        """One beat of the unified control loop.

        Records step metrics, advances every runnable coroutine to its next
        yield point, then runs one Algorithm-1 evaluation; on a spread-rate
        change the registered RelayoutHandlers migrate live state.
        """
        self._step += 1
        if step_metrics:
            self.counters.record_step(
                step_time=step_metrics.get("step_time", 0.0),
                local_bytes=step_metrics.get("local_bytes", 0.0),
                remote_bytes=step_metrics.get("remote_bytes", 0.0),
                dcn_bytes=step_metrics.get("dcn_bytes", 0.0),
                flops=step_metrics.get("flops", 0.0),
                kv_occupancy=step_metrics.get("kv_occupancy", 0.0),
                kv_parks=step_metrics.get("kv_parks", 0.0),
                kv_blocks_migrated=step_metrics.get("kv_blocks_migrated",
                                                    0.0),
                kv_lazy_grows=step_metrics.get("kv_lazy_grows", 0.0),
                kv_mid_decode_parks=step_metrics.get("kv_mid_decode_parks",
                                                     0.0),
                prefill_chunks=step_metrics.get("prefill_chunks", 0.0),
                kv_spilled_pages=step_metrics.get("kv_spilled_pages", 0.0),
                kv_restores=step_metrics.get("kv_restores", 0.0),
                recompute_tokens=step_metrics.get("recompute_tokens", 0.0),
                mixed_tick_decode_rows_saved=step_metrics.get(
                    "mixed_tick_decode_rows_saved", 0.0),
                kv_prefix_hits=step_metrics.get("kv_prefix_hits", 0.0),
                prefill_tokens_skipped=step_metrics.get(
                    "prefill_tokens_skipped", 0.0),
                kv_shared_pages=step_metrics.get("kv_shared_pages", 0.0),
                kv_shared_bytes=step_metrics.get("kv_shared_bytes", 0.0),
                spec_tokens_drafted=step_metrics.get("spec_tokens_drafted",
                                                     0.0),
                spec_tokens_accepted=step_metrics.get(
                    "spec_tokens_accepted", 0.0),
                spec_rollbacks=step_metrics.get("spec_rollbacks", 0.0),
                spec_accept_rate=step_metrics.get("spec_accept_rate", 0.0),
                kv_bypass_grants=step_metrics.get("kv_bypass_grants", 0.0),
                kv_head_wait_ticks=step_metrics.get("kv_head_wait_ticks",
                                                    0.0))
        self.last_active = (self.tasks.tick()
                            if run_tasks and self.tasks.pending() else 0)
        return self._control()

    def _control(self) -> Optional[Decision]:
        if not self.control_enabled:
            return None
        decision = self.controller.maybe_reschedule(self.counters)
        if decision is not None:
            t0 = time.monotonic()
            new_layout = self.layout()
            for h in self._handlers:
                h(new_layout, decision)
            self.migrations.append(
                MigrationEvent(self._step, decision, time.monotonic() - t0))
        return decision

    def run_until_done(self, *, max_rounds: int = 10_000_000,
                       concurrency_trace: Optional[List[int]] = None,
                       metrics_fn: Optional[Callable[[], Dict[str, float]]]
                       = None,
                       round_hook: Optional[Callable[[], None]] = None) -> int:
        """Tick until the task runtime drains; returns rounds used.

        Unlike ``TaskRuntime.run``, the controller fires *during* the run,
        so relayout handlers may migrate state (and spawn replacement
        coroutines) mid-flight.  ``metrics_fn`` — when given — supplies the
        per-round ``step_metrics`` dict fed to the profiler (e.g. the
        serving engine's KV-pool gauges).  ``round_hook`` — when given — is
        called after every tick, at a point where all coroutines sit at
        yield boundaries; the serving engine uses it to watch for
        allocation stalls (every stream BLOCK-parked on pool growth) and
        break them, something no single coroutine can observe from inside.
        """
        rounds = 0
        while self.tasks.pending() and rounds < max_rounds:
            self.tick(step_metrics=metrics_fn() if metrics_fn else None)
            if concurrency_trace is not None:
                concurrency_trace.append(self.last_active)
            if round_hook is not None:
                round_hook()
            rounds += 1
        if self.tasks.pending():
            raise RuntimeError("GlobalScheduler.run_until_done exceeded "
                               "max_rounds")
        return rounds

    # -- legacy single-shot entry (pre-tick API), kept for compatibility ---
    def after_step(self, *, step_metrics: Optional[Dict[str, float]] = None,
                   migrate_fn: Optional[Callable[[Layout], None]] = None
                   ) -> Optional[Decision]:
        """Deprecated: one control evaluation without driving tasks.
        Prefer ``tick()`` with a registered RelayoutHandler."""
        if migrate_fn is None:
            return self.tick(step_metrics=step_metrics, run_tasks=False)
        handler: RelayoutHandler = lambda layout, _d: migrate_fn(layout)
        self._handlers.append(handler)
        try:
            return self.tick(step_metrics=step_metrics, run_tasks=False)
        finally:
            self._handlers.remove(handler)


class TieredQueues:
    """§4.4 tier-ordered work stealing for request-level objects.

    Queue ``i`` belongs to pod ``pods[i]`` (for serving: one queue per
    replica group, pod derived from the Layout).  ``pop(i)`` drains the
    local queue first; otherwise it steals the oldest item from the fullest
    victim queue, walking the tiers outward — counting ``steals_<tier>`` and
    feeding ``remote_bytes`` (plus ``dcn_bytes`` for cross-pod moves) so
    Algorithm 1 sees request migration traffic exactly like coroutine-steal
    traffic.

    With ``neighborhoods`` given (one id per queue), queues sharing a
    neighborhood form a third, cheaper *group* tier searched before the pod
    tier — replicas whose chiplet-group spans are 1-hop ICI neighbors (used
    by the engine when ``spread_rate < groups_per_pod``).  Steal order is
    then: own queue -> same neighborhood ("group") -> same pod ("pod") ->
    anywhere ("fleet").
    """

    def __init__(self, pods: Sequence[int], *,
                 neighborhoods: Optional[Sequence[Any]] = None,
                 counters: Optional[PerfCounters] = None,
                 bytes_fn: Optional[Callable[[Any], float]] = None):
        self._pods = list(pods)
        self._qs: List[Deque[Any]] = [collections.deque() for _ in pods]
        self.counters = counters or PerfCounters()
        self._bytes_fn = bytes_fn or (lambda _item: 1.0)
        by_pod: Dict[int, List[int]] = collections.defaultdict(list)
        for qid, pod in enumerate(self._pods):
            by_pod[pod].append(qid)
        hoods = list(neighborhoods) if neighborhoods is not None else None
        if hoods is not None and len(hoods) != len(self._pods):
            raise ValueError("neighborhoods must give one id per queue")
        # precomputed steal tiers per queue: neighborhood peers (optional),
        # then remaining same-pod peers, then the rest
        self._tiers: List[Tuple[Tuple[str, List[int]], ...]] = []
        for qid, pod in enumerate(self._pods):
            same = [j for j in by_pod[pod] if j != qid]
            rest = [j for j in range(len(self._pods)) if self._pods[j] != pod]
            tiers: List[Tuple[str, List[int]]] = []
            if hoods is not None:
                near = [j for j in same if hoods[j] == hoods[qid]]
                if near:
                    tiers.append(("group", near))
                same = [j for j in same if hoods[j] != hoods[qid]]
            tiers.append(("pod", same))
            tiers.append(("fleet", rest))
            self._tiers.append(tuple(tiers))

    def __len__(self) -> int:
        return sum(len(q) for q in self._qs)

    def pending(self) -> bool:
        return any(self._qs)

    def queue(self, qid: int) -> Deque[Any]:
        """The underlying deque (read/len; prefer push/pop to mutate)."""
        return self._qs[qid]

    def push(self, qid: int, item: Any):
        self._qs[qid].append(item)

    def pop(self, qid: int,
            accept: Optional[Callable[[Any, str], bool]] = None
            ) -> Tuple[Optional[Any], Optional[str]]:
        """-> (item, tier) with tier in {"local", "group", "pod", "fleet"},
        or (None, None) when no queue can serve.

        ``accept(item, tier)`` — when given — is consulted before a steal is
        committed; returning False leaves the item on its victim queue and
        the steal uncounted (the serving engine uses this to refuse steals
        whose KV reservation cannot move into the thief's memory domain).
        """
        q = self._qs[qid]
        if q:
            return q.popleft(), "local"
        for tier, cand in self._tiers[qid]:
            victims = sorted((j for j in cand if self._qs[j]),
                             key=lambda v: (-len(self._qs[v]), v))  # balance
            for j in victims:
                item = self._qs[j][0]
                if accept is not None and not accept(item, tier):
                    continue
                self._qs[j].popleft()
                moved = float(self._bytes_fn(item))
                self.counters.add(f"steals_{tier}", 1)
                self.counters.add("remote_bytes", moved)
                if tier == "fleet":
                    self.counters.add("dcn_bytes", moved)
                return item, tier
        return None, None


def migrate_pytree(tree: Any, shardings: Any) -> Any:
    """Reshard a pytree of arrays onto new NamedShardings (task migration)."""
    return jax.device_put(tree, shardings)
