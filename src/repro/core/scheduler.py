"""Global scheduler (paper §4.1 component 4).

Owns the adaptive controller, the coroutine runtime and the current Layout;
applies policies by *migrating* state: on a spread-rate change the params /
optimizer / cache pytrees are ``jax.device_put`` to the new mesh's
NamedShardings at a step boundary (the TPU analogue of moving threads and
rebinding memory).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.core.controller import AdaptiveController, ControllerConfig, Decision
from repro.core.counters import PerfCounters
from repro.core.layout import Layout
from repro.core.tasks import TaskRuntime
from repro.core.topology import ChipletTopology


@dataclasses.dataclass
class MigrationEvent:
    step: int
    decision: Decision
    seconds: float


class GlobalScheduler:
    def __init__(self, topology: ChipletTopology,
                 controller_cfg: Optional[ControllerConfig] = None,
                 *, spread_rate: int = 1, pod_axis: bool = False,
                 cost_fn=None, working_set_fn=None,
                 counters: Optional[PerfCounters] = None):
        self.topology = topology
        self.counters = counters or PerfCounters()
        self.controller = AdaptiveController(
            topology, controller_cfg or ControllerConfig(),
            spread_rate=spread_rate, pod_axis=pod_axis,
            cost_fn=cost_fn, working_set_fn=working_set_fn)
        self.tasks = TaskRuntime(
            n_pods=topology.n_pods, groups_per_pod=topology.groups_per_pod,
            counters=self.counters)
        self.migrations: List[MigrationEvent] = []
        self._step = 0

    # ------------------------------------------------------------------
    def layout(self) -> Layout:
        return self.controller.layout()

    def after_step(self, *, step_metrics: Optional[Dict[str, float]] = None,
                   migrate_fn: Optional[Callable[[Layout], None]] = None
                   ) -> Optional[Decision]:
        """Call once per training/serving step; may trigger a relayout.

        ``migrate_fn(new_layout)`` performs the actual state movement
        (device_put of the param/opt/cache pytrees onto the new mesh).
        """
        self._step += 1
        if step_metrics:
            self.counters.record_step(
                step_time=step_metrics.get("step_time", 0.0),
                local_bytes=step_metrics.get("local_bytes", 0.0),
                remote_bytes=step_metrics.get("remote_bytes", 0.0),
                dcn_bytes=step_metrics.get("dcn_bytes", 0.0),
                flops=step_metrics.get("flops", 0.0))
        decision = self.controller.maybe_reschedule(self.counters)
        if decision is not None and migrate_fn is not None:
            t0 = time.monotonic()
            migrate_fn(self.layout())
            self.migrations.append(
                MigrationEvent(self._step, decision, time.monotonic() - t0))
        return decision


def migrate_pytree(tree: Any, shardings: Any) -> Any:
    """Reshard a pytree of arrays onto new NamedShardings (task migration)."""
    return jax.device_put(tree, shardings)
