"""Lightweight concurrency (paper §4.4): coroutine tasks + chiplet-first
work stealing.

Tasks are Python generators (user-level continuations with developer-defined
yield points — the coroutine model of the paper).  Each *worker* owns a
deque; a worker whose deque is empty steals: first from workers in the SAME
chiplet group, then same pod, then anywhere — the locality-preserving steal
order of §4.4.  The runtime is cooperative and deterministic (seeded steal
order) so schedulers built on it are testable; at yield points the
integrated profiler hook fires (§4.4: "when a coroutine yields, ARCAS's
profiling system activates").

On TPU the "work" scheduled here is host-side: serving requests,
prefill/decode micro-steps, data prefetch, checkpoint IO.  Device compute
stays inside XLA programs.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import random
import time
from typing import Any, Callable, Deque, Dict, Generator, List, Optional

from repro.core.counters import PerfCounters


@dataclasses.dataclass
class TaskStats:
    spawned_at: float = 0.0
    yields: int = 0
    steals: int = 0
    finished_at: Optional[float] = None


class Task:
    _ids = itertools.count()

    def __init__(self, gen: Generator, *, group: Optional[int] = None,
                 name: str = ""):
        if not isinstance(gen, Generator):
            raise TypeError("Task wraps a generator (coroutine with yields)")
        self.id = next(Task._ids)
        self.gen = gen
        self.group = group              # preferred chiplet group (affinity)
        self.name = name or f"task{self.id}"
        self.stats = TaskStats(spawned_at=time.monotonic())
        self.result: Any = None
        self.done = False

    def step(self) -> bool:
        """Advance to the next yield point.  True if finished."""
        try:
            next(self.gen)
            self.stats.yields += 1
            return False
        except StopIteration as e:
            self.result = getattr(e, "value", None)
            self.done = True
            self.stats.finished_at = time.monotonic()
            return True


class Worker:
    def __init__(self, wid: int, group: int, pod: int):
        self.wid = wid
        self.group = group
        self.pod = pod
        self.deque: Deque[Task] = collections.deque()
        self.executed_steps = 0
        self.stolen = 0

    def push(self, task: Task):
        self.deque.append(task)

    def pop_local(self) -> Optional[Task]:
        return self.deque.pop() if self.deque else None     # LIFO own end

    def steal_from(self) -> Optional[Task]:
        return self.deque.popleft() if self.deque else None  # FIFO victim end


class TaskRuntime:
    """Cooperative scheduler over per-group workers with locality stealing."""

    def __init__(self, *, n_pods: int = 1, groups_per_pod: int = 16,
                 workers_per_group: int = 1, seed: int = 0,
                 counters: Optional[PerfCounters] = None,
                 profile_hook: Optional[Callable[[Task], None]] = None):
        self.counters = counters or PerfCounters()
        self.profile_hook = profile_hook
        self.workers: List[Worker] = []
        for pod in range(n_pods):
            for g in range(groups_per_pod):
                for _ in range(workers_per_group):
                    gid = pod * groups_per_pod + g
                    self.workers.append(Worker(len(self.workers), gid, pod))
        self._rng = random.Random(seed)
        self._rr = 0
        self.steal_log: List[Dict] = []

    # ------------------------------------------------------------------
    def spawn(self, gen: Generator, *, group: Optional[int] = None,
              name: str = "") -> Task:
        task = Task(gen, group=group, name=name)
        w = self._home_worker(task)
        w.push(task)
        self.counters.add("tasks_spawned", 1)
        return task

    def _home_worker(self, task: Task) -> Worker:
        if task.group is not None:
            cands = [w for w in self.workers if w.group == task.group]
            if cands:
                return min(cands, key=lambda w: len(w.deque))
        self._rr = (self._rr + 1) % len(self.workers)
        return self.workers[self._rr]

    # -- §4.4 steal order: same group, then same pod, then anywhere --------
    def _steal(self, thief: Worker) -> Optional[Task]:
        tiers = (
            [w for w in self.workers
             if w is not thief and w.group == thief.group],
            [w for w in self.workers
             if w.group != thief.group and w.pod == thief.pod],
            [w for w in self.workers if w.pod != thief.pod],
        )
        for tier_name, tier in zip(("group", "pod", "fleet"), tiers):
            victims = [w for w in tier if w.deque]
            if victims:
                victim = self._rng.choice(victims)
                task = victim.steal_from()
                if task is not None:
                    thief.stolen += 1
                    task.stats.steals += 1
                    self.counters.add(f"steals_{tier_name}", 1)
                    # cross-group steal = remote traffic (counter feed)
                    if tier_name != "group":
                        self.counters.add("remote_bytes", 1.0)
                    self.steal_log.append(
                        {"thief": thief.wid, "victim": victim.wid,
                         "tier": tier_name, "task": task.id})
                    return task
        return None

    # ------------------------------------------------------------------
    def run(self, *, max_rounds: int = 10_000_000,
            concurrency_trace: Optional[List[int]] = None) -> None:
        """Drive all tasks to completion (cooperative round-robin)."""
        pending = True
        rounds = 0
        while pending and rounds < max_rounds:
            pending = False
            rounds += 1
            active = 0
            for w in self.workers:
                task = w.pop_local() or self._steal(w)
                if task is None:
                    continue
                active += 1
                pending = True
                finished = task.step()
                w.executed_steps += 1
                if self.profile_hook is not None:
                    self.profile_hook(task)           # yield-point profiling
                if not finished:
                    w.push(task)
            if concurrency_trace is not None:
                concurrency_trace.append(active)
        if pending:
            raise RuntimeError("TaskRuntime.run exceeded max_rounds")

    def barrier(self):
        """Paper API: run everything currently queued to completion."""
        self.run()
