"""Lightweight concurrency (paper §4.4): coroutine tasks + chiplet-first
work stealing.

Tasks are Python generators (user-level continuations with developer-defined
yield points — the coroutine model of the paper).  Each *worker* owns a
priority deque; a worker whose deque is empty steals: first from workers in
the SAME chiplet group, then same pod, then anywhere — the
locality-preserving steal order of §4.4.

The steal path is tiered and O(#nonempty): victim tiers are *precomputed*
per worker at construction (group members, pod members) and the runtime
maintains occupancy indexes (which workers currently have work, per group /
per pod / fleet-wide), so an idle worker never rebuilds group/pod/fleet
candidate lists with full worker scans.  The seed's scan-based steal is kept
as ``steal_impl="scan"`` so ``benchmarks/sched_micro.py`` can measure the
win.

The runtime is cooperative and deterministic (seeded steal order) so
schedulers built on it are testable; at yield points the integrated profiler
hook fires (§4.4: "when a coroutine yields, ARCAS's profiling system
activates").  ``run()`` drives everything to completion; ``tick()`` advances
exactly one round so an outer control loop (the GlobalScheduler) can
evaluate Algorithm 1 at yield-point boundaries.

Tasks may park themselves by yielding the ``BLOCK`` sentinel (e.g. a request
waiting on KV-cache space); ``TaskRuntime.unblock`` re-enqueues them on
their home worker.  Higher ``priority`` tasks run before lower ones within a
worker.

On TPU the "work" scheduled here is host-side: serving requests,
prefill/decode micro-steps, data prefetch, checkpoint IO.  Device compute
stays inside XLA programs.
"""
from __future__ import annotations

import bisect
import collections
import dataclasses
import itertools
import random
import time
from typing import Any, Callable, Deque, Dict, FrozenSet, Generator, List, \
    Optional, Tuple

from repro.core.counters import PerfCounters

# Yield this sentinel to park the task until TaskRuntime.unblock(task).
BLOCK = object()

_EMPTY: FrozenSet[int] = frozenset()


@dataclasses.dataclass
class TaskStats:
    spawned_at: float = 0.0
    yields: int = 0
    steals: int = 0
    finished_at: Optional[float] = None


class Task:
    _ids = itertools.count()

    def __init__(self, gen: Generator, *, group: Optional[int] = None,
                 name: str = "", priority: int = 0):
        if not isinstance(gen, Generator):
            raise TypeError("Task wraps a generator (coroutine with yields)")
        self.id = next(Task._ids)
        self.gen = gen
        self.group = group              # preferred chiplet group (affinity)
        self.name = name or f"task{self.id}"
        self.priority = priority        # higher runs first within a worker
        self.state = "ready"            # ready | blocked | done
        self.last_yield: Any = None     # value of the most recent yield
        self.stats = TaskStats(spawned_at=time.monotonic())
        self.result: Any = None
        self.done = False

    def step(self) -> bool:
        """Advance to the next yield point.  True if finished."""
        try:
            self.last_yield = next(self.gen)
            self.stats.yields += 1
            return False
        except StopIteration as e:
            self.result = getattr(e, "value", None)
            self.done = True
            self.state = "done"
            self.stats.finished_at = time.monotonic()
            return True


class Worker:
    """Owns per-priority deques; notifies the runtime on empty<->nonempty
    transitions so the tiered steal path can keep its occupancy indexes."""

    def __init__(self, wid: int, group: int, pod: int,
                 runtime: Optional["TaskRuntime"] = None):
        self.wid = wid
        self.group = group
        self.pod = pod
        self._runtime = runtime
        self._deques: Dict[int, Deque[Task]] = {}
        self._prios: List[int] = []     # ascending; scanned from the back
        self._size = 0
        self.executed_steps = 0
        self.stolen = 0

    def __len__(self) -> int:
        return self._size

    @property
    def deque(self) -> Tuple[Task, ...]:
        """Read-only snapshot (legacy view), highest priority first."""
        out: List[Task] = []
        for p in reversed(self._prios):
            out.extend(self._deques[p])
        return tuple(out)

    def push(self, task: Task):
        was_empty = self._size == 0
        dq = self._deques.get(task.priority)
        if dq is None:
            dq = self._deques[task.priority] = collections.deque()
            bisect.insort(self._prios, task.priority)
        dq.append(task)
        self._size += 1
        if was_empty and self._runtime is not None:
            self._runtime._mark_nonempty(self)

    def _take(self, *, newest: bool) -> Optional[Task]:
        if not self._size:
            return None
        for p in reversed(self._prios):
            dq = self._deques[p]
            if dq:
                task = dq.pop() if newest else dq.popleft()
                self._size -= 1
                if self._size == 0 and self._runtime is not None:
                    self._runtime._mark_empty(self)
                return task
        return None

    def pop_local(self) -> Optional[Task]:
        return self._take(newest=True)      # LIFO own end

    def steal_from(self) -> Optional[Task]:
        return self._take(newest=False)     # FIFO victim end


class WaitQueue:
    """Deterministic FIFO of BLOCK-parked tasks (§4.4 wakeup plumbing).

    A resource owner (e.g. the serving KV block pool) parks tasks that could
    not acquire the resource and wakes them when capacity frees up.  The
    protocol is cooperative and race-free: the task calls ``park(self_task)``
    and immediately ``yield BLOCK``; because the runtime is single-threaded,
    any ``wake`` (triggered by another task's step) can only run after the
    yield has been processed and the task really is blocked.  ``wake``
    re-enqueues parked tasks via ``TaskRuntime.unblock`` in FIFO order.

    The line is ordered by an explicit per-entry SEQ (a monotonic counter
    drawn at park time by default).  ``park(task, seq=...)`` lets a caller
    re-insert a task at a position it held earlier: the serving engine's
    size-aware bypass removes grantees from the MIDDLE of the line, and a
    bypassed stream that later parks mid-decode re-enters at its original
    arrival seq — not the back — so bypass never costs a stream its
    arrival-order claim.  ``to_back`` still draws a fresh (maximal) seq:
    spill victims consumed their turn.
    """

    def __init__(self, runtime: "TaskRuntime", clock=time.monotonic):
        self._rt = runtime
        self._clock = clock
        self._next_seq = 0
        self._q: Dict[int, Task] = {}
        self._order: Dict[int, int] = {}    # task.id -> line seq
        self._parked_at: Dict[int, float] = {}

    def __len__(self) -> int:
        return len(self._q)

    def __contains__(self, task: Task) -> bool:
        return task.id in self._q

    def _line(self) -> List[Task]:
        return sorted(self._q.values(), key=lambda t: self._order[t.id])

    def park(self, task: Task, seq: Optional[int] = None) -> int:
        """Join the wait line (idempotent: re-parking a task already in the
        line keeps its position, so a woken task that fails its retry and
        parks again has not lost its turn).  ``seq`` pins the line position
        (see class docstring); default is a fresh counter value — the back
        of the line.  Returns the seq the task holds."""
        if task.id in self._q:
            return self._order[task.id]
        self._parked_at[task.id] = self._clock()
        self._q[task.id] = task
        s = self._draw() if seq is None else seq
        # keep the counter strictly past any pinned seq, so a later
        # default park or ``to_back`` is genuinely the back of the line
        self._next_seq = max(self._next_seq, s + 1)
        self._order[task.id] = s
        return s

    def _draw(self) -> int:
        s = self._next_seq
        self._next_seq += 1
        return s

    def seq_of(self, task: Task) -> Optional[int]:
        """The line seq ``task`` holds, or None if it is not in the line."""
        return self._order.get(task.id)

    def remove(self, task: Task):
        """Leave the line — called by the task itself once its resource
        grant succeeds.  Membership until *grant* (not until wake) is what
        keeps grants FIFO: new arrivals check ``len(queue)`` and a
        woken-but-not-yet-granted head still counts."""
        self._q.pop(task.id, None)
        self._order.pop(task.id, None)
        self._parked_at.pop(task.id, None)

    def to_back(self, task: Task) -> Optional[int]:
        """Re-queue a parked task at the BACK of the line — the regrant
        path for a stream whose resources were reclaimed mid-wait (e.g. a
        KV table spilled to the swap tier): it consumed its turn, so every
        waiter currently in line now goes first.  Resets its parked-since
        clock (the new wait starts now); a no-op for tasks not in line.
        Returns the fresh seq (None for the no-op) so the caller can
        retire any arrival-position claim the task held."""
        if task.id not in self._q:
            return None
        s = self._draw()
        self._order[task.id] = s
        self._parked_at[task.id] = self._clock()
        return s

    def parked_since(self, task: Task) -> Optional[float]:
        """Clock time at which ``task`` first joined the line (survives
        wake/re-park cycles), or None if it is not in the line."""
        return self._parked_at.get(task.id)

    def oldest(self) -> Optional[Task]:
        """The lowest-seq task — the one a free is granted to first."""
        line = self._line()
        return line[0] if line else None

    def youngest(self) -> Optional[Task]:
        """The highest-seq task — the back of the line.  (Note: the
        serving engine's eviction watchdog picks its victim from its own
        mid-decode park records, NOT from this line, which also holds
        admission tasks that hold no resources worth reclaiming.)"""
        line = self._line()
        return line[-1] if line else None

    def tasks(self) -> List[Task]:
        """The whole line, front (lowest seq) first — the bypass safety
        scan walks this to apply the aging backstop."""
        return self._line()

    def wake(self, n: Optional[int] = None) -> int:
        """Wake the first ``n`` parked tasks (all when n is None) without
        removing them; returns the number woken.  Waking a task that is
        already runnable is a no-op (``unblock`` ignores it)."""
        woken = 0
        for task in self._line():
            if n is not None and woken >= n:
                break
            self._rt.unblock(task)
            woken += 1
        return woken


class TaskRuntime:
    """Cooperative scheduler over per-group workers with locality stealing."""

    def __init__(self, *, n_pods: int = 1, groups_per_pod: int = 16,
                 workers_per_group: int = 1, seed: int = 0,
                 counters: Optional[PerfCounters] = None,
                 profile_hook: Optional[Callable[[Task], None]] = None,
                 steal_impl: str = "tiered"):
        self.counters = counters or PerfCounters()
        self.profile_hook = profile_hook
        self.workers: List[Worker] = []
        for pod in range(n_pods):
            for g in range(groups_per_pod):
                for _ in range(workers_per_group):
                    gid = pod * groups_per_pod + g
                    self.workers.append(
                        Worker(len(self.workers), gid, pod, runtime=self))
        # precomputed victim tiers: static membership per group / per pod
        self._group_members: Dict[int, FrozenSet[int]] = {}
        self._pod_members: Dict[int, FrozenSet[int]] = {}
        by_g: Dict[int, set] = collections.defaultdict(set)
        by_p: Dict[int, set] = collections.defaultdict(set)
        for w in self.workers:
            by_g[w.group].add(w.wid)
            by_p[w.pod].add(w.wid)
        self._group_members = {g: frozenset(s) for g, s in by_g.items()}
        self._pod_members = {p: frozenset(s) for p, s in by_p.items()}
        # occupancy indexes: wids that currently have queued work
        self._ne_group: Dict[int, set] = collections.defaultdict(set)
        self._ne_pod: Dict[int, set] = collections.defaultdict(set)
        self._ne_all: set = set()
        self._blocked: Dict[int, Task] = {}
        if steal_impl not in ("tiered", "scan"):
            raise ValueError(f"unknown steal_impl {steal_impl!r}")
        self._steal = (self._steal_tiered if steal_impl == "tiered"
                       else self._steal_scan)
        self._rng = random.Random(seed)
        self._rr = 0
        self.rounds = 0
        self.steal_log: List[Dict] = []

    # -- occupancy bookkeeping (called by Worker on transitions) -----------
    def _mark_nonempty(self, w: Worker):
        self._ne_group[w.group].add(w.wid)
        self._ne_pod[w.pod].add(w.wid)
        self._ne_all.add(w.wid)

    def _mark_empty(self, w: Worker):
        self._ne_group[w.group].discard(w.wid)
        self._ne_pod[w.pod].discard(w.wid)
        self._ne_all.discard(w.wid)

    def pending(self) -> bool:
        """Any runnable (non-blocked) work queued anywhere?"""
        return bool(self._ne_all)

    def blocked(self) -> List[Task]:
        return list(self._blocked.values())

    # ------------------------------------------------------------------
    def spawn(self, gen: Generator, *, group: Optional[int] = None,
              name: str = "", priority: int = 0,
              worker: Optional[int] = None) -> Task:
        task = Task(gen, group=group, name=name, priority=priority)
        w = (self.workers[worker] if worker is not None
             else self._home_worker(task))
        w.push(task)
        self.counters.add("tasks_spawned", 1)
        return task

    def _home_worker(self, task: Task) -> Worker:
        if task.group is not None:
            members = self._group_members.get(task.group)
            if members:
                return min((self.workers[i] for i in members),
                           key=lambda w: (len(w), w.wid))
        self._rr = (self._rr + 1) % len(self.workers)
        return self.workers[self._rr]

    def unblock(self, task: Task):
        """Re-enqueue a task previously parked via ``yield BLOCK``."""
        t = self._blocked.pop(task.id, None)
        if t is None or t.done:
            return
        t.state = "ready"
        self.counters.add("tasks_unblocked", 1)
        self._home_worker(t).push(t)

    # -- §4.4 steal order: same group, then same pod, then anywhere --------
    def _steal_tiered(self, thief: Worker) -> Optional[Task]:
        """Occupancy-indexed steal: cost scales with the number of workers
        that *have* work, not the fleet size."""
        g_ne = self._ne_group.get(thief.group, _EMPTY)
        cands: Any = g_ne - {thief.wid} if g_ne else _EMPTY
        tier = "group"
        if not cands:
            p_ne = self._ne_pod.get(thief.pod, _EMPTY)
            cands, tier = p_ne - g_ne if p_ne else _EMPTY, "pod"
        if not cands:
            p_ne = self._ne_pod.get(thief.pod, _EMPTY)
            cands, tier = self._ne_all - p_ne, "fleet"
        if not cands:
            return None
        victim = self.workers[self._rng.choice(sorted(cands))]
        return self._finish_steal(thief, victim, tier)

    def _steal_scan(self, thief: Worker) -> Optional[Task]:
        """The seed's scan-based steal (O(W) per idle call) — kept as the
        baseline for benchmarks/sched_micro.py."""
        tiers = (
            [w for w in self.workers
             if w is not thief and w.group == thief.group],
            [w for w in self.workers
             if w.group != thief.group and w.pod == thief.pod],
            [w for w in self.workers if w.pod != thief.pod],
        )
        for tier_name, tier in zip(("group", "pod", "fleet"), tiers):
            victims = [w for w in tier if len(w)]
            if victims:
                victim = self._rng.choice(victims)
                return self._finish_steal(thief, victim, tier_name)
        return None

    def _finish_steal(self, thief: Worker, victim: Worker,
                      tier: str) -> Optional[Task]:
        task = victim.steal_from()
        if task is None:
            return None
        thief.stolen += 1
        task.stats.steals += 1
        self.counters.add(f"steals_{tier}", 1)
        # cross-group steal = remote traffic (counter feed for Algorithm 1)
        if tier != "group":
            self.counters.add("remote_bytes", 1.0)
        self.steal_log.append(
            {"thief": thief.wid, "victim": victim.wid,
             "tier": tier, "task": task.id})
        return task

    # ------------------------------------------------------------------
    def tick(self) -> int:
        """One cooperative round over all workers (a yield-point boundary
        for every running task).  Returns the number of tasks advanced."""
        active = 0
        for w in self.workers:
            task = w.pop_local() or self._steal(w)
            if task is None:
                continue
            active += 1
            finished = task.step()
            w.executed_steps += 1
            if self.profile_hook is not None:
                self.profile_hook(task)           # yield-point profiling
            if finished:
                continue
            if task.last_yield is BLOCK:
                task.state = "blocked"
                self._blocked[task.id] = task
                self.counters.add("tasks_blocked", 1)
            else:
                w.push(task)
        self.rounds += 1
        return active

    def run(self, *, max_rounds: int = 10_000_000,
            concurrency_trace: Optional[List[int]] = None) -> int:
        """Drive all runnable tasks to completion; returns rounds used.
        Tasks parked via BLOCK stay parked (see ``unblock``)."""
        rounds = 0
        while self.pending() and rounds < max_rounds:
            active = self.tick()
            rounds += 1
            if concurrency_trace is not None:
                concurrency_trace.append(active)
        if self.pending():
            raise RuntimeError("TaskRuntime.run exceeded max_rounds")
        return rounds

    def barrier(self):
        """Paper API: run everything currently queued to completion."""
        self.run()
