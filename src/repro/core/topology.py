"""Chiplet-group model of a TPU fleet (the paper's §2 adapted to pods).

The CPU hierarchy  core < chiplet (shared 32 MB L3) < NUMA socket
maps to the TPU hierarchy  chip < ICI neighborhood ("chiplet group",
one 16-chip row of a pod, 1-hop ICI links) < pod (full ICI domain),
with DCN playing the cross-NUMA interconnect.

The shared-per-group resource that creates the paper's locality/capacity
trade-off is the group's aggregate HBM (the "L3 capacity" analogue) and its
intra-row ICI bandwidth (the "L3 bandwidth" analogue).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """TPU v5e-class constants (per chip)."""
    peak_flops_bf16: float = 197e12      # FLOP/s
    hbm_bw: float = 819e9                # B/s
    hbm_bytes: float = 16e9              # capacity
    vmem_bytes: float = 128 * 2**20
    ici_bw: float = 50e9                 # B/s per link
    ici_links: int = 4                   # 2D torus
    dcn_bw: float = 6.25e9               # B/s per chip, cross-pod
    # host link (PCIe gen4 x16-class): the swap tier's D2H/H2D path
    d2h_bw: float = 20e9                 # B/s device -> pinned host
    h2d_bw: float = 20e9                 # B/s pinned host -> device
    # latency model for the Fig.3 analogue (seconds, one 512B message)
    lat_intra_group: float = 1e-6
    lat_intra_pod: float = 3e-6
    lat_cross_pod: float = 25e-6


@dataclasses.dataclass(frozen=True)
class ChipletTopology:
    """n_pods x groups_per_pod x chips_per_group fleet."""
    n_pods: int = 1
    groups_per_pod: int = 16             # CHIPLETS (per NUMA domain)
    chips_per_group: int = 16            # CORES_PER_CHIPLET
    hw: HardwareSpec = HardwareSpec()

    @property
    def chips_per_pod(self) -> int:
        return self.groups_per_pod * self.chips_per_group

    @property
    def total_chips(self) -> int:
        return self.n_pods * self.chips_per_pod

    @property
    def total_groups(self) -> int:
        return self.n_pods * self.groups_per_pod

    # -- coordinates ------------------------------------------------------
    def coords(self, chip: int) -> Tuple[int, int, int]:
        """chip id -> (pod, group, slot)."""
        pod, rem = divmod(chip, self.chips_per_pod)
        group, slot = divmod(rem, self.chips_per_group)
        return pod, group, slot

    def chip_id(self, pod: int, group: int, slot: int) -> int:
        return (pod * self.chips_per_pod + group * self.chips_per_group
                + slot)

    def group_of(self, chip: int) -> int:
        """Global group index."""
        pod, group, _ = self.coords(chip)
        return pod * self.groups_per_pod + group

    # -- link classes (Fig. 3 analogue) ------------------------------------
    def link_class(self, a: int, b: int) -> str:
        pa, ga, _ = self.coords(a)
        pb, gb, _ = self.coords(b)
        if pa != pb:
            return "cross_pod"
        if ga != gb:
            return "intra_pod"
        return "intra_group"

    def latency(self, a: int, b: int) -> float:
        return {"intra_group": self.hw.lat_intra_group,
                "intra_pod": self.hw.lat_intra_pod,
                "cross_pod": self.hw.lat_cross_pod}[self.link_class(a, b)]

    def bandwidth(self, cls: str) -> float:
        """Effective per-chip bandwidth for a collective on links of ``cls``."""
        if cls == "intra_group":
            return self.hw.ici_bw * 2          # bidirectional ring in-row
        if cls == "intra_pod":
            return self.hw.ici_bw              # row-crossing: single column link
        return self.hw.dcn_bw

    def latency_cdf(self, sample_pairs: int = 4096, seed: int = 0):
        """(latencies, labels) over random chip pairs — the Fig. 3 CDF."""
        rng = np.random.default_rng(seed)
        n = self.total_chips
        a = rng.integers(0, n, sample_pairs)
        b = rng.integers(0, n, sample_pairs)
        lats = np.array([self.latency(x, y) for x, y in zip(a, b)])
        cls = [self.link_class(x, y) for x, y in zip(a, b)]
        return lats, cls

    # -- capacity (the "L3 size" analogue) ----------------------------------
    def group_hbm(self) -> float:
        return self.chips_per_group * self.hw.hbm_bytes


def production_topology(multi_pod: bool = False) -> ChipletTopology:
    """The assigned production mesh: 16x16 per pod, optionally 2 pods."""
    return ChipletTopology(n_pods=2 if multi_pod else 1)
