"""Algorithm 2 (Update Location) — faithful port + TPU mesh synthesis.

The paper's placement function maps a task ``rank`` to a (chiplet, slot,
core) under the current ``spread_rate`` and binds memory to the matching
NUMA node.  Here the same arithmetic produces the device permutation from
which the ``jax.sharding.Mesh`` for the chosen layout is built:

  spread_rate s = chiplet groups per model replica
    -> model-parallel degree  m = s * chips_per_group
    -> replica count          R = total_groups / s
  mesh = (data=R, model=m), with each replica's model axis laid over s
  *contiguous* groups (the paper's affinity step), and the NUMA bind step
  becoming the NamedSharding placement of params/optimizer state.

The "LocalCache" policy of the paper is s=1 (TP confined to one ICI
neighborhood); "DistributedCache" is s=groups_per_pod.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import numpy as np

from repro.core.topology import ChipletTopology


# ---------------------------------------------------------------------------
# Algorithm 2, faithful (rank -> core), as in the paper
# ---------------------------------------------------------------------------

def update_location(rank: int, spread_rate: int, *, chiplets: int,
                    cores_per_chiplet: int, thread_size: int
                    ) -> Optional[Tuple[int, int, int]]:
    """Returns (chiplet, slot, core) or None if the bounds check fails.

    Mirrors Algorithm 2: threads fill ``spread_rate`` chiplets using
    ``cores_per_chiplet / spread_rate`` slots on each, wrapping around when
    the computed chiplet exceeds the available count.
    """
    if not (0 < spread_rate <= chiplets):
        return None                                    # bounds check
    if thread_size > spread_rate * cores_per_chiplet * (chiplets // spread_rate):
        return None                                    # not enough cores
    slots_per_chiplet = max(1, cores_per_chiplet // spread_rate)
    chiplet = rank // slots_per_chiplet
    slot = rank % slots_per_chiplet
    if chiplet >= chiplets:                            # wrap-around
        slot = slot + (chiplet // chiplets) * slots_per_chiplet
        chiplet = chiplet % chiplets
    core = chiplet * cores_per_chiplet + slot
    return chiplet, slot, core


def numa_node_of(core: int, cores_per_numa: int) -> int:
    """Algorithm 2's set_mempolicy(MPOL_BIND, 1 << numa_node) analogue."""
    return core // cores_per_numa


# ---------------------------------------------------------------------------
# Mesh-level layout
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Layout:
    """A concrete placement: how the fleet factors into replicas x shards."""
    topology: ChipletTopology
    spread_rate: int                    # groups per replica (1..groups_per_pod)
    pod_axis: bool = False              # keep an explicit leading "pod" axis

    def __post_init__(self):
        s = self.spread_rate
        t = self.topology
        assert 1 <= s <= t.groups_per_pod, s
        assert t.groups_per_pod % s == 0, (t.groups_per_pod, s)

    @property
    def model_degree(self) -> int:
        return self.spread_rate * self.topology.chips_per_group

    @property
    def replicas_per_pod(self) -> int:
        return self.topology.groups_per_pod // self.spread_rate

    @property
    def replicas(self) -> int:
        return self.replicas_per_pod * self.topology.n_pods

    # -- device permutation (Algorithm 2 applied to shards) ------------------
    def device_order(self) -> np.ndarray:
        """(replicas, model_degree) array of chip ids, replicas pod-major.

        Shard j of replica r sits in group  r*s + j // chips_per_group  at
        slot  j % chips_per_group  — contiguous groups per replica, the
        affinity discipline of Algorithm 2.
        """
        t = self.topology
        s = self.spread_rate
        out = np.empty((self.replicas, self.model_degree), dtype=np.int64)
        for pod in range(t.n_pods):
            for r in range(self.replicas_per_pod):
                base_group = r * s
                for j in range(self.model_degree):
                    g = base_group + j // t.chips_per_group
                    slot = j % t.chips_per_group
                    out[pod * self.replicas_per_pod + r, j] = t.chip_id(
                        pod, g, slot)
        return out

    def make_mesh(self, devices=None):
        """Build the jax Mesh for this layout (optionally with a pod axis)."""
        import jax
        from jax.sharding import Mesh

        devices = list(jax.devices()) if devices is None else list(devices)
        order = self.device_order()
        dev_arr = np.asarray(devices, dtype=object)[order]
        if self.pod_axis:
            t = self.topology
            dev_arr = dev_arr.reshape(t.n_pods, self.replicas_per_pod,
                                      self.model_degree)
            return Mesh(dev_arr, ("pod", "data", "model"))
        return Mesh(dev_arr, ("data", "model"))

    # -- capacity (Fig. 5 working-set test) -----------------------------------
    def replica_hbm(self) -> float:
        return self.model_degree * self.topology.hw.hbm_bytes

    def fits(self, replica_working_set_bytes: float,
             headroom: float = 0.9) -> bool:
        return replica_working_set_bytes <= self.replica_hbm() * headroom

    def describe(self) -> str:
        return (f"Layout(s={self.spread_rate}: {self.replicas}r x "
                f"{self.model_degree}m, replica HBM "
                f"{self.replica_hbm() / 1e9:.0f}GB)")


def layout_family(topology: ChipletTopology, pod_axis: bool = False
                  ) -> List[Layout]:
    """All legal spread rates (divisors of groups_per_pod)."""
    g = topology.groups_per_pod
    return [Layout(topology, s, pod_axis)
            for s in range(1, g + 1) if g % s == 0]
