"""Algorithm 1 (Chiplet Scheduling Policy) — faithful port — plus the
approach->policy machinery of §4.1 and a beyond-paper cost-model-guided
variant.

Faithful control law (per SCHEDULER_TIMER interval):
    rate = event_counter * SCHEDULER_TIMER / elapsed
    rate >= RMT_CHIP_ACCESS_RATE  ->  spread_rate += 1   (spread)
    else                          ->  spread_rate -= 1   (compact)
bounded to [1, CHIPLETS], followed by updateLocation().

Approaches (paper §4.1): an *approach* is the guiding principle, a *policy*
the concrete action rule the scheduler executes.
  location_centric — minimize cross-group traffic: always compact (s -> 1)
  cache_centric    — maximize aggregate capacity: always spread (s -> max)
  adaptive         — the Algorithm-1 feedback loop between the two
  model_guided     — (beyond paper) jump straight to argmin of the roofline
                     cost model instead of +-1 steps
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional

from repro.core.counters import PerfCounters
from repro.core.layout import Layout, layout_family
from repro.core.topology import ChipletTopology

# Paper §4.6: sensitivity analysis picked 300 events / interval; our events
# are bytes, so the threshold is expressed in bytes per interval and set per
# workload by the same kind of calibration (see benchmarks/fig5).
RMT_CHIP_ACCESS_RATE = 300.0
SCHEDULER_TIMER = 1.0


@dataclasses.dataclass
class ControllerConfig:
    approach: str = "adaptive"       # location_centric|cache_centric|adaptive|model_guided
    scheduler_timer: float = SCHEDULER_TIMER      # seconds (or steps if step_mode)
    threshold: float = RMT_CHIP_ACCESS_RATE       # events per interval
    step_mode: bool = True           # interval measured in steps, not wall time
    min_dwell: int = 1               # intervals to wait between moves


@dataclasses.dataclass
class Decision:
    step: int
    old_spread: int
    new_spread: int
    rate: float
    reason: str


class AdaptiveController:
    """The paper's adaptive controller (2) driving spread/compact moves."""

    def __init__(self, topology: ChipletTopology, cfg: ControllerConfig,
                 *, spread_rate: int = 1, pod_axis: bool = False,
                 cost_fn: Optional[Callable[[Layout], float]] = None,
                 working_set_fn: Optional[Callable[[], float]] = None):
        self.topology = topology
        self.cfg = cfg
        self.pod_axis = pod_axis
        self.cost_fn = cost_fn
        self.working_set_fn = working_set_fn
        self._legal = sorted(s.spread_rate for s in layout_family(topology))
        if spread_rate not in self._legal:
            spread_rate = self._legal[0]
        self.spread_rate = spread_rate
        self._last_check = 0.0
        self._steps = 0
        self._dwell = 0
        self.decisions: List[Decision] = []

    # ------------------------------------------------------------------
    def layout(self) -> Layout:
        return Layout(self.topology, self.spread_rate, self.pod_axis)

    def _bump(self, direction: int) -> int:
        """Next legal spread rate in +-1 'steps' over the divisor ladder."""
        i = self._legal.index(self.spread_rate)
        j = min(max(i + direction, 0), len(self._legal) - 1)
        return self._legal[j]

    # -- Algorithm 1 ------------------------------------------------------
    def maybe_reschedule(self, counters: PerfCounters,
                         now: Optional[float] = None) -> Optional[Decision]:
        """Run one Algorithm-1 evaluation; returns a Decision on change."""
        self._steps += 1
        elapsed = (self._steps - self._last_check if self.cfg.step_mode
                   else (now or counters.elapsed()) - self._last_check)
        if elapsed < self.cfg.scheduler_timer:
            return None

        counter = counters.event_counter("remote_bytes")      # cache-fill events
        rate = counter * self.cfg.scheduler_timer / max(elapsed, 1e-9)
        old = self.spread_rate

        if self.cfg.approach == "location_centric":
            new, reason = self._legal[0], "location_centric: compact"
        elif self.cfg.approach == "cache_centric":
            new, reason = self._legal[-1], "cache_centric: spread"
        elif self.cfg.approach == "model_guided" and self.cost_fn is not None:
            cand = min((Layout(self.topology, s, self.pod_axis)
                        for s in self._legal), key=self.cost_fn)
            new, reason = cand.spread_rate, "model_guided: argmin cost"
        else:  # adaptive — the faithful Algorithm 1 body
            if rate >= self.cfg.threshold:
                new = self._bump(+1)
                reason = f"rate {rate:.3g} >= {self.cfg.threshold:.3g}: spread"
            else:
                new = self._bump(-1)
                reason = f"rate {rate:.3g} < {self.cfg.threshold:.3g}: compact"

        # capacity guard (the hard HBM-fit constraint of the TPU adaptation)
        if self.working_set_fn is not None:
            ws = self.working_set_fn()
            while not Layout(self.topology, new, self.pod_axis).fits(ws):
                i = self._legal.index(new)
                if i == len(self._legal) - 1:
                    break
                new = self._legal[i + 1]
                reason += " +capacity_guard"

        self._last_check = self._steps if self.cfg.step_mode else (
            now or counters.elapsed())
        counters.reset_events("remote_bytes")

        if new == old or self._dwell > 0:
            self._dwell = max(0, self._dwell - 1)
            return None
        self.spread_rate = new
        self._dwell = self.cfg.min_dwell
        d = Decision(self._steps, old, new, rate, reason)
        self.decisions.append(d)
        return d
