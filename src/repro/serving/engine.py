"""Serving engine: a continuous-batching TOKEN loop over chiplet-group
replicas, running on the unified GlobalScheduler substrate with an elastic,
paged, chiplet-aware KV allocator.

ARCAS mapping (the paper's runtime, applied to inference):
  * every request is a COROUTINE: an admission task that reserves KV pages
    from its replica's chiplet-group memory domain — parking via ``yield
    BLOCK`` when the pool is exhausted and woken by the pool's free
    callback (allocation failure IS the back-pressure mechanism);
  * every engine tick builds ONE batched model step whose streams are a mix
    of prefill CHUNKS (page-sized slices of prompts scattered into the pool
    page-by-page, so prefill memory is bounded by one chunk regardless of
    prompt length) and single-token decode streams — there is no separate
    prefill phase, just streams at different positions in one loop.  Chunk
    ticks run on one of TWO COMPILED PATHS
    (``EngineConfig(prefill_mode=)``): "parallel" (default) fuses the
    whole chunk into one model forward — intra-chunk causal attention
    against the gathered ring prefix, chunk scans for rgLRU/SSD state —
    so a C-token chunk costs ONE model step; "scan" keeps the per-token
    reference (C sequential steps, bit-identical to single-token
    stepping).  Pure-decode ticks use the single-token step either way;
  * KV reservations are ELASTIC: admission takes only the pages of the
    first chunk plus the state slot, and the table GROWS lazily as ``pos``
    crosses page boundaries.  When a stream's domain is exhausted MID-
    DECODE it parks — suspend at a defined point, resume wherever capacity
    appears — via the same ``yield BLOCK`` / free-callback path admission
    uses, releasing its decode slot to other streams while it waits;
  * KV cache is PAGED (``serving/kvpool.py``): a block pool partitioned per
    chiplet-group domain; a request holds a block table, not a slot in a
    monolithic per-replica array, so short requests reserve only the pages
    they need and ``max_batch`` becomes a scheduling knob instead of a
    memory allocation;
  * the fleet is partitioned into replica groups by the current Layout
    (spread_rate): compact = many small replicas, spread = few big ones;
    each replica group owns ``spread_rate`` pool domains;
  * waiting requests are WORK-STOLEN between replica queues in §4.4 tier
    order (own queue -> neighborhood -> pod -> fleet) via TieredQueues; a
    steal migrates the request's KV reservation into the thief's domain
    (memory follows work — the NUMA-bind discipline), partially-grown
    tables included;
  * the adaptive controller runs LIVE: on a spread-rate change the engine's
    RelayoutHandler rebuilds replica groups MID-RUN — in-flight streams
    (mid-prefill or mid-decode) keep their pool pages and only re-point
    their block tables at the new owner replica of their domain; streams
    rebalanced onto a non-owner replica copy just their *used* pages
    between domains (never whole cache slices), so adaptive and
    non-adaptive runs generate identical tokens;
  * incremental allocation can deadlock (every stream in a domain holding
    pages and needing one more); a ``round_hook`` on the scheduler watches
    for allocation stalls and resolves them up a memory-pressure LADDER:
    admission headroom (keep ``k`` blocks free past the first chunk) makes
    deadlocks rarer, parking absorbs transient pressure, and when the
    watchdog fires the victim's used pages are SPILLED to a host swap tier
    (``evict_mode="swap"``, the default): its device pages go to the
    longest-parked waiter, the table turns host-resident (migrating by
    re-point, zero device copies), and on re-grant the stream restores its
    pages and resumes mid-decode at its saved cursor — zero recomputed
    tokens.  ``evict_mode="restart"`` keeps the PR-3 last resort (also the
    swap mode's fallback when every parked stream is already spilled):
    free the victim and re-run it from scratch, which under greedy
    decoding regenerates the identical tokens at ``recompute_tokens``
    cost;
  * prompt PREFIX SHARING (``EngineConfig(prefix_share=)``, default on for
    lazy ring models): admission hashes the prompt page-by-page and asks
    each candidate domain for the longest chain of already-resident pages;
    a match attaches those pages REFCOUNTED (copy-on-write at ring-wrap)
    and starts prefill at the first unmatched chunk boundary — skipped
    chunks cost zero model steps AND zero fresh pages, so shared-preamble
    tenants admit more concurrent streams from the same byte budget.  The
    skip is computationally identical to resuming a parked stream at a
    chunk boundary, so tokens are bit-identical to the unshared run;
  * an open-loop client coroutine (``open_loop_client``) shares the same
    TaskRuntime and submits requests over time from a seeded schedule, so
    steady-state adaptation and TTFT/TPOT tails are actually exercised.

``EngineConfig(lazy=False)`` keeps the PR-2 eager allocator (full capped
reservation at admission + whole-prompt prefill); ``paged=False`` keeps the
PR-1 slot monolith.  Both ride the same token loop — their streams simply
never have more than one token per tick — and stay token-identical to the
lazy path.

On this CPU container the model compute is real (tiny configs) while the
replica groups are logical queues over the same device — the scheduling,
batching, stealing, paging, growth, controller and migration behavior is
exactly the code a TPU deployment would run host-side.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.controller import ControllerConfig, Decision
from repro.core.layout import Layout
from repro.core.scheduler import GlobalScheduler, TieredQueues
from repro.core.tasks import BLOCK, WaitQueue
from repro.core.topology import ChipletTopology
from repro.models import decode as dec
from repro.models.params import init_params
from repro.core.costmodel import kv_bypass_floor_bytes, \
    kv_transfer_seconds, prefill_chunk_bytes, prefill_chunk_score_bytes, \
    spec_rejected_bytes, spec_rollback_bytes
from repro.launch.steps import make_prefill, make_serve_chunk_step, \
    make_serve_step, make_spec_verify_step
from repro.serving.kvpool import KVBlockPool, KVTable, kv_bytes_exact
from repro.serving.spec import make_drafter


@dataclasses.dataclass(frozen=True)
class ClassSLO:
    """Per-request-class service targets + scheduling privileges.

    ``ttft_target``/``tpot_target`` are reporting targets (seconds to
    first token / seconds per output token after the first) the per-class
    latency stats are judged against; ``bypass`` marks the class eligible
    for the size-aware admission bypass — a grant past a blocked line
    head, allowed only under the provable no-delay bound."""
    ttft_target: float = math.inf
    tpot_target: float = math.inf
    bypass: bool = False


#: The default two-tier mix: latency-sensitive ``interactive`` requests
#: may bypass (their small footprints are exactly what fits the safety
#: bound); throughput ``batch`` requests — the submit() default — never
#: do, so single-class workloads keep the strict-FIFO grant order and
#: every pre-existing counter baseline.
DEFAULT_SLO_CLASSES: Dict[str, ClassSLO] = {
    "interactive": ClassSLO(ttft_target=0.5, tpot_target=0.05, bypass=True),
    "batch": ClassSLO(),
}


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new: int
    arrived: float = 0.0
    group: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    migrations: int = 0                 # relayouts survived while in flight
    table: Optional[KVTable] = None     # paged mode: KV pages + state slot
    prefix_tokens: int = 0              # prompt tokens served from shared
                                        # prefix pages (prefill starts here)
    cls: str = "batch"                  # SLO class (EngineConfig.slo_classes)
    bypassed: bool = False              # granted past a blocked line head
    wq_seq: Optional[int] = None        # wait-line seq drawn at submit; a
                                        # BYPASSED stream that parks later
                                        # re-enters at this arrival position
    grant_rounds: List[int] = dataclasses.field(default_factory=list)
                                        # engine round of every page grant
                                        # (admission, regrow, restore) — the
                                        # no-starvation gates compare these
    arrive_round: int = 0               # engine round at submit: with
                                        # grant_rounds this gives a
                                        # deterministic (round-based)
                                        # admission-delay metric
    page_keys: Optional[List[bytes]] = dataclasses.field(
        default=None, repr=False, compare=False)  # prompt hash chain
    _kv_fn: Optional[Callable[[int], float]] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def done(self) -> bool:
        return self.t_done is not None

    def kv_bytes(self) -> float:
        """KV footprint moved when this request changes groups.  Exact
        (costmodel-derived per-token bytes) when the engine installed its
        calculator; the seed's rough 2-bytes/token estimate otherwise."""
        tokens = len(self.prompt) + len(self.generated)
        if self._kv_fn is not None:
            return self._kv_fn(tokens)
        return float(tokens * 2)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8                 # decode slots per replica group
    max_len: int = 256
    adaptive: bool = True
    paged: bool = True                 # paged KV block pool (default) vs
                                       # the legacy slot-monolith cache
    lazy: bool = True                  # elastic reservations + chunked
                                       # prefill (False = PR-2 eager mode)
    block_tokens: int = 16             # ring tokens per KV page
    prefill_chunk: Optional[int] = None  # prompt tokens per prefill chunk;
                                         # default: one KV page
    prefill_mode: str = "parallel"     # chunk-tick compiled path: "parallel"
                                       # fuses the whole chunk into ONE
                                       # model forward (intra-chunk causal
                                       # attention + chunk scans for
                                       # rgLRU/SSD state); "scan" keeps the
                                       # PR-3 per-token reference (C
                                       # sequential model steps per chunk,
                                       # bit-identical to single-token
                                       # stepping)
    chunk_kernel: str = "blocked"      # fused-path attention: "blocked"
                                       # streams KV in (block_q, block_kv)
                                       # tiles through the Pallas online-
                                       # softmax ring kernel; "dense" keeps
                                       # the (C, W+C) einsum reference
    split_ticks: bool = True           # mixed ticks run TWO compiled steps
                                       # (a compacted fused chunk forward
                                       # for prefill streams + the single-
                                       # token step for decode streams) so
                                       # decode streams stop paying C-1
                                       # masked query rows; False keeps the
                                       # PR-5 one-step mixed tick
    pool_streams: Optional[int] = None  # per-DOMAIN budget, expressed as
                                        # full-length streams (monolith
                                        # equivalence); default max_batch
    stall_evict_rounds: int = 6        # allocation-stall rounds before the
                                       # deadlock breaker evicts a stream
    evict_mode: str = "swap"           # stall-watchdog policy: "swap" spills
                                       # the victim's used pages to the host
                                       # tier and resumes it mid-decode on
                                       # re-grant (zero recompute); "restart"
                                       # keeps the PR-3 recompute-from-
                                       # scratch eviction
    headroom: int = 0                  # lazy admission guard: grant only
                                       # when the domain keeps this many
                                       # free blocks AFTER the first chunk
                                       # (k=0 = unguarded PR-3 behavior)
    prefix_share: bool = True          # hash-matched prefix caching: new
                                       # requests attach refcounted shared
                                       # KV pages for prompt pages already
                                       # resident in their domain and skip
                                       # the matched prefill chunks; pages
                                       # copy-on-write at ring-wrap.  Only
                                       # active on the lazy paged path for
                                       # models with ring pages
    spec_decode: str = "off"           # speculative decoding: "ngram"
                                       # drafts up to spec_k tokens per
                                       # decode tick from the stream's own
                                       # committed tokens and verifies them
                                       # in ONE fused chunk forward (greedy
                                       # acceptance -> token-identical to
                                       # "off" by construction).  Lazy
                                       # paged path only; deliberately off
                                       # by default so the non-speculative
                                       # counter gates keep their exact
                                       # baselines — flip per run/workload
    spec_k: int = 4                    # max draft tokens per tick
    spec_ngram: int = 3                # longest n-gram the prompt-lookup
                                       # drafter matches on
    cached_retention: str = "access"   # cached prefix-page reclaim order:
                                       # "access" evicts the coldest page
                                       # by last-hit recency, "blind" the
                                       # PR-7 free-list order
    slo_classes: Dict[str, ClassSLO] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_SLO_CLASSES))
                                       # request classes submit() accepts;
                                       # unknown names fail fast
    slo_bypass: bool = True            # size-aware bypass: a bypass-class
                                       # request may be granted past a
                                       # PARKED line head when its charged
                                       # pages fit under the head's provable
                                       # need (never delays the head); off
                                       # = strict FIFO even for bypass
                                       # classes
    slo_aging_rounds: int = 200        # bypass fairness backstop: bypass is
                                       # suspended while ANY waiter ahead of
                                       # the candidate has been blocked
                                       # longer than this many rounds — the
                                       # line drains strictly FIFO until the
                                       # aged waiter is granted
    spill_watermarks: Optional[Tuple[float, float]] = None
                                       # (high, low) per-domain occupancy
                                       # marks for PROACTIVE spill of the
                                       # coldest parked stream BEFORE the
                                       # stall watchdog fires; hysteresis:
                                       # a domain that spilled at high
                                       # re-arms only under low.  None =
                                       # watchdog-only (the PR-4 ladder)
    async_swap: bool = False           # overlap spills behind the token
                                       # loop: the pressure ladder ISSUES
                                       # the D2H copy and keeps ticking,
                                       # landing it (and re-granting the
                                       # victim's pages) at a later poll;
                                       # fences only on shutdown, relayout
                                       # or a genuinely stalled watchdog.
                                       # False = the PR-4 synchronous
                                       # spill (issue + immediate fence,
                                       # byte-identical payload)
    controller: ControllerConfig = dataclasses.field(
        default_factory=lambda: ControllerConfig(
            scheduler_timer=8, threshold=4.0, min_dwell=2))


@dataclasses.dataclass
class _InFlight:
    """A mid-generation stream harvested from a retired replica group (or
    a mid-decode park).  ``cache`` carries the KV slice only in legacy
    (slot-monolith) mode; in paged mode the KV stays in the pool and only
    the table pointer moves.  ``pos`` < len(prompt) means the stream was
    harvested mid-PREFILL: it resumes at the next chunk boundary."""
    req: Request
    cache: Any
    pos: int
    token: int


@dataclasses.dataclass
class _Parked:
    """A stream suspended MID-DECODE because its domain could not grow its
    table.  It holds its pages (and its place in the engine's FIFO wait
    line) but not a decode slot; ``_regrow_task`` resumes it."""
    req: Request
    pos: int
    token: int
    seq: int                            # park order (eviction prefers max)
    cell: Dict[str, Any] = dataclasses.field(default_factory=dict)
    evicted: bool = False


class _Group:
    """One replica group: decode slots (+ its own cache pool in legacy
    mode; in paged mode KV lives in the engine's KVBlockPool).

    ``queue`` is the group's deque inside the engine's TieredQueues;
    ``resume`` holds migrated in-flight streams awaiting a free slot;
    ``retired`` marks groups dissolved by a relayout (their coroutine exits
    at its next yield point).  ``pos_h``/``tok_h`` are the host-side view
    of every slot's stream cursor: absolute position of the next token to
    process and the last emitted token.
    """

    def __init__(self, gid: int, pod: int, cfg: ModelConfig, params,
                 ecfg: EngineConfig, queue, domains: List[int]):
        self.gid = gid
        self.pod = pod
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.queue = queue
        self.domains = domains          # chiplet-group pool domains owned
        self.resume: List[_InFlight] = []
        self.retired = False
        self.slots: List[Optional[Request]] = [None] * ecfg.max_batch
        self.cache = (None if ecfg.paged
                      else dec.init_cache(cfg, ecfg.max_batch, ecfg.max_len))
        self.pos_h = np.zeros((ecfg.max_batch,), np.int32)
        self.tok_h = np.zeros((ecfg.max_batch,), np.int32)
        self.steps = 0

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def busy(self) -> bool:
        return (bool(self.queue) or bool(self.resume)
                or any(s is not None for s in self.slots))

    def kv_pressure(self) -> float:
        used = sum(1 for s in self.slots if s is not None)
        return used / max(1, len(self.slots))


class ServeEngine:
    def __init__(self, cfg: ModelConfig, topology: ChipletTopology,
                 ecfg: EngineConfig = EngineConfig(), *, seed: int = 0,
                 spread_rate: int = 1):
        self.cfg = cfg
        self.topology = topology
        self.ecfg = ecfg
        self.sched = GlobalScheduler(
            topology, ecfg.controller, spread_rate=spread_rate,
            control_enabled=ecfg.adaptive)
        # compat aliases: the scheduler owns these now
        self.counters = self.sched.counters
        self.controller = self.sched.controller
        self.runtime = self.sched.tasks
        self.params = init_params(cfg, jax.random.PRNGKey(seed))
        self._prefill = jax.jit(make_prefill(cfg, max_len=ecfg.max_len))
        self._decode = jax.jit(make_serve_step(cfg))
        self._rid = itertools.count()
        self._clock = time.monotonic
        self._running = False
        self._inflight = 0              # submitted, not yet done
        self._clients = 0               # active open-loop client coroutines
        self.submitted: List[Request] = []
        self.relayouts: List[Dict] = []
        self.pool: Optional[KVBlockPool] = None
        self._lazy = ecfg.paged and ecfg.lazy
        self._async = bool(ecfg.paged and ecfg.async_swap)
        if ecfg.evict_mode not in ("swap", "restart"):
            raise ValueError(f"unknown evict_mode {ecfg.evict_mode!r}")
        if ecfg.prefill_mode not in ("parallel", "scan"):
            raise ValueError(f"unknown prefill_mode {ecfg.prefill_mode!r}")
        if ecfg.chunk_kernel not in ("blocked", "dense"):
            raise ValueError(f"unknown chunk_kernel {ecfg.chunk_kernel!r}")
        if ecfg.spec_decode not in ("off", "ngram"):
            raise ValueError(f"unknown spec_decode {ecfg.spec_decode!r}")
        self._prefill_mode = ecfg.prefill_mode if self._lazy else "scan"
        self._chunk_kernel = (ecfg.chunk_kernel
                              if self._prefill_mode == "parallel" else "dense")
        self._parked: Dict[int, _Parked] = {}
        self._park_seq = itertools.count()
        self._progress_mark = -1.0
        self._stall_rounds = 0
        self._round = 0                 # scheduler rounds seen (_stall_hook)
        self._head_id: Optional[int] = None   # current line-head task id and
        self._head_wait = 0                   # rounds it has sat blocked there
        if not ecfg.slo_classes:
            raise ValueError("slo_classes must name at least one class")
        # size-aware bypass bookkeeping: round each waiter joined the line
        # (the aging backstop's clock) and the waiting admission cells of
        # bypass-eligible classes (targeted wakes — non-head waiters only
        # retry when a bypass could actually have opened)
        # _bypass_wake: bypass-class waiters are WOKEN on frees/grants (so a
        # ``slo_bypass=False`` twin steps task-for-task with the bypass
        # engine until the first actual bypass grant — the no-starvation
        # comparison is exact, not cadence-polluted); _bypass_on gates the
        # GRANTS themselves
        self._bypass_wake = bool(ecfg.paged
                                 and any(c.bypass
                                         for c in ecfg.slo_classes.values()))
        self._bypass_on = bool(self._bypass_wake and ecfg.slo_bypass)
        self._wait_round: Dict[int, int] = {}
        self._bypass_cells: Dict[int, Dict[str, Any]] = {}
        # every bypass grant as (round, granted rid, jumped head rid)
        self.bypass_log: List[Tuple[int, int, int]] = []
        if ecfg.paged:
            streams = ecfg.pool_streams or ecfg.max_batch
            budget = KVBlockPool.blocks_for_streams(
                cfg, ecfg.max_len, streams, ecfg.block_tokens)
            self.pool = KVBlockPool(
                cfg, n_domains=topology.total_groups, max_len=ecfg.max_len,
                block_tokens=ecfg.block_tokens, counters=self.counters,
                retention=ecfg.cached_retention, topology=topology,
                **budget)
            self.waiters = WaitQueue(self.runtime)
            # wake ONE waiter per free: grants stay FIFO (a successful
            # admission cascades the wake to the next waiter itself).
            # Bypass-eligible waiters are additionally woken — they are
            # allowed to attempt a grant without being the head
            self.pool.on_free(self._on_pool_free)
            if ecfg.spill_watermarks is not None:
                self.pool.set_watermarks(*ecfg.spill_watermarks)
            # donate the pool storage: the scatter-back updates in place
            # instead of copying the whole fleet's blocks every tick
            self._paged_decode = jax.jit(self._make_paged_decode(),
                                         donate_argnums=(1,))
            self._commit_prefill = jax.jit(self._make_commit_prefill(),
                                           donate_argnums=(0,))
            ml = ecfg.max_len
            self._kv_fn = lambda n: kv_bytes_exact(cfg, n, ml)
            # prefix sharing needs elastic tables (the skip resumes at a
            # chunk boundary exactly like a restored park) and ring pages
            # to share; eager and pure-state models run unshared
            self._share = (self._lazy and ecfg.prefix_share
                           and self.pool.pages_per_stream > 0)
            # prefill chunk: one KV page by default (ring models), the
            # configured page size for pure-state models (no ring pages)
            self._chunk = ecfg.prefill_chunk or (
                self.pool.block_tokens if self.pool.pages_per_stream
                else ecfg.block_tokens)
            # no C <= W clamp: the fused forward handles chunks wider than
            # the ring (attention masks each query to its surviving span,
            # the cache write keeps the last W active tokens)
            if self._lazy:
                self._paged_chunk = jax.jit(
                    self._make_paged_chunk(self._prefill_mode),
                    donate_argnums=(1,))
            # speculative decoding rides the lazy chunk path: drafted
            # decode streams become small-chunk rows verified through an
            # all-position-logits variant of the same fused forward
            self._spec = self._lazy and ecfg.spec_decode != "off" \
                and ecfg.spec_k > 0 and self._chunk > 1
            if self._spec:
                self.drafter = make_drafter(ecfg.spec_decode,
                                            ngram=ecfg.spec_ngram)
                # pure-spec ticks run at this narrow width; ticks that
                # also carry a prefill chunk reuse the full chunk width
                self._spec_w = min(ecfg.spec_k + 1, self._chunk)
                self._paged_spec = jax.jit(
                    self._make_paged_spec(self._prefill_mode),
                    donate_argnums=(1,))
            else:
                self.drafter = None
        else:
            self._kv_fn = None
            self._chunk = 1
            self._share = False
            self._spec = False
            self.drafter = None
        self._build_groups()
        self.sched.register_relayout(self._relayout)

    # ------------------------------------------------------------------
    def _domains_of(self, gid: int, lay: Layout) -> List[int]:
        """Chiplet-group pool domains a replica group spans (Algorithm 2's
        contiguous-group affinity)."""
        rpp = lay.replicas_per_pod
        pod, local = divmod(gid, rpp)
        s = lay.spread_rate
        base = pod * self.topology.groups_per_pod + local * s
        return list(range(base, base + s))

    def _build_groups(self):
        lay = self.sched.layout()
        rpp = lay.replicas_per_pod
        pods = [g // rpp for g in range(lay.replicas)]
        # neighborhood tier: adjacent replica pairs inside a pod share
        # 1-hop ICI spans; only meaningful when a pod holds >1 replica
        hoods = ([(p, (g % rpp) // 2) for g, p in enumerate(pods)]
                 if rpp > 1 else None)
        self.queues = TieredQueues(pods, neighborhoods=hoods,
                                   counters=self.counters,
                                   bytes_fn=Request.kv_bytes)
        self.groups = [_Group(g, pods[g], self.cfg, self.params, self.ecfg,
                              self.queues.queue(g), self._domains_of(g, lay))
                       for g in range(lay.replicas)]

    def _owner_group(self, domain: int) -> "_Group":
        for g in self.groups:
            if domain in g.domains:
                return g
        raise KeyError(domain)

    def _domain_order(self, g: _Group) -> List[int]:
        """A group's domains, most-capacity first (blocks are the scarce
        resource when the model has ring pages; state slots otherwise)."""
        assert self.pool is not None
        return sorted(g.domains,
                      key=lambda d: (-self.pool.free_blocks(d),
                                     -self.pool.free_states(d), d))

    def _try_admit(self, total_tokens: int, first_tokens: Optional[int],
                   keys: Optional[List[bytes]] = None, prompt_len: int = 0
                   ) -> Tuple[Optional["_Group"], Optional[KVTable]]:
        """Sweep every group (least-pressured first) and every domain it
        owns; one logical alloc failure only when the whole pool is dry.
        Lazy admissions keep ``headroom`` blocks free in the granting
        domain so growth of in-flight streams is less likely to close the
        incremental-allocation deadlock.

        With ``keys`` (the prompt's page hash chain), candidate domains are
        re-ranked by matched prefix length FIRST: a domain already holding
        the prompt's pages admits the request onto shared refcounted pages
        and charges only the unshared tail — both fewer pages AND fewer
        prefill chunks.  Ties fall back to the pressure order."""
        headroom = self.ecfg.headroom if self._lazy else 0
        cands = [(g, d)
                 for g in sorted(self.groups,
                                 key=lambda gr: (gr.kv_pressure(),
                                                 len(gr.queue), gr.gid))
                 for d in self._domain_order(g)]
        matches: Dict[int, Tuple[List[int], int]] = {}
        if keys:
            matches = {d: self.pool.match_prefix(d, keys,
                                                 prompt_len=prompt_len)
                       for _, d in cands}
            # stable sort: longest match first, pressure order inside ties
            cands.sort(key=lambda gd: -len(matches[gd[1]][0]))
        for g, d in cands:
            shared, ckpt = matches.get(d, ((), 0))
            first = first_tokens
            if shared:
                # the skip moves the first chunk past the shared pages
                skip = len(shared) * self.pool.block_tokens
                first = skip + min(self._chunk, max(1, prompt_len - skip))
            table = self.pool.reserve(d, total_tokens,
                                      first_tokens=first,
                                      headroom=headroom,
                                      count_failure=False,
                                      prefix_blocks=shared,
                                      prefix_state=ckpt)
            if table is not None:
                return g, table
        self.counters.add("kv_alloc_failures", 1)
        return None, None

    def _migrate_into(self, table: KVTable, g: _Group) -> bool:
        """Move a reservation into any of the group's domains."""
        if table.domain in g.domains:
            return True
        return any(self.pool.migrate(table, d) for d in self._domain_order(g))

    # -- size-aware bypass (PR 9): grant past a blocked head, provably free --
    def _head_rec(self) -> Optional[_Parked]:
        """The line head's park record — None when the head is an
        ADMISSION task.  Bypass only ever jumps a PARKED head: a blocked
        admission can be served from any domain, so every page in the
        pool is a page it might need and no provable slack exists; a
        parked stream's need is pinned to specific domains, leaving the
        rest of the pool provably useless to it."""
        head = self.waiters.oldest()
        if head is None:
            return None
        for rec in self._parked.values():
            if rec.cell.get("task") is head:
                return rec
        return None

    def _head_need_in(self, rec: _Parked, d: int
                      ) -> Optional[Tuple[int, bool]]:
        """``(pages, needs_state)``: the blocked head's PROVABLE need from
        domain ``d`` — the free-block floor a bypass grant in ``d`` must
        leave behind so the head's time-to-grant cannot be delayed.

        A spilled head restores anywhere: its floor is its host pages
        plus next-chunk growth (and a state slot) in EVERY domain.  A
        parked grower is pinned: its own domain owes the next-chunk
        pages, its replica group's other domains owe a whole-table
        migrate, and domains OUTSIDE its group owe NOTHING — growth and
        migration never leave the group, so those domains' pages are
        provably useless to the head.  That last case is the bypass
        window this whole mechanism exists for."""
        t = rec.req.table
        if t.spill is not None:
            n, _ = self._next_chunk_need(rec.req, rec.pos)
            grow = max(0, self.pool.pages_needed(rec.pos + n)
                       - t.spill.pages)
            return t.spill.pages + grow, t.spill.had_state
        n, need = self._next_chunk_need(rec.req, rec.pos)
        need = max(need, 0)
        if d == t.domain:
            return need, False
        g = self._owner_group(t.domain)
        if d in g.domains:
            return len(t.blocks) + need, False
        return 0, False

    def _aging_clear(self, task) -> bool:
        """The bypass fairness backstop: True when no waiter AHEAD of
        ``task`` has been blocked longer than ``slo_aging_rounds`` —
        otherwise bypass is suspended and the line drains strictly FIFO
        until the aged waiter is granted.  (The head itself is protected
        by the safety bound; this bounds how long anyone else can be
        repeatedly jumped.)"""
        limit = self.ecfg.slo_aging_rounds
        my = self.waiters.seq_of(task)
        if my is None:
            return False
        for t in self.waiters.tasks():
            if self.waiters.seq_of(t) >= my:
                return True             # reached ourselves: all clear
            if self._round - self._wait_round.get(t.id, self._round) > limit:
                return False
        return True

    def _try_bypass(self, req: Request, total_tokens: int
                    ) -> Tuple[Optional["_Group"], Optional[KVTable]]:
        """Attempt a size-aware bypass grant for a non-head waiter.

        The reservation is EAGER (full cap pages up front, minus
        prefix-match credit) even on the lazy path: a bypassed stream
        never grows, so its footprint can never later eat into frees the
        head is waiting for — the no-delay bound is checked once, at
        grant time, and stays true.  Per candidate domain the grant must
        keep ``head_need`` free blocks (reserve's unclamped ``min_free``
        floor) and, for a spilled hybrid head, a second state slot."""
        rec = self._head_rec()
        if rec is None or rec.req.table is None:
            return None, None
        cands = [(g, d)
                 for g in sorted(self.groups,
                                 key=lambda gr: (gr.kv_pressure(),
                                                 len(gr.queue), gr.gid))
                 for d in self._domain_order(g)]
        matches: Dict[int, Tuple[List[int], int]] = {}
        if req.page_keys:
            matches = {d: self.pool.match_prefix(d, req.page_keys,
                                                 prompt_len=len(req.prompt))
                       for _, d in cands}
            cands.sort(key=lambda gd: -len(matches[gd[1]][0]))
        headroom = self.ecfg.headroom if self._lazy else 0
        for g, d in cands:
            bound = self._head_need_in(rec, d)
            if bound is None:
                continue
            hn, head_state = bound
            if (head_state and self.pool.has_state
                    and self.pool.free_states(d) < 2):
                continue                # the head's restore slot is not ours
            shared, ckpt = matches.get(d, ((), 0))
            table = self.pool.reserve(d, total_tokens,
                                      first_tokens=None,  # eager: no growth
                                      headroom=headroom,
                                      min_free=hn,
                                      count_failure=False,
                                      prefix_blocks=shared,
                                      prefix_state=ckpt)
            if table is not None:
                self.counters.add("kv_bypass_floor_pages", hn)
                # (round, granted rid, jumped head rid): the no-starvation
                # gates compare the FIRST entry's head across bypass-on/off
                # twins — dynamics are identical up to that round
                self.bypass_log.append((self._round, req.rid, rec.req.rid))
                return g, table
        return None, None

    # -- submission ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int,
               cls: str = "batch") -> Request:
        if cls not in self.ecfg.slo_classes:
            raise ValueError(
                f"unknown SLO class {cls!r}: configured classes are "
                f"{sorted(self.ecfg.slo_classes)}")
        req = Request(next(self._rid), np.asarray(prompt, np.int32), max_new,
                      arrived=self._clock(), cls=cls,
                      arrive_round=self._round)
        req._kv_fn = self._kv_fn
        self._inflight += 1
        self.submitted.append(req)
        self.counters.add(f"kv_class_submits/{cls}", 1)
        if not self.ecfg.paged:
            # legacy: route straight to the least-pressured group's queue
            g = min(self.groups,
                    key=lambda gr: (gr.kv_pressure(), len(gr.queue)))
            req.group = g.gid
            self.queues.push(g.gid, req)
            return req
        cell: Dict[str, Any] = {"req": req}
        cell["task"] = self.sched.spawn(
            self._admission_task(req, cell), name=f"admit{req.rid}",
            priority=1)
        # join the FIFO wait line AT SUBMIT TIME: grant order is submission
        # order, not coroutine execution order (workers pop LIFO, so a
        # burst of arrivals would otherwise be admitted newest-first — and
        # could starve a stream parked mid-decode before they arrived)
        req.wq_seq = self._join_line(cell["task"])
        if self._bypass_wake and self.ecfg.slo_classes[cls].bypass:
            self._bypass_cells[cell["task"].id] = cell
        return req

    # -- wait-line bookkeeping (size-aware bypass, PR 9) --------------------
    def _join_line(self, task, seq: Optional[int] = None) -> int:
        s = self.waiters.park(task, seq=seq)
        self._wait_round.setdefault(task.id, self._round)
        return s

    def _leave_line(self, task):
        """Grant-time cleanup + wake cascade: the next head retries, and
        bypass-eligible waiters get a shot too (a grant may have changed
        the head — and with it the safety bound)."""
        self.waiters.remove(task)
        self._wait_round.pop(task.id, None)
        self._bypass_cells.pop(task.id, None)
        self.waiters.wake(1)            # maybe the next waiter fits too
        self._wake_bypassers()

    def _on_pool_free(self):
        self.waiters.wake(1)
        self._wake_bypassers()

    def _wake_bypassers(self):
        for cell in self._bypass_cells.values():
            self.runtime.unblock(cell["task"])

    def _admission_task(self, req: Request, cell: Dict[str, Any]):
        """Per-request coroutine: reserve KV pages, sweeping groups by
        pressure; park on pool exhaustion until a free wakes us.

        Grants are FIFO across admissions AND mid-decode growers: every
        admission is in the wait line from submit time and only the line
        HEAD attempts a reservation, waiters stay in the line until their
        reservation is GRANTED, and a successful admission cascades the
        wake to the next waiter (frees wake exactly one task).

        ONE exception (PR 9, size-aware bypass): a bypass-class request
        may be granted while NOT the head — but only past a PARKED head,
        only in a domain where the grant provably leaves the head's whole
        restore/grow need free (``_try_bypass``), and only while no
        waiter ahead of it has aged past the fairness backstop.  The
        head's time-to-grant is untouched by construction: strict FIFO
        order is relaxed exactly where relaxing it is free."""
        total = len(req.prompt) + req.max_new
        # lazy: only the first chunk's pages are committed at admission
        first = (min(self._chunk, max(1, len(req.prompt)))
                 if self._lazy else None)
        if self._share and req.page_keys is None:
            req.page_keys = self.pool.prefix_keys(req.prompt)
        while True:
            if self.waiters.oldest() is cell["task"]:
                g, table = self._try_admit(total, first, req.page_keys,
                                           len(req.prompt))
                if table is not None:
                    break
            elif (self._bypass_on
                    and cell["task"].id in self._bypass_cells
                    and self._aging_clear(cell["task"])):
                g, table = self._try_bypass(req, total)
                if table is not None:
                    req.bypassed = True
                    self.counters.add("kv_bypass_grants", 1)
                    self.counters.add(f"kv_class_bypass/{req.cls}", 1)
                    break
            yield BLOCK                 # woken by KVBlockPool.free (heads
                                        # + bypass candidates) or a grant
        self._leave_line(cell["task"])
        req.grant_rounds.append(self._round)
        self.counters.add(f"kv_class_admits/{req.cls}", 1)
        req.table = table
        # shared prefix pages are already filled: prefill resumes at the
        # first unmatched chunk boundary (identical to a restored park)
        req.prefix_tokens = table.used_pages * self.pool.block_tokens
        req.group = g.gid
        self.queues.push(g.gid, req)
        return

    def open_loop_client(self, schedule: Iterable[Tuple[int, np.ndarray, int]]
                         ) -> Any:
        """Spawn an open-loop client on the shared TaskRuntime.

        ``schedule`` yields ``(gap_rounds, prompt, max_new)`` or
        ``(gap_rounds, prompt, max_new, cls)``: the client sleeps
        ``gap_rounds`` engine rounds (cooperative yields), then submits —
        arrivals over time instead of an up-front queue, so the controller
        sees steady-state load and tail latencies are real.  The optional
        4th element tags the arrival's SLO class (default ``"batch"``).
        """
        self._clients += 1

        def client():
            try:
                for item in schedule:
                    gap, prompt, max_new = item[0], item[1], item[2]
                    cls = item[3] if len(item) > 3 else "batch"
                    for _ in range(int(gap)):
                        yield
                    self.submit(prompt, max_new, cls=cls)
            finally:
                self._clients -= 1

        return self.sched.spawn(client(), name="client", priority=2)

    # -- live relayout: merge/split replica groups mid-run -------------------
    def _relayout(self, new_layout: Layout, decision: Decision):
        old_groups = self.groups
        if new_layout.replicas == len(old_groups):
            return
        if self.pool is not None:
            # quiesce the transfer engine: tables must not be harvested or
            # re-pointed with a D2H copy still on the wire
            self.pool.drain()
        # harvest in-flight streams and queued requests from the dissolving
        # groups; in paged mode KV stays in the pool (tables move, data
        # does not — except used pages of rebalanced streams).  Streams
        # harvested mid-prefill carry just their position: their next chunk
        # resumes on the new owner.  Mid-decode PARKED streams need no
        # harvesting at all — their regrow task re-resolves the owner group
        # of their domain when it wakes.
        inflight: List[_InFlight] = []
        queued: List[Request] = []
        mig0 = self.counters.totals.get("kv_blocks_migrated", 0.0)
        for g in old_groups:
            g.retired = True
            for slot, req in enumerate(g.slots):
                if req is None:
                    continue
                if self.ecfg.paged:
                    one = None
                else:
                    one = jax.tree.map(lambda p: p[:, slot], g.cache)
                inflight.append(_InFlight(req, one, int(g.pos_h[slot]),
                                          int(g.tok_h[slot])))
                g.slots[slot] = None
                # counted per slot-harvest so each migration pairs with
                # exactly one restore; resume-backlog streams below were
                # already counted on their first hop
                self.counters.add("kv_slots_migrated", 1)
                self.counters.add("migration_bytes", req.kv_bytes())
            inflight.extend(g.resume)
            g.resume = []
            while g.queue:
                queued.append(g.queue.popleft())
        self._build_groups()
        n = len(self.groups)
        if self.ecfg.paged:
            # tables follow their domain's new owner; only streams
            # rebalanced off the owner copy their used pages cross-domain
            cap = max(1, math.ceil(len(inflight) / n))
            load = {g.gid: 0 for g in self.groups}
            for fl in inflight:
                tgt = self._owner_group(fl.req.table.domain)
                if load[tgt.gid] >= cap:
                    alt = min(self.groups,
                              key=lambda gr: (load[gr.gid], gr.gid))
                    if alt is not tgt and self._migrate_into(fl.req.table,
                                                            alt):
                        tgt = alt
                fl.req.group = tgt.gid
                fl.req.migrations += 1
                load[tgt.gid] += 1
                tgt.resume.append(fl)
            for req in queued:
                tgt = self._owner_group(req.table.domain)
                req.group = tgt.gid
                self.queues.push(tgt.gid, req)
        else:
            for i, fl in enumerate(inflight):
                tgt = self.groups[i % n]
                fl.req.group = tgt.gid
                fl.req.migrations += 1
                tgt.resume.append(fl)
            for i, req in enumerate(queued):
                tgt = self.groups[i % n]
                req.group = tgt.gid
                self.queues.push(tgt.gid, req)
        self.relayouts.append({
            "step": decision.step, "old_groups": len(old_groups),
            "new_groups": n, "moved_slots": len(inflight),
            "requeued": len(queued), "reason": decision.reason,
            "blocks_migrated": self.counters.totals.get(
                "kv_blocks_migrated", 0.0) - mig0})
        if self._running:
            for g in self.groups:
                self._spawn_group(g)

    # -- paged device-side step builders -------------------------------------
    def _make_paged_decode(self):
        cfg, spec = self.cfg, self.pool.spec

        def paged_decode(params, storage, tables, state_slots, tokens, pos):
            view = dec.gather_cache_view(storage, spec, tables, state_slots)
            logits, view = dec.decode_step(params, cfg, view, tokens, pos)
            storage = dec.scatter_cache_view(storage, spec, tables,
                                             state_slots, view)
            return logits, storage

        return paged_decode

    def _make_paged_chunk(self, mode: str = "scan"):
        """The continuous-batching mixed step: prefill chunks and decode
        streams share one gather -> chunked-masked step -> scatter.
        ``mode="parallel"`` compiles the fused multi-token forward (one
        model pass per tick); "scan" the per-token reference."""
        spec = self.pool.spec
        step = make_serve_chunk_step(self.cfg, spec, mode=mode,
                                     chunk_kernel=self._chunk_kernel)

        def paged_chunk(params, storage, tables, state_slots, tokens, pos,
                        n_tokens):
            view = dec.gather_cache_view(storage, spec, tables, state_slots)
            logits, view = step(params, view, tokens, pos, n_tokens)
            storage = dec.scatter_cache_view(storage, spec, tables,
                                             state_slots, view)
            return logits, storage

        return paged_chunk

    def _make_paged_spec(self, mode: str = "scan"):
        """The speculative VERIFY step: same gather -> masked chunk forward
        -> scatter as ``_make_paged_chunk`` but returning the logits after
        EVERY fed token (B, C, V), so greedy acceptance can compare each
        draft against the argmax one position earlier.  The cache commits
        optimistically; the host rolls back rejected suffixes from the
        pool's page checkpoints."""
        spec = self.pool.spec
        step = make_spec_verify_step(self.cfg, spec, mode=mode,
                                     chunk_kernel=self._chunk_kernel)

        def paged_spec(params, storage, tables, state_slots, tokens, pos,
                       n_tokens):
            view = dec.gather_cache_view(storage, spec, tables, state_slots)
            logits, view = step(params, view, tokens, pos, n_tokens)
            storage = dec.scatter_cache_view(storage, spec, tables,
                                             state_slots, view)
            return logits, storage

        return paged_spec

    def _make_commit_prefill(self):
        spec = self.pool.spec

        def commit(storage, tables, state_slots, cache1):
            return dec.scatter_cache_view(storage, spec, tables,
                                          state_slots, cache1)

        return commit

    def _table_row(self, req: Optional[Request]) -> Tuple[List[int], int]:
        """Null-padded (pages, state_slot) row for the gather indices.
        Partially-grown tables pad their unallocated tail with the null
        block — those ring positions are past ``pos`` and never read."""
        P = self.pool.pages_per_stream
        if req is None or req.table is None:
            return [0] * P, 0
        t = req.table
        return t.blocks + [0] * (P - len(t.blocks)), t.state_slot

    def _group_indices(self, g: _Group) -> Tuple[jnp.ndarray, jnp.ndarray]:
        rows, slots = zip(*(self._table_row(r) for r in g.slots))
        P = self.pool.pages_per_stream
        tables = jnp.asarray(
            np.asarray(rows, np.int32).reshape(len(g.slots), P))
        return tables, jnp.asarray(np.asarray(slots, np.int32))

    # -- elastic growth / mid-decode parking ---------------------------------
    def _next_chunk_need(self, req: Request, pos: int) -> Tuple[int, int]:
        """(tokens the stream consumes next tick, pages its table is short
        by) — the single definition both the tick's growth phase and a
        parked stream's regrow retry must agree on."""
        S = len(req.prompt)
        n = min(self._chunk, S - pos) if pos < S else 1
        need = self.pool.pages_needed(pos + n) - len(req.table.blocks)
        return n, need

    def _grow_stream(self, req: Request, g: _Group, need: int,
                     forks: Tuple[int, ...] = ()) -> bool:
        """Commit ``need`` more pages for a stream — and privatize (CoW)
        any shared pages its next write touches — its own domain first,
        then any domain its replica group owns (migrating the used pages —
        memory follows the stream's placement, never the reverse; a
        migration COPIES every page, so the moved table is private and the
        pending forks dissolve)."""
        t = req.table
        if (all(self.pool.cow_fork(t, p) for p in forks)
                and self.pool.grow(t, need)):
            return True
        for d in self._domain_order(g):
            if d == t.domain:
                continue
            if self.pool.free_blocks(d) < len(t.blocks) + need:
                continue
            if self.pool.migrate(t, d) and self.pool.grow(t, need):
                return True
        return False

    def _park_stream(self, g: _Group, slot: int):
        """Suspend a stream MID-DECODE: it keeps its pages but releases its
        decode slot, joins the engine's FIFO wait line (ahead of any
        later-arriving admission) and resumes via the pool free callback."""
        req = g.slots[slot]
        g.slots[slot] = None
        rec = _Parked(req, int(g.pos_h[slot]), int(g.tok_h[slot]),
                      next(self._park_seq))
        self._parked[req.rid] = rec
        self.counters.add("kv_mid_decode_parks", 1)
        rec.cell["task"] = self.sched.spawn(
            self._regrow_task(rec), name=f"regrow{req.rid}", priority=1)
        # join the line NOW (synchronously): a request admitted after this
        # park must queue behind it — mid-decode streams cannot be starved
        # by newcomers (grants are FIFO by park order).  A BYPASSED stream
        # re-enters at its original ARRIVAL seq instead: it jumped the line
        # once under the no-delay bound, but parking must not also demote
        # it behind arrivals it legitimately preceded (to_back stays
        # reserved for spill victims, who consumed their turn)
        req.wq_seq = self._join_line(
            rec.cell["task"], seq=req.wq_seq if req.bypassed else None)

    def _regrow_task(self, rec: _Parked):
        """Waiter coroutine for a mid-decode parked stream: retry growth
        when it reaches the head of the line (same discipline as
        admission, so grants stay FIFO across admissions AND growers); on
        grant, hand the stream back to the owner group of its (possibly
        migrated) domain.

        If the stall watchdog SPILLED the stream while it waited, the
        retry becomes a restore: re-grant device pages (any domain —
        host-resident tables re-point for free), scatter the host payload
        back, and resume at the saved cursor — zero recomputed tokens."""
        req = rec.req
        while True:
            if rec.evicted:
                return
            if req.table is not None and req.table.inflight:
                # our own spill is still on the wire: the fence-before-
                # regrant invariant freezes the table until it lands (the
                # landing's free callback wakes the line head)
                yield BLOCK
                continue
            if self.waiters.oldest() is not rec.cell["task"]:
                if self._async and req.table.spill is not None:
                    # not our turn yet: stage the H2D upload behind the
                    # ticks ahead of us so the eventual re-grant scatters
                    # device-resident arrays instead of waiting on PCIe
                    self.pool.restore_prefetch(req.table)
                yield BLOCK             # not our turn: the grant cascade
                continue                # (or a free) will wake the head
            if req.table.spill is not None:
                g = self._restore_stream(rec)
                if g is not None:
                    break
            else:
                g = self._owner_group(req.table.domain)
                n, need = self._next_chunk_need(req, rec.pos)
                forks = (self.pool.fork_pages(req.table, rec.pos, n)
                         if self._share else [])
                if self._grow_stream(req, g, max(need, 0), tuple(forks)):
                    break
            yield BLOCK                 # woken by KVBlockPool.free
        self._leave_line(rec.cell["task"])
        req.grant_rounds.append(self._round)
        self._parked.pop(req.rid, None)
        req.group = g.gid
        g.resume.append(_InFlight(req, None, rec.pos, rec.token))
        return

    def _restore_stream(self, rec: _Parked) -> Optional["_Group"]:
        """Re-grant a SPILLED stream: find a domain with room for its host
        pages PLUS the growth its next chunk needs (its own domain first —
        re-pointing a host-resident table to any other is free) and land
        it there in ONE atomic ``restore_into`` leg; None when no domain
        can take it yet.  The old sweep re-pointed, restored and grew in
        separate steps — a leg whose grow failed after the restore left
        the stream half-granted in the wrong domain with its state
        checkpoint consumed.  ``restore_into`` reserves pages + grow +
        state slot all-or-nothing, so a failed leg has zero side effects
        and the sweep just tries the next domain."""
        req = rec.req
        t = req.table
        n, _ = self._next_chunk_need(req, rec.pos)
        grow_by = max(0, self.pool.pages_needed(rec.pos + n) - t.spill.pages)
        order = [t.domain] + [
            d for g in sorted(self.groups,
                              key=lambda gr: (gr.kv_pressure(), gr.gid))
            for d in self._domain_order(g) if d != t.domain]
        for d in order:
            if self.pool.restore_into(t, d, grow_by=grow_by):
                return self._owner_group(t.domain)
        return None

    # -- allocation-stall watchdog (the incremental-allocation deadlock) -----
    def _progress_signature(self) -> float:
        t = self.counters.totals
        return (t.get("tokens_processed", 0.0)
                + t.get("kv_reservations", 0.0)
                + t.get("kv_lazy_grows", 0.0)
                + t.get("kv_blocks_freed", 0.0)
                # an ISSUED spill is progress-in-motion: its frees are on
                # the wire, so the watchdog must not fire again before the
                # landing re-grants them
                + t.get("kv_spill_issues", 0.0))

    def _stall_hook(self):
        """Called by the scheduler after every round.  If nothing has made
        progress for ``stall_evict_rounds`` rounds while streams sit parked
        holding pages, the classic incremental-allocation deadlock has
        closed: break it by evicting the MOST-RECENTLY-parked stream (it
        loses the least work and nobody behind it in the line exists)."""
        if self.pool is None:
            return
        self._round += 1
        if self._async:
            # poll phase of the ladder: land every transfer whose device
            # arrays report ready — landings fire the free callback, so
            # re-grants happen here, not at issue
            self.pool.spill_poll()
        if len(self.waiters):
            # rounds the wait line spent non-empty: the head-blocking
            # exposure the size-aware bypass converts into admissions
            self.counters.add("kv_head_wait_ticks", 1)
        head = self.waiters.oldest()
        hid = head.id if head is not None else None
        if hid != self._head_id:
            self._head_id, self._head_wait = hid, 0
        elif hid is not None:
            self._head_wait += 1
        # proactive-spill rung of the pressure ladder: a domain crossing
        # its HIGH occupancy watermark sheds ONE cold parked stream NOW,
        # before the allocation stall can close into a watchdog-grade
        # deadlock (hysteresis: it re-arms only under the LOW mark)
        if self._parked:
            infl = self.pool.inflight_domains() if self._async else set()
            for d in self.pool.watermark_domains():
                if d in infl:
                    continue            # its frees are already in the pipe:
                                        # never double-spill a domain
                if self._spill_parked(domain=d):
                    self.pool.watermark_arm(d)
                    self.counters.add("kv_proactive_spills", 1)
        sig = self._progress_signature()
        if sig != self._progress_mark:
            self._progress_mark = sig
            self._stall_rounds = 0
        else:
            self._stall_rounds += 1
        stalled = self._stall_rounds >= self.ecfg.stall_evict_rounds
        # Bypassed streams tick the GLOBAL progress clock (their tokens and
        # frees are progress) without ever feeding the head's need domains —
        # left alone they would postpone the very spill that unblocks the
        # head, re-introducing the delay the bypass-safety bound rules out.
        # Once any bypass grant exists, the head's OWN wait drives the
        # watchdog too: the head is unblocked at the same round or earlier
        # than a no-bypass run, never later.
        head_stalled = (not stalled
                        and self.counters.totals.get("kv_bypass_grants",
                                                     0.0) > 0
                        and self._head_wait >= self.ecfg.stall_evict_rounds)
        if stalled and self._parked:
            if self._async and self.pool.inflight_tables():
                # a spill is already on the wire: fence it instead of
                # issuing another — the landing re-grants the victim's
                # pages, which is exactly the progress the watchdog wants
                self.pool.spill_fence()
            elif self.ecfg.evict_mode == "swap" and self._spill_youngest():
                self.counters.add("kv_watchdog_spills", 1)
            else:
                self._evict_youngest()
            self._stall_rounds = 0
            self._head_wait = 0
        elif head_stalled and self._parked:
            # the head-wait rung frees pages the head can actually USE: a
            # parked grower regrows only in its own domain, so the victim
            # must hold pages there (a spilled or admission head restores
            # anywhere — any domain's coldest park will do).  Never spill
            # the head itself: that would demote it to the back of the
            # line, manufacturing the starvation this rung prevents.
            hr = self._head_rec()
            dom = None
            if hr is not None and hr.req.table is not None \
                    and hr.req.table.spill is None:
                dom = hr.req.table.domain
            ex = hr.req.rid if hr is not None else None
            if self._async and self.pool.inflight_tables():
                self.pool.spill_fence()     # land the pipe before adding
                self._head_wait = 0         # to it (same as the stalled
                return                      # rung)
            if self.ecfg.evict_mode == "swap" and (
                    self._spill_parked(domain=dom, exclude_rid=ex)
                    or (dom is not None
                        and self._spill_parked(domain=None, exclude_rid=ex))):
                self.counters.add("kv_watchdog_spills", 1)
            self._head_wait = 0

    def _spill_youngest(self) -> bool:
        """Swap-tier deadlock breaker: move the most-recently-parked
        stream's used pages to the host spill store — its device pages go
        to the LONGEST-parked waiter via the free callback, but nothing is
        recomputed: the stream keeps its saved cursor and restores
        mid-decode when it is re-granted pages.  The victim re-queues at
        the BACK of the wait line (it had its turn), exactly where
        restart-eviction would have sent its re-admission.  False when
        every parked stream is already host-resident (nothing left to
        spill — the caller falls back to restart eviction)."""
        return self._spill_parked(domain=None)

    def _spill_parked(self, domain: Optional[int],
                      exclude_rid: Optional[int] = None) -> bool:
        """Spill the most-recently-parked spillable stream — pool-wide for
        the stall watchdog, or restricted to ``domain`` for the proactive
        watermark rung and the head-wait rung (which also excludes the
        line head itself via ``exclude_rid``).  The victim rule is shared:
        the youngest park re-queues at the back of the line either way, so
        of all parked streams its pages are the COLDEST — the last the
        line will ask for.  False when nothing in scope is left to
        spill."""
        cands = [r for r in self._parked.values()
                 if r.req.table is not None and r.req.table.spill is None
                 and not r.req.table.inflight
                 and r.req.table.blocks
                 and r.req.rid != exclude_rid
                 and (domain is None or r.req.table.domain == domain)]
        if not cands:
            return False
        if self._async and domain is not None:
            # async ladder, domain-scoped rungs: the §4.5 access counters
            # pick the victim — min ``last_touch`` is the parked stream
            # whose pages have gone longest without a decode tick, so its
            # bytes are the cheapest to push behind the token loop
            rec = min(cands, key=lambda r: (r.req.table.last_touch, r.seq))
        else:
            rec = max(cands, key=lambda r: r.seq)
        task = rec.cell.get("task")
        if task is not None:
            # demote BEFORE spilling: the spill's free callback wakes the
            # line head, which must be the next waiter — not the victim.
            # The fresh seq retires any arrival-position claim a bypassed
            # victim held: it consumed its turn
            ns = self.waiters.to_back(task)
            if ns is not None:
                rec.req.wq_seq = ns
                self._wait_round[task.id] = self._round
        if self._async:
            # issue-only: the D2H copy drains behind the token loop and
            # the victim's pages re-grant at the poll that lands it
            # (fence-before-regrant) — the wake fires there, not here
            self.pool.spill_issue(rec.req.table)
        else:
            self.pool.spill(rec.req.table)  # frees pages -> wakes the head
        rec.seq = next(self._park_seq)  # its park is "fresh" again
        return True

    def _evict_youngest(self):
        """Restart-eviction deadlock breaker (``evict_mode="restart"``, and
        the swap mode's last resort): free the most-recently-parked
        stream's pages (granting them to the LONGEST-parked waiter via the
        free callback) and restart it from scratch — greedy decoding
        regenerates the identical tokens, so eviction is invisible in the
        output, but every token processed so far is recomputed
        (``recompute_tokens``)."""
        rec = max(self._parked.values(), key=lambda r: r.seq)
        rec.evicted = True
        self._parked.pop(rec.req.rid, None)
        task = rec.cell.get("task")
        if task is not None:
            self.waiters.remove(task)
            self._wait_round.pop(task.id, None)
            self.runtime.unblock(task)  # let the generator observe .evicted
        req = rec.req
        self.pool.free(req.table)       # wakes the longest-parked waiter
        req.table = None
        req.generated = []
        req.t_first = None
        req.bypassed = False            # the restart is a fresh admission
        self.counters.add("kv_evictions", 1)
        self.counters.add("recompute_tokens", rec.pos)
        cell: Dict[str, Any] = {"req": req}
        cell["task"] = self.sched.spawn(
            self._admission_task(req, cell), name=f"readmit{req.rid}",
            priority=1)
        # back of the line: it had its turn (and that demotion replaces
        # any arrival-position claim for future parks)
        req.wq_seq = self._join_line(cell["task"])
        if self._bypass_wake and self.ecfg.slo_classes[req.cls].bypass:
            self._bypass_cells[cell["task"].id] = cell

    # -- one engine tick: admit + mixed chunk/decode token step ---------------
    def _install(self, g: _Group, slot: int, fl: _InFlight):
        """Re-slot a migrated stream.  Paged mode is pure bookkeeping (the
        KV never left the pool); legacy mode writes the carried slice."""
        if not self.ecfg.paged:
            g.cache = jax.tree.map(
                lambda pool, one: pool.at[:, slot].set(one),
                g.cache, fl.cache)
        g.slots[slot] = fl.req
        g.pos_h[slot] = fl.pos
        g.tok_h[slot] = fl.token
        self.counters.add("kv_slots_restored", 1)

    def _accept_steal(self, g: _Group):
        """TieredQueues accept hook: a stolen request's KV reservation must
        move into the thief's memory domain (memory follows work).
        Partially-grown tables move only their reserved pages."""
        def accept(req: Request, _tier: str) -> bool:
            if not self.ecfg.paged or req.table is None:
                return True
            return self._migrate_into(req.table, g)
        return accept

    def _admit(self, g: _Group):
        for slot in g.free_slots():
            if g.resume:                       # migrated streams first
                self._install(g, slot, g.resume.pop(0))
                continue
            req, tier = self.queues.pop(g.gid, accept=self._accept_steal(g))
            if req is None:
                break
            if tier != "local":
                req.group = g.gid
            if self._lazy:
                # the token loop prefills this stream chunk-by-chunk;
                # admission points a slot at the first unmatched prompt
                # position (0 when no prefix pages were shared)
                g.slots[slot] = req
                g.pos_h[slot] = req.prefix_tokens
                g.tok_h[slot] = 0
                continue
            prompt = req.prompt[None, :]
            logits, cache1 = self._prefill(self.params, {"tokens": prompt})
            nxt = int(jnp.argmax(logits[0]))
            req.generated.append(nxt)
            req.t_first = self._clock()
            self.counters.add("prefills", 1)
            self.counters.add("tokens_processed", len(req.prompt))
            if len(req.generated) >= req.max_new:
                # prefill's token already met the budget (max_new=1):
                # finish without ever taking a decode slot or pool pages
                req.t_done = req.t_first
                self._inflight -= 1
                if self.ecfg.paged:
                    self.pool.free(req.table)
                continue
            if self.ecfg.paged:
                tables, slots1 = self._table_row(req)
                self.pool.storage = self._commit_prefill(
                    self.pool.storage,
                    jnp.asarray(np.asarray([tables], np.int32)),
                    jnp.asarray(np.asarray([slots1], np.int32)), cache1)
                req.table.used_pages = self.pool.pages_needed(
                    len(req.prompt))
            else:
                # copy the single-stream cache into the group slot
                g.cache = jax.tree.map(
                    lambda pool, one: pool.at[:, slot].set(one[:, 0]),
                    g.cache, cache1)
            g.slots[slot] = req
            g.pos_h[slot] = len(req.prompt)
            g.tok_h[slot] = nxt

    def _split_tick(self, g: _Group, n_h, toks, C: int,
                    deco_rows: List[int]) -> np.ndarray:
        """A mixed tick as TWO compiled steps instead of one C-wide step.

        BOTH halves run over COMPACTED batches padded to a power-of-two
        bucket (so the number of distinct compiled shapes stays
        O(log max_batch) per half): the fused chunk forward holds only the
        multi-token prefill streams, the single-token step only the decode
        streams.  Bucket padding rows point at the null table/state slot
        (reserved id 0 — written but never read, the same convention empty
        slots use).  The two steps touch disjoint real pages, so running
        them back to back over the donated storage is exact.  Decode
        streams thus pay 1 query row instead of C — the (C-1)·n_decode
        rows saved land in ``mixed_tick_decode_rows_saved`` — and the
        decode gather/scatter moves bucket-of-n_decode rows instead of
        max_batch (``decode_gather_rows_saved``).
        """
        B = self.ecfg.max_batch
        P = self.pool.pages_per_stream
        chunk_rows = [i for i in range(B) if n_h[i] > 1]
        # -- chunk half: compacted fused forward over prefill streams only
        Bc = 1
        while Bc < len(chunk_rows):
            Bc *= 2
        Bc = min(Bc, B)
        rows = chunk_rows + [None] * (Bc - len(chunk_rows))
        trows, srows = zip(*(self._table_row(g.slots[i])
                             if i is not None else self._table_row(None)
                             for i in rows))
        toks_c = np.zeros((Bc, C), np.int32)
        pos_c = np.zeros((Bc,), np.int32)
        n_c = np.zeros((Bc,), np.int32)
        for j, i in enumerate(chunk_rows):
            toks_c[j] = toks[i]
            pos_c[j] = g.pos_h[i]
            n_c[j] = n_h[i]
        logits_c, self.pool.storage = self._paged_chunk(
            self.params, self.pool.storage,
            jnp.asarray(np.asarray(trows, np.int32).reshape(Bc, P)),
            jnp.asarray(np.asarray(srows, np.int32)),
            jnp.asarray(toks_c), jnp.asarray(pos_c), jnp.asarray(n_c))
        nxt_c = np.asarray(dec.next_token_ids(logits_c, jnp.asarray(n_c)))
        # -- decode half: the single-token step, compacted to its own bucket
        Bd = 1
        while Bd < len(deco_rows):
            Bd *= 2
        Bd = min(Bd, B)
        rows_d = deco_rows + [None] * (Bd - len(deco_rows))
        trows, srows = zip(*(self._table_row(g.slots[i])
                             if i is not None else self._table_row(None)
                             for i in rows_d))
        toks_d = np.zeros((Bd, 1), np.int32)
        pos_d = np.zeros((Bd,), np.int32)
        n_d = np.zeros((Bd,), np.int32)
        for j, i in enumerate(deco_rows):
            toks_d[j, 0] = toks[i, 0]
            pos_d[j] = g.pos_h[i]
            n_d[j] = 1
        logits_d, self.pool.storage = self._paged_decode(
            self.params, self.pool.storage,
            jnp.asarray(np.asarray(trows, np.int32).reshape(Bd, P)),
            jnp.asarray(np.asarray(srows, np.int32)),
            jnp.asarray(toks_d), jnp.asarray(pos_d))
        nxt_d = np.asarray(dec.next_token_ids(logits_d, jnp.asarray(n_d)))
        nxt = np.full((B,), -1, np.int32)   # idle rows keep the sentinel
        for j, i in enumerate(deco_rows):
            nxt[i] = nxt_d[j]
        for j, i in enumerate(chunk_rows):
            nxt[i] = nxt_c[j]
        self.counters.add("split_ticks", 1)
        self.counters.add("mixed_tick_decode_rows_saved",
                          (C - 1) * len(deco_rows))
        self.counters.add("decode_gather_rows_saved", B - Bd)
        self.counters.add("decode_gather_null_rows", Bd - len(deco_rows))
        return nxt

    def _draft_for(self, req: Request, pos: int) -> List[int]:
        """Up to spec_k draft tokens for a DECODE stream — empty during
        prefill, near max_new (the verify chunk's free boundary token must
        never overrun the budget), or when the drafter has nothing.
        Proposals are sanitized (in-vocab prefix) but never trusted: the
        verify forward is the only thing that commits tokens."""
        S = len(req.prompt)
        if pos < S:
            return []
        k = min(self.ecfg.spec_k, self._spec_w - 1,
                req.max_new - len(req.generated) - 1)
        if k <= 0:
            return []
        out: List[int] = []
        for t in self.drafter.draft(req, k)[:k]:
            t = int(t)
            if not 0 <= t < self.cfg.vocab:
                break
            out.append(t)
        return out

    def _spec_verify(self, g: _Group, toks, n_h,
                     drafts: Dict[int, List[int]]) -> Dict[int, np.ndarray]:
        """The verify half: ONE all-position-logits fused chunk forward
        over the drafted rows, compacted into their own pow-2 bucket at
        the narrow spec width (drafted rows never share a compiled program
        with prefill chunks or plain decode rows, so those paths stay
        bit-identical to the spec-off engine).  The cache commits
        optimistically; rejected suffixes roll back from the page
        checkpoints.  Returns row -> (n_i, V) logits."""
        rows = sorted(drafts)
        W = self._spec_w
        P = self.pool.pages_per_stream
        Bs = 1
        while Bs < len(rows):
            Bs *= 2
        Bs = min(Bs, self.ecfg.max_batch)
        rs = rows + [None] * (Bs - len(rows))
        trows, srows = zip(*(self._table_row(g.slots[i])
                             if i is not None else self._table_row(None)
                             for i in rs))
        toks_s = np.zeros((Bs, W), np.int32)
        pos_s = np.zeros((Bs,), np.int32)
        n_s = np.zeros((Bs,), np.int32)
        for j, i in enumerate(rows):
            n = int(n_h[i])
            toks_s[j, :n] = toks[i, :n]
            pos_s[j] = g.pos_h[i]
            n_s[j] = n
        lg, self.pool.storage = self._paged_spec(
            self.params, self.pool.storage,
            jnp.asarray(np.asarray(trows, np.int32).reshape(Bs, P)),
            jnp.asarray(np.asarray(srows, np.int32)),
            jnp.asarray(toks_s), jnp.asarray(pos_s), jnp.asarray(n_s))
        lg = np.asarray(lg)
        self.counters.add("spec_verify_forwards", 1)
        self.counters.add("spec_row_forwards", len(rows))
        return {i: lg[j, :int(n_h[i])] for j, i in enumerate(rows)}

    def _spec_reapply(self, g: _Group, toks,
                      rows: List[Tuple[int, int]]):
        """Re-apply the ACCEPTED prefix of each rolled-back draft row with
        one masked chunk forward from the restored pre-verify state
        (logits discarded — the verify pass already fixed the committed
        tokens).  Causal masking makes this bit-equivalent to having fed
        only those tokens in the first place."""
        W = self._spec_w
        P = self.pool.pages_per_stream
        Br = 1
        while Br < len(rows):
            Br *= 2
        Br = min(Br, self.ecfg.max_batch)
        rs = rows + [(None, 0)] * (Br - len(rows))
        trows, srows = zip(*(self._table_row(g.slots[i])
                             if i is not None else self._table_row(None)
                             for i, _ in rs))
        toks_r = np.zeros((Br, W), np.int32)
        pos_r = np.zeros((Br,), np.int32)
        n_r = np.zeros((Br,), np.int32)
        for j, (i, nc) in enumerate(rows):
            toks_r[j, :nc] = toks[i, :nc]
            pos_r[j] = g.pos_h[i]
            n_r[j] = nc
        _, self.pool.storage = self._paged_chunk(
            self.params, self.pool.storage,
            jnp.asarray(np.asarray(trows, np.int32).reshape(Br, P)),
            jnp.asarray(np.asarray(srows, np.int32)),
            jnp.asarray(toks_r), jnp.asarray(pos_r), jnp.asarray(n_r))
        self.counters.add("spec_reapply_forwards", 1)
        self.counters.add("spec_row_reapplies", len(rows))

    def _decode_tick(self, g: _Group):
        """ONE batched model step for the group: every occupied slot
        consumes its next tokens — a page-sized prompt chunk for streams
        still in prefill, the last generated token (plus up to spec_k
        drafted tokens when speculative decoding is on) for decode
        streams.  Lazy tables grow (or park their stream) before the step
        commits any bytes."""
        B = self.ecfg.max_batch
        n_h = np.zeros((B,), np.int32)
        chunked = False
        drafts: Dict[int, List[int]] = {}
        for i in range(B):
            req = g.slots[i]
            if req is None:
                continue
            pos = int(g.pos_h[i])
            if req.table is not None and self.ecfg.paged:
                self.pool.touch_table(req.table)
                n, need = self._next_chunk_need(req, pos)
                d = self._draft_for(req, pos) if self._spec else []
                if d:
                    # a drafted decode stream writes 1 + k positions this
                    # tick: growth and CoW must cover the full draft width
                    # BEFORE the optimistic verify forward touches pages
                    n = 1 + len(d)
                    need = (self.pool.pages_needed(pos + n)
                            - len(req.table.blocks))
                forks = (self.pool.fork_pages(req.table, pos, n)
                         if self._share else [])
                grown = not (self._lazy and self.pool.pages_per_stream
                             and (need > 0 or forks)) \
                    or self._grow_stream(req, g, max(need, 0), tuple(forks))
                if not grown and d:
                    # speculation is opportunistic: under memory pressure
                    # drop the draft and retry as a plain decode, so spec
                    # never parks a stream the non-speculative engine
                    # would have run this tick
                    d = []
                    n, need = self._next_chunk_need(req, pos)
                    forks = (self.pool.fork_pages(req.table, pos, n)
                             if self._share else [])
                    grown = not (need > 0 or forks) or self._grow_stream(
                        req, g, max(need, 0), tuple(forks))
                if not grown:
                    self._park_stream(g, i)
                    continue
                if self._share:
                    # writing into a published page forks the page's index
                    # entry off it (the OLD block keeps its entry)
                    self.pool.note_writes(req.table, pos, n)
                if d:
                    drafts[i] = d
            else:
                S = len(req.prompt)
                n = min(self._chunk, S - pos) if pos < S else 1
            n_h[i] = n
            # drafted rows run their OWN verify half; "chunked" tracks
            # only real prefill chunks so the spec-off paths (and their
            # counters) stay byte-for-byte unchanged
            chunked = chunked or (n > 1 and i not in drafts)
        if not n_h.any():
            return
        if self.ecfg.paged and self.pool.inflight_tables():
            # the overlap clock: a real model tick ran with at least one
            # D2H transfer on the wire — decode time the spill hid behind
            self.counters.add("kv_ticks_while_inflight", 1)
        if self.ecfg.paged:
            tables, slots1 = self._group_indices(g)
        pos_j = jnp.asarray(g.pos_h)
        # per-stream token feed: the next prompt slice for streams still in
        # prefill (a final chunk may hold a single token), the last emitted
        # token — plus its draft continuation — for decode streams
        C = self._chunk if chunked else (self._spec_w if drafts else 1)
        toks = np.zeros((B, C), np.int32)
        for i in range(B):
            req = g.slots[i]
            if req is None or not n_h[i]:
                continue
            pos = int(g.pos_h[i])
            if pos < len(req.prompt):
                toks[i, :n_h[i]] = req.prompt[pos:pos + n_h[i]]
            else:
                toks[i, 0] = g.tok_h[i]
                d = drafts.get(i)
                if d:
                    toks[i, 1:1 + len(d)] = d
        # drafted rows are carved out of the regular paths (n_eff = 0:
        # gathered but never computed or written) — they run through the
        # dedicated verify half below, so prefill chunks and plain decode
        # rows execute the EXACT compiled programs the spec-off engine runs
        n_eff = n_h.copy()
        for i in drafts:
            n_eff[i] = 0
        deco_rows = [i for i in range(B) if n_eff[i] == 1]
        if chunked:
            # model-step accounting, STRUCTURAL (by construction of the
            # compiled path, not measured at runtime): the fused path is
            # one forward per tick, the scan path a length-C lax.scan of
            # decode_step.  The benchmark's parallel-vs-scan token
            # identity is the behavioral gate; this feeds the C× metric.
            self.counters.add("chunk_ticks", 1)
            self.counters.add(
                "prefill_model_steps",
                1 if self._prefill_mode == "parallel" else C)
            if self.ecfg.split_ticks and deco_rows:
                nxt = self._split_tick(g, n_eff, toks, C, deco_rows)
            else:
                if deco_rows:
                    # single-token streams ride the C-wide step: C-1 of
                    # their query rows are pure masked-FLOP waste
                    self.counters.add("decode_masked_query_rows",
                                      (C - 1) * len(deco_rows))
                logits, self.pool.storage = self._paged_chunk(
                    self.params, self.pool.storage, tables, slots1,
                    jnp.asarray(toks), pos_j, jnp.asarray(n_eff))
                nxt = np.asarray(dec.next_token_ids(logits,
                                                    jnp.asarray(n_eff)))
        elif deco_rows:
            tokens = jnp.asarray(toks[:, :1])
            if self.ecfg.paged:
                if drafts:
                    # the single-token step has NO per-row length mask, so
                    # a drafted row riding it would write its ring page
                    # AND advance its recurrent state a second time before
                    # the verify half runs.  Point drafted rows at the
                    # null table/state row instead (reserved id 0 —
                    # written but never read, the same convention idle
                    # slots and bucket padding use); their logits are
                    # already masked to the -1 sentinel via n_eff.
                    P = self.pool.pages_per_stream
                    rowlist, slotlist = zip(
                        *(self._table_row(None) if i in drafts
                          else self._table_row(g.slots[i])
                          for i in range(B)))
                    tables = jnp.asarray(
                        np.asarray(rowlist, np.int32).reshape(B, P))
                    slots1 = jnp.asarray(np.asarray(slotlist, np.int32))
                logits, self.pool.storage = self._paged_decode(
                    self.params, self.pool.storage, tables, slots1,
                    tokens, pos_j)
            else:
                logits, g.cache = self._decode(self.params, g.cache, tokens,
                                               pos_j)
            # idle-slot hardening: slots with n == 0 get the -1 sentinel,
            # never an argmax over a constant (all-zero / all-NEG_INF) row
            nxt = np.asarray(dec.next_token_ids(logits, jnp.asarray(n_eff)))
        else:
            nxt = np.full((B,), -1, np.int32)   # pure-spec tick
        if deco_rows:
            self.counters.add("decode_row_forwards", sum(
                1 for i in deco_rows
                if int(g.pos_h[i]) >= len(g.slots[i].prompt)))
            if not chunked or self.ecfg.split_ticks:
                self.counters.add("decode_forwards", 1)
        # -- speculative verify half: one all-logits fused forward over the
        # drafted rows, then greedy acceptance with checkpoint rollback
        commits: Dict[int, List[int]] = {}
        if drafts:
            self.counters.add("spec_ticks", 1)
            # Rollback needs, per row.  While the write window stays below
            # the ring width, rejected-suffix KV PAGE writes are dead
            # weight, never wrong: position -> ring slot is injective
            # there, the suffix sits at or past the committed cursor, and
            # every read (attention gather, prefix match, spill) is
            # cursor-masked, so the stale bytes are overwritten before any
            # read can see them.  Once ``pos + n`` crosses the ring width
            # (local-attention models whose window is narrower than
            # max_len) a rejected write at position p lands on slot
            # p % W and DESTROYS the still-live position p - W, so the
            # touched pages must be snapshotted.  Recurrent STATE always
            # needs its snapshot: the slot holds the reduction over ALL n
            # fed tokens and cannot be recomputed from pages.  A partial
            # accept restores the snapshot and re-applies the accepted
            # prefix to advance it.
            ring_w = self.pool.spec.width if self.pool.pages_per_stream \
                else 0
            snap_rows: List[Tuple[KVTable, int, int, bool]] = []
            snap_idx: List[int] = []
            for i in sorted(drafts):
                p0, nn = int(g.pos_h[i]), int(n_h[i])
                wraps = bool(ring_w) and p0 + nn > ring_w
                if self.pool.has_state or wraps:
                    snap_rows.append((g.slots[i].table, p0, nn, wraps))
                    snap_idx.append(i)
            # ONE device gather snapshots every drafted row (PR-8
            # leftover): the checkpoints stay device-resident — a full
            # accept drops them without any host copy ever happening
            snaps = dict(zip(snap_idx,
                             self.pool.checkpoint_rows(snap_rows))) \
                if snap_rows else {}
            spec_lg = self._spec_verify(g, toks, n_h, drafts)
            reapply: List[Tuple[int, int]] = []
            rolled: List[dict] = []
            for i in sorted(drafts):
                n = int(n_h[i])
                am = np.argmax(spec_lg[i], axis=-1)
                # accept the longest prefix where each draft token matches
                # the verified argmax one position earlier; the token at
                # the accept boundary comes free (full accept: k+1 tokens)
                m = 0
                while m < n - 1 and int(toks[i, m + 1]) == int(am[m]):
                    m += 1
                commits[i] = [int(x) for x in am[:m + 1]]
                self.counters.add("spec_tokens_drafted", n - 1)
                self.counters.add("spec_tokens_accepted", m)
                if m + 1 < n:
                    self.counters.add("spec_rollbacks", 1)
                    if m == 0:
                        self.counters.add("spec_full_rejects", 1)
                    if i in snaps:
                        rolled.append(snaps[i])
                        reapply.append((i, m + 1))
            if rolled:
                # one batched scatter restores every rejected row
                self.pool.rollback_rows(rolled)
            if reapply:
                self._spec_reapply(g, toks, reapply)
            drafted = self.counters.totals.get("spec_tokens_drafted", 0.0)
            if drafted:
                self.counters.set(
                    "spec_accept_rate",
                    self.counters.totals.get("spec_tokens_accepted", 0.0)
                    / drafted)
        g.steps += 1
        now = self._clock()
        for i in range(B):
            req = g.slots[i]
            if req is None or not n_h[i]:
                continue
            S = len(req.prompt)
            pos0 = int(g.pos_h[i])
            if i in commits:
                # a drafted decode row commits its verified tokens: the
                # accepted draft prefix plus the free boundary token.  The
                # cursor lands on the last ACCEPTED position — a park or
                # spill next tick saves exactly this state
                out = commits[i]
                g.pos_h[i] = pos0 + len(out)
                self.counters.add("tokens_processed", len(out))
                self.counters.add("decode_committed_tokens", len(out))
                for tok in out:
                    assert tok >= 0, f"spec slot {i} emitted a sentinel"
                    req.generated.append(tok)
                g.tok_h[i] = out[-1]
                req.table.used_pages = min(
                    len(req.table.blocks),
                    self.pool.pages_needed(pos0 + len(out)))
                if len(req.generated) >= req.max_new:
                    req.t_done = now
                    g.slots[i] = None
                    self._inflight -= 1
                    self.pool.free(req.table)  # wakes parked streams
                continue
            new_pos = pos0 + int(n_h[i])
            g.pos_h[i] = new_pos
            self.counters.add("tokens_processed", int(n_h[i]))
            if pos0 >= S:
                self.counters.add("decode_committed_tokens", 1)
            if pos0 < S:
                self.counters.add("prefill_chunks", 1)
                if self.ecfg.paged:
                    req.table.used_pages = min(
                        len(req.table.blocks),
                        self.pool.pages_needed(new_pos))
                if self._share and req.page_keys:
                    # publish the prompt pages this chunk completed so
                    # later requests with the same prefix can attach
                    self.pool.register_prefix(req.table, req.page_keys,
                                              pos0, new_pos, S)
                if new_pos < S:
                    continue            # mid-prompt: no token emitted yet
                req.t_first = now
                self.counters.add("prefills", 1)
            tok = int(nxt[i])
            assert tok >= 0, f"idle slot {i} emitted a token"
            req.generated.append(tok)
            g.tok_h[i] = tok
            if self.ecfg.paged:
                req.table.used_pages = min(len(req.table.blocks),
                                           self.pool.pages_needed(new_pos))
            if len(req.generated) >= req.max_new:
                req.t_done = now
                g.slots[i] = None
                self._inflight -= 1
                if self.ecfg.paged:
                    self.pool.free(req.table)  # wakes parked streams
        self.counters.add("decode_steps", 1)
        self.counters.add("decode_tokens",
                          sum(1 for s in g.slots if s is not None))

    # -- engine task (coroutine per group, scheduled by the task runtime) ----
    def _group_task(self, g: _Group):
        while not g.retired:
            outstanding = self._inflight > 0 or self._clients > 0
            if not g.busy() and not outstanding:
                return
            self._admit(g)
            self._decode_tick(g)
            yield   # yield point: profiler + Algorithm 1 + possible relayout

    def _spawn_group(self, g: _Group):
        self.sched.spawn(self._group_task(g), group=g.gid,
                         name=f"group{g.gid}")

    def _round_metrics(self) -> Optional[Callable[[], Dict[str, float]]]:
        """Per-round profiler feed: KV-pool gauges + deltas since the
        previous round (None in legacy slot-monolith mode)."""
        if self.pool is None:
            return None
        names = ("kv_alloc_failures", "kv_blocks_migrated", "kv_lazy_grows",
                 "kv_mid_decode_parks", "prefill_chunks",
                 "kv_spilled_pages", "kv_restores", "recompute_tokens",
                 "mixed_tick_decode_rows_saved",
                 "kv_prefix_hits", "prefill_tokens_skipped",
                 "spec_tokens_drafted", "spec_tokens_accepted",
                 "spec_rollbacks", "kv_bypass_grants", "kv_head_wait_ticks",
                 "kv_ticks_while_inflight", "kv_fence_waits")
        state = {"t": self._clock()}
        state.update({n: self.counters.totals.get(n, 0.0) for n in names})

        def metrics() -> Dict[str, float]:
            t1 = self._clock()
            cur = {n: self.counters.totals.get(n, 0.0) for n in names}
            out = {"step_time": t1 - state["t"],
                   "kv_occupancy": self.pool.occupancy(),
                   "kv_parks": cur["kv_alloc_failures"]
                   - state["kv_alloc_failures"],
                   "kv_shared_pages": float(self.pool.shared_pages()),
                   "kv_shared_bytes": self.pool.shared_bytes(),
                   "spec_accept_rate": self.counters.totals.get(
                       "spec_accept_rate", 0.0),
                   # transfer-engine gauges at sample time, not deltas
                   "kv_spill_inflight_pages": float(
                       self.pool.inflight_pages()),
                   "kv_spill_inflight_bytes": float(
                       self.pool.inflight_bytes())}
            for n in names[1:]:
                out[n] = cur[n] - state[n]
            state.update(t=t1, **cur)
            return out

        return metrics

    def run_until_done(self, *, max_rounds: int = 100000) -> Dict:
        trace: List[int] = []
        self._running = True
        try:
            for g in self.groups:
                self._spawn_group(g)
            self.sched.run_until_done(max_rounds=max_rounds,
                                      concurrency_trace=trace,
                                      metrics_fn=self._round_metrics(),
                                      round_hook=self._stall_hook)
        finally:
            self._running = False
            if self.pool is not None:
                self.pool.drain()       # no transfer outlives the run
        out = {"concurrency": trace, "counters": self.counters.snapshot(),
               "relayouts": list(self.relayouts),
               "decisions": [dataclasses.asdict(x)
                             for x in self.controller.decisions]}
        if self.pool is not None:
            out["kv"] = self.kv_stats()
        return out

    # -- measured model steps (compiled HLO, not structural) -----------------
    def _layer_trips(self) -> Tuple[int, ...]:
        """Trip counts a per-layer scan can compile to for this model —
        the probe ``hlo_analysis.model_steps_per_call`` matches while
        loops against."""
        if self.cfg.block_pattern:
            from repro.models.params import hybrid_structure
            _, n_groups, _ = hybrid_structure(self.cfg)
            return (n_groups,)
        if self.cfg.family == "encdec":
            return (self.cfg.dec_layers,)
        return (self.cfg.n_layers,)

    def measured_model_steps(self, kind: str = "chunk", *,
                             C: Optional[int] = None, B: int = 1) -> float:
        """Sequential model steps ONE call of a compiled paged step runs,
        counted from its optimized HLO (while-loop trip counts) instead of
        assumed from the path's construction — the PR-5 leftover that
        makes accepted-tokens-per-model-step a measured number.  ``kind``
        is "decode" (single-token step), "chunk" (the mixed chunk step) or
        "spec" (the all-logits verify step); ``C`` the chunk width to
        compile at (defaults to the engine's own width for the kind)."""
        from repro.launch.hlo_analysis import model_steps_per_call
        if self.pool is None:
            raise ValueError("measured_model_steps needs the paged path")
        P = self.pool.pages_per_stream
        sd = jax.ShapeDtypeStruct
        storage = jax.tree.map(lambda a: sd(a.shape, a.dtype),
                               self.pool.storage)
        tables = sd((B, P), jnp.int32)
        slots = sd((B,), jnp.int32)
        pos = sd((B,), jnp.int32)
        if kind == "decode":
            fn = self._paged_decode
            args = (self.params, storage, tables, slots,
                    sd((B, 1), jnp.int32), pos)
        elif kind in ("chunk", "spec"):
            if kind == "spec" and not self._spec:
                raise ValueError("spec step not built: spec_decode is off")
            fn = self._paged_chunk if kind == "chunk" else self._paged_spec
            W = C or (self._chunk if kind == "chunk" else self._spec_w)
            args = (self.params, storage, tables, slots,
                    sd((B, W), jnp.int32), pos, sd((B,), jnp.int32))
        else:
            raise ValueError(f"unknown step kind {kind!r}")
        hlo = fn.lower(*args).compile().as_text()
        return model_steps_per_call(hlo, self._layer_trips())

    def warm_steps(self, chunks: Tuple[int, ...] = (4, 8, 16)) -> int:
        """Trace + compile every paged step the serve loop can dispatch —
        decode, the chunk widths in ``chunks`` (clamped to the engine's
        chunk size) and, when speculative decoding is on, the verify and
        reapply widths — at every pow-2 batch bucket up to ``max_batch``.

        Each warm call drives the REAL dispatch partials (the AOT
        ``lower().compile()`` path keeps its own cache, so it cannot
        pre-pay dispatch-side compiles) with all-null rows: tables point
        at reserved block 0 and state slot 0, whose contents are written
        but never read, and chunk rows carry n_tokens=0 so live caches
        pass through bit-unchanged.  Serving after a warm-up therefore
        never stalls a request on an XLA backend compile.  Returns the
        number of step calls made."""
        if self.pool is None:
            return 0
        P = self.pool.pages_per_stream
        calls = 0
        widths = sorted({min(c, self._chunk) for c in chunks}
                        | ({self._spec_w} if self._spec else set()))
        B = 1
        while B <= self.ecfg.max_batch:
            tables = jnp.asarray(np.zeros((B, P), np.int32))
            slots = jnp.asarray(np.zeros((B,), np.int32))
            pos = jnp.asarray(np.zeros((B,), np.int32))
            _, self.pool.storage = self._paged_decode(
                self.params, self.pool.storage, tables, slots,
                jnp.asarray(np.zeros((B, 1), np.int32)), pos)
            calls += 1
            for W in widths:
                toks = jnp.asarray(np.zeros((B, W), np.int32))
                n = jnp.asarray(np.zeros((B,), np.int32))
                _, self.pool.storage = self._paged_chunk(
                    self.params, self.pool.storage, tables, slots,
                    toks, pos, n)
                calls += 1
                if self._spec and W == self._spec_w:
                    _, self.pool.storage = self._paged_spec(
                        self.params, self.pool.storage, tables, slots,
                        toks, pos, n)
                    calls += 1
            # the host-side argmax/mask group that follows every step
            dec.next_token_ids(jnp.zeros((B, self.cfg.vocab)),
                               jnp.asarray(np.zeros((B,), np.int32)))
            B *= 2
        # the pow-2 page-copy buckets behind migrations and prefix forks:
        # null-block self-copies are bit-exact no-ops
        b = 1
        while b <= P:
            self.pool.storage = dec.copy_pool_entries(
                self.pool.storage, self.pool.spec, [0] * b, [0] * b)
            calls += 1
            b *= 2
        return calls

    # -- latency / pool stats --------------------------------------------------
    def kv_stats(self) -> Dict[str, float]:
        """KV-pool health: occupancy, park (alloc-failure) rate, lazy
        growth / mid-decode park / eviction counts, blocks migrated per
        relayout."""
        if self.pool is None:
            return {}
        s = self.pool.stats()
        # the pool defaults this to one page; the engine knows the real
        # configured chunk size (prefill_chunk may span several pages) and
        # the compiled path (parallel adds the fused score transient)
        s["prefill_chunk_bytes"] = prefill_chunk_bytes(
            self.cfg, self._chunk, self.ecfg.max_len,
            mode=self._prefill_mode, kernel=self._chunk_kernel)
        s["prefill_score_bytes"] = (
            prefill_chunk_score_bytes(self.cfg, self._chunk,
                                      self.ecfg.max_len,
                                      kernel=self._chunk_kernel)
            if self._prefill_mode == "parallel" else 0.0)
        s["chunk_kernel"] = self._chunk_kernel
        s["mixed_tick_decode_rows_saved"] = self.counters.totals.get(
            "mixed_tick_decode_rows_saved", 0.0)
        s["decode_gather_rows_saved"] = self.counters.totals.get(
            "decode_gather_rows_saved", 0.0)
        s["decode_masked_query_rows"] = self.counters.totals.get(
            "decode_masked_query_rows", 0.0)
        s["prefill_model_steps"] = self.counters.totals.get(
            "prefill_model_steps", 0.0)
        s["chunk_ticks"] = self.counters.totals.get("chunk_ticks", 0.0)
        s["evictions"] = self.counters.totals.get("kv_evictions", 0.0)
        s["recompute_tokens"] = self.counters.totals.get(
            "recompute_tokens", 0.0)
        s["blocks_per_relayout"] = [r.get("blocks_migrated", 0.0)
                                    for r in self.relayouts]
        # speculative decoding: acceptance totals, forward participations
        # (the denominators of accepted-tokens-per-model-step) and the
        # costmodel-priced bytes optimism wasted
        s["spec_decode"] = self.ecfg.spec_decode if self._spec else "off"
        tot = self.counters.totals
        for k in ("spec_ticks", "spec_verify_forwards",
                  "spec_reapply_forwards", "spec_row_forwards",
                  "spec_row_reapplies", "spec_tokens_drafted",
                  "spec_tokens_accepted", "spec_rollbacks",
                  "spec_full_rejects", "spec_accept_rate",
                  "decode_forwards", "decode_row_forwards",
                  "decode_committed_tokens"):
            s[k] = tot.get(k, 0.0)
        rejected = s["spec_tokens_drafted"] - s["spec_tokens_accepted"]
        s["spec_rejected_bytes"] = spec_rejected_bytes(self.cfg,
                                                       int(rejected))
        s["spec_rollback_bytes"] = spec_rollback_bytes(
            self.cfg, int(tot.get("kv_spec_ckpt_pages", 0.0)),
            int(tot.get("kv_spec_rollback_pages", 0.0)),
            self.pool.block_tokens,
            ckpts=int(tot.get("kv_spec_ckpts", 0.0)),
            rollbacks=int(s["spec_rollbacks"]))
        # SLO-tiered admission: bypass volume, the priced safety floors
        # those grants preserved for the blocked heads they jumped, the
        # head-blocking exposure, the proactive-vs-watchdog spill split,
        # and per-class admission counts + latency percentiles (computed
        # from the very samples ``stats``/the benchmark report)
        s["bypass_grants"] = tot.get("kv_bypass_grants", 0.0)
        s["bypass_floor_pages"] = tot.get("kv_bypass_floor_pages", 0.0)
        s["bypass_floor_bytes"] = kv_bypass_floor_bytes(
            self.cfg, int(s["bypass_floor_pages"]), self.pool.block_tokens)
        s["head_wait_ticks"] = tot.get("kv_head_wait_ticks", 0.0)
        s["proactive_spills"] = tot.get("kv_proactive_spills", 0.0)
        s["watchdog_spills"] = tot.get("kv_watchdog_spills", 0.0)
        # async swap tier: overlap efficiency (decode ticks that ran with
        # a transfer on the wire, rounds each landed spill hid behind,
        # fences that actually waited) + the costmodel-priced time the
        # host link spent moving spill payloads
        s["async_swap"] = bool(self._async)
        s["ticks_while_inflight"] = tot.get("kv_ticks_while_inflight", 0.0)
        spills = max(1.0, s.get("spills", 0.0))
        s["overlap_rounds_per_spill"] = (
            tot.get("kv_spill_overlap_rounds", 0.0) / spills)
        s["d2h_seconds"] = kv_transfer_seconds(
            tot.get("kv_d2h_bytes", 0.0), self.topology.hw.d2h_bw)
        s["h2d_seconds"] = kv_transfer_seconds(
            tot.get("kv_h2d_bytes", 0.0), self.topology.hw.h2d_bw)
        s["class_submits"] = {c: tot.get(f"kv_class_submits/{c}", 0.0)
                              for c in self.ecfg.slo_classes}
        s["class_admits"] = {c: tot.get(f"kv_class_admits/{c}", 0.0)
                             for c in self.ecfg.slo_classes}
        s["class_bypass_grants"] = {c: tot.get(f"kv_class_bypass/{c}", 0.0)
                                    for c in self.ecfg.slo_classes}
        s["per_class"] = self.class_stats(self.submitted,
                                          self.ecfg.slo_classes)
        return s

    @staticmethod
    def class_stats(reqs: List[Request],
                    slo_classes: Optional[Dict[str, ClassSLO]] = None
                    ) -> Dict[str, Dict[str, float]]:
        """Per-SLO-class latency stats over the SAME samples :meth:`stats`
        reports — one ``stats`` dict per class, annotated with the class's
        TTFT/TPOT targets and whether the p99s met them.  Classes with no
        finished requests report ``{"n": 0}`` plus their targets."""
        classes = sorted({r.cls for r in reqs} | set(slo_classes or ()))
        out: Dict[str, Dict[str, float]] = {}
        for c in classes:
            sub = ServeEngine.stats([r for r in reqs if r.cls == c])
            if not sub:
                sub = {"n": 0}
            if slo_classes and c in slo_classes:
                slo = slo_classes[c]
                sub["ttft_target"] = slo.ttft_target
                sub["tpot_target"] = slo.tpot_target
                if sub["n"]:
                    sub["ttft_slo_met"] = bool(
                        sub["ttft_p99"] <= slo.ttft_target)
                    sub["tpot_slo_met"] = bool(
                        sub["tpot_p99"] <= slo.tpot_target)
            out[c] = sub
        return out

    @staticmethod
    def stats(reqs: List[Request]) -> Dict[str, float]:
        done = [r for r in reqs if r.done]
        if not done:
            return {}
        ttft = np.array([r.t_first - r.arrived for r in done])
        total = np.array([r.t_done - r.arrived for r in done])
        tpot = np.array([(r.t_done - r.t_first)
                         / max(1, len(r.generated) - 1) for r in done])
        return {
            "n": len(done),
            "ttft_mean": float(ttft.mean()),
            "ttft_p50": float(np.percentile(ttft, 50)),
            "ttft_p99": float(np.percentile(ttft, 99)),
            "tpot_p50": float(np.percentile(tpot, 50)),
            "tpot_p99": float(np.percentile(tpot, 99)),
            "latency_mean": float(total.mean()),
            "latency_p95": float(np.percentile(total, 95)),
            "tokens": sum(len(r.generated) for r in done),
        }
