"""Serving engine: continuous batching over chiplet-group replicas, running
on the unified GlobalScheduler substrate.

ARCAS mapping (the paper's runtime, applied to inference):
  * every request is a COROUTINE (prefill step, then one yield per decode
    step) scheduled by the §4.4 task runtime that the GlobalScheduler owns;
  * the fleet is partitioned into replica groups by the current Layout
    (spread_rate): compact layout = many small replicas (low latency, small
    aggregate KV "cache" per replica = LocalCache), spread = few big
    replicas (large aggregate KV = DistributedCache);
  * waiting requests are WORK-STOLEN between replica queues in §4.4 tier
    order (own queue, then same-pod, then cross-pod) via TieredQueues;
  * the adaptive controller runs LIVE: Algorithm 1 is evaluated at
    yield-point boundaries by GlobalScheduler.tick, and on a spread-rate
    change the engine's RelayoutHandler merges/splits replica groups
    MID-RUN — in-flight KV-cache slots, positions and next tokens migrate
    to the new groups and queued requests are redistributed, so adaptive
    and non-adaptive runs generate identical tokens.

On this CPU container the model compute is real (tiny configs) while the
replica groups are logical queues over the same device — the scheduling,
batching, stealing, controller and migration behavior is exactly the code a
TPU deployment would run host-side.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.controller import ControllerConfig, Decision
from repro.core.layout import Layout
from repro.core.scheduler import GlobalScheduler, TieredQueues
from repro.core.topology import ChipletTopology
from repro.models import decode as dec
from repro.models.params import init_params
from repro.launch.steps import make_prefill, make_serve_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new: int
    arrived: float = 0.0
    group: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    migrations: int = 0                 # relayouts survived while in flight

    @property
    def done(self) -> bool:
        return self.t_done is not None

    def kv_bytes(self) -> float:
        """Rough KV footprint moved when this request changes groups."""
        return float((len(self.prompt) + len(self.generated)) * 2)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8                 # decode slots per replica group
    max_len: int = 256
    adaptive: bool = True
    controller: ControllerConfig = dataclasses.field(
        default_factory=lambda: ControllerConfig(
            scheduler_timer=8, threshold=4.0, min_dwell=2))


@dataclasses.dataclass
class _InFlight:
    """A mid-generation stream harvested from a retired replica group."""
    req: Request
    cache: Any                          # per-stream cache slice (axis-1 cut)
    pos: int
    token: int


class _Group:
    """One replica group: decode slots + its own cache pool.

    ``queue`` is the group's deque inside the engine's TieredQueues;
    ``resume`` holds migrated in-flight streams awaiting a free slot;
    ``retired`` marks groups dissolved by a relayout (their coroutine exits
    at its next yield point).
    """

    def __init__(self, gid: int, pod: int, cfg: ModelConfig, params,
                 ecfg: EngineConfig, queue):
        self.gid = gid
        self.pod = pod
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.queue = queue
        self.resume: List[_InFlight] = []
        self.retired = False
        self.slots: List[Optional[Request]] = [None] * ecfg.max_batch
        self.cache = dec.init_cache(cfg, ecfg.max_batch, ecfg.max_len)
        self.pos = jnp.zeros((ecfg.max_batch,), jnp.int32)
        self.tokens = jnp.zeros((ecfg.max_batch, 1), jnp.int32)
        self.steps = 0

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def busy(self) -> bool:
        return (bool(self.queue) or bool(self.resume)
                or any(s is not None for s in self.slots))

    def kv_pressure(self) -> float:
        used = sum(1 for s in self.slots if s is not None)
        return used / max(1, len(self.slots))


class ServeEngine:
    def __init__(self, cfg: ModelConfig, topology: ChipletTopology,
                 ecfg: EngineConfig = EngineConfig(), *, seed: int = 0,
                 spread_rate: int = 1):
        self.cfg = cfg
        self.topology = topology
        self.ecfg = ecfg
        self.sched = GlobalScheduler(
            topology, ecfg.controller, spread_rate=spread_rate,
            control_enabled=ecfg.adaptive)
        # compat aliases: the scheduler owns these now
        self.counters = self.sched.counters
        self.controller = self.sched.controller
        self.runtime = self.sched.tasks
        self.params = init_params(cfg, jax.random.PRNGKey(seed))
        self._prefill = jax.jit(make_prefill(cfg, max_len=ecfg.max_len))
        self._decode = jax.jit(make_serve_step(cfg))
        self._rid = itertools.count()
        self._clock = time.monotonic
        self._running = False
        self.relayouts: List[Dict] = []
        self._build_groups()
        self.sched.register_relayout(self._relayout)

    # ------------------------------------------------------------------
    def _build_groups(self):
        lay = self.sched.layout()
        rpp = lay.replicas_per_pod
        pods = [g // rpp for g in range(lay.replicas)]
        self.queues = TieredQueues(pods, counters=self.counters,
                                   bytes_fn=Request.kv_bytes)
        self.groups = [_Group(g, pods[g], self.cfg, self.params, self.ecfg,
                              self.queues.queue(g))
                       for g in range(lay.replicas)]

    def submit(self, prompt: np.ndarray, max_new: int) -> Request:
        req = Request(next(self._rid), np.asarray(prompt, np.int32), max_new,
                      arrived=self._clock())
        # route to least-pressured group (global scheduler placement)
        g = min(self.groups, key=lambda gr: (gr.kv_pressure(), len(gr.queue)))
        req.group = g.gid
        self.queues.push(g.gid, req)
        return req

    # -- live relayout: merge/split replica groups mid-run -------------------
    def _relayout(self, new_layout: Layout, decision: Decision):
        old_groups = self.groups
        if new_layout.replicas == len(old_groups):
            return
        # harvest in-flight streams (KV slot + position + next token) and
        # queued requests from the dissolving groups
        inflight: List[_InFlight] = []
        queued: List[Request] = []
        for g in old_groups:
            g.retired = True
            for slot, req in enumerate(g.slots):
                if req is None:
                    continue
                one = jax.tree.map(lambda p: p[:, slot], g.cache)
                inflight.append(_InFlight(req, one, int(g.pos[slot]),
                                          int(g.tokens[slot, 0])))
                g.slots[slot] = None
                # counted per slot-harvest so each migration pairs with
                # exactly one restore; resume-backlog streams below were
                # already counted on their first hop
                self.counters.add("kv_slots_migrated", 1)
                self.counters.add("migration_bytes", req.kv_bytes())
            inflight.extend(g.resume)
            g.resume = []
            while g.queue:
                queued.append(g.queue.popleft())
        self._build_groups()
        n = len(self.groups)
        for i, fl in enumerate(inflight):
            tgt = self.groups[i % n]
            fl.req.group = tgt.gid
            fl.req.migrations += 1
            tgt.resume.append(fl)
        for i, req in enumerate(queued):
            tgt = self.groups[i % n]
            req.group = tgt.gid
            self.queues.push(tgt.gid, req)
        self.relayouts.append({
            "step": decision.step, "old_groups": len(old_groups),
            "new_groups": n, "moved_slots": len(inflight),
            "requeued": len(queued), "reason": decision.reason})
        if self._running:
            for g in self.groups:
                self._spawn_group(g)

    # -- one engine tick: admit + prefill + batched decode --------------------
    def _install(self, g: _Group, slot: int, fl: _InFlight):
        """Write a migrated stream's KV state into a free slot."""
        g.cache = jax.tree.map(lambda pool, one: pool.at[:, slot].set(one),
                               g.cache, fl.cache)
        g.slots[slot] = fl.req
        g.pos = g.pos.at[slot].set(fl.pos)
        g.tokens = g.tokens.at[slot, 0].set(fl.token)
        self.counters.add("kv_slots_restored", 1)

    def _admit(self, g: _Group):
        for slot in g.free_slots():
            if g.resume:                       # migrated streams first
                self._install(g, slot, g.resume.pop(0))
                continue
            req, tier = self.queues.pop(g.gid)
            if req is None:
                break
            if tier != "local":
                req.group = g.gid
            prompt = req.prompt[None, :]
            logits, cache1 = self._prefill(self.params, {"tokens": prompt})
            nxt = int(jnp.argmax(logits[0]))
            req.generated.append(nxt)
            req.t_first = self._clock()
            # copy the single-stream cache into the group slot
            g.cache = jax.tree.map(
                lambda pool, one: pool.at[:, slot].set(one[:, 0]),
                g.cache, cache1)
            g.slots[slot] = req
            g.pos = g.pos.at[slot].set(len(req.prompt))
            g.tokens = g.tokens.at[slot, 0].set(nxt)
            self.counters.add("prefills", 1)

    def _decode_tick(self, g: _Group):
        if not any(s is not None for s in g.slots):
            return
        logits, g.cache = self._decode(self.params, g.cache, g.tokens, g.pos)
        nxt = jnp.argmax(logits, axis=-1)
        g.pos = g.pos + jnp.where(
            jnp.array([s is not None for s in g.slots]), 1, 0)
        g.tokens = nxt[:, None].astype(jnp.int32)
        g.steps += 1
        now = self._clock()
        for i, req in enumerate(g.slots):
            if req is None:
                continue
            req.generated.append(int(nxt[i]))
            if len(req.generated) >= req.max_new:
                req.t_done = now
                g.slots[i] = None
        self.counters.add("decode_steps", 1)
        self.counters.add("decode_tokens",
                          sum(1 for s in g.slots if s is not None))

    # -- engine task (coroutine per group, scheduled by the task runtime) ----
    def _group_task(self, g: _Group):
        while not g.retired:
            others_waiting = (self.queues.pending()
                              or any(o.resume for o in self.groups))
            if not g.busy() and not others_waiting:
                return
            self._admit(g)
            self._decode_tick(g)
            yield   # yield point: profiler + Algorithm 1 + possible relayout

    def _spawn_group(self, g: _Group):
        self.sched.spawn(self._group_task(g), group=g.gid,
                         name=f"group{g.gid}")

    def run_until_done(self, *, max_rounds: int = 100000) -> Dict:
        trace: List[int] = []
        self._running = True
        try:
            for g in self.groups:
                self._spawn_group(g)
            self.sched.run_until_done(max_rounds=max_rounds,
                                      concurrency_trace=trace)
        finally:
            self._running = False
        return {"concurrency": trace, "counters": self.counters.snapshot(),
                "relayouts": list(self.relayouts),
                "decisions": [dataclasses.asdict(x)
                              for x in self.controller.decisions]}

    # -- latency stats ---------------------------------------------------------
    @staticmethod
    def stats(reqs: List[Request]) -> Dict[str, float]:
        done = [r for r in reqs if r.done]
        if not done:
            return {}
        ttft = [r.t_first - r.arrived for r in done]
        total = [r.t_done - r.arrived for r in done]
        return {
            "n": len(done),
            "ttft_mean": float(np.mean(ttft)),
            "latency_mean": float(np.mean(total)),
            "latency_p95": float(np.percentile(total, 95)),
            "tokens": sum(len(r.generated) for r in done),
        }
