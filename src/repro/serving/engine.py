"""Serving engine: continuous batching over chiplet-group replicas.

ARCAS mapping (the paper's runtime, applied to inference):
  * every request is a COROUTINE (prefill step, then one yield per decode
    step) scheduled by the §4.4 task runtime;
  * the fleet is partitioned into replica groups by the current Layout
    (spread_rate): compact layout = many small replicas (low latency, small
    aggregate KV "cache" per replica = LocalCache), spread = few big
    replicas (large aggregate KV = DistributedCache);
  * waiting requests are WORK-STOLEN between group queues, same-pod first;
  * the adaptive controller watches the remote-counter analogue
    (cross-group steals + KV-pressure overflow) and re-spreads/compacts.

On this CPU container the model compute is real (tiny configs) while the
replica groups are logical queues over the same device — the scheduling,
batching, stealing and controller behavior is exactly the code a TPU
deployment would run host-side.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.controller import AdaptiveController, ControllerConfig
from repro.core.counters import PerfCounters
from repro.core.layout import Layout
from repro.core.tasks import TaskRuntime
from repro.core.topology import ChipletTopology
from repro.models import decode as dec
from repro.models.params import init_params
from repro.launch.steps import make_prefill, make_serve_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new: int
    arrived: float = 0.0
    group: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    t_first: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.t_done is not None


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8                 # decode slots per replica group
    max_len: int = 256
    adaptive: bool = True
    controller: ControllerConfig = dataclasses.field(
        default_factory=lambda: ControllerConfig(
            scheduler_timer=8, threshold=4.0, min_dwell=2))


class _Group:
    """One replica group: decode slots + its own cache pool."""

    def __init__(self, gid: int, cfg: ModelConfig, params, ecfg: EngineConfig):
        self.gid = gid
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.slots: List[Optional[Request]] = [None] * ecfg.max_batch
        self.cache = dec.init_cache(cfg, ecfg.max_batch, ecfg.max_len)
        self.pos = jnp.zeros((ecfg.max_batch,), jnp.int32)
        self.tokens = jnp.zeros((ecfg.max_batch, 1), jnp.int32)
        self.queue: List[Request] = []
        self.steps = 0

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def kv_pressure(self) -> float:
        used = sum(1 for s in self.slots if s is not None)
        return used / max(1, len(self.slots))


class ServeEngine:
    def __init__(self, cfg: ModelConfig, topology: ChipletTopology,
                 ecfg: EngineConfig = EngineConfig(), *, seed: int = 0,
                 spread_rate: int = 1):
        self.cfg = cfg
        self.topology = topology
        self.ecfg = ecfg
        self.counters = PerfCounters()
        self.runtime = TaskRuntime(
            n_pods=topology.n_pods, groups_per_pod=topology.groups_per_pod,
            counters=self.counters)
        self.controller = AdaptiveController(
            topology, ecfg.controller, spread_rate=spread_rate)
        self.params = init_params(cfg, jax.random.PRNGKey(seed))
        self._prefill = jax.jit(make_prefill(cfg, max_len=ecfg.max_len))
        self._decode = jax.jit(make_serve_step(cfg))
        self._rid = itertools.count()
        self._clock = time.monotonic
        self._build_groups()
        self.trace: List[Dict] = []

    # ------------------------------------------------------------------
    def _n_groups(self) -> int:
        return self.controller.layout().replicas

    def _build_groups(self):
        self.groups = [_Group(g, self.cfg, self.params, self.ecfg)
                       for g in range(self._n_groups())]

    def submit(self, prompt: np.ndarray, max_new: int) -> Request:
        req = Request(next(self._rid), np.asarray(prompt, np.int32), max_new,
                      arrived=self._clock())
        # route to least-pressured group (global scheduler placement)
        g = min(self.groups, key=lambda gr: (gr.kv_pressure(), len(gr.queue)))
        req.group = g.gid
        g.queue.append(req)
        return req

    # -- chiplet-first stealing of queued requests ---------------------------
    def _steal_for(self, g: "_Group") -> Optional[Request]:
        donors = sorted((o for o in self.groups
                         if o is not g and o.queue),
                        key=lambda o: -len(o.queue))
        if not donors:
            return None
        victim = donors[0]
        req = victim.queue.pop(0)
        self.counters.add("remote_bytes",
                          float(len(req.prompt) * 2))   # moved KV bytes
        self.counters.add("steals_group", 1)
        req.group = g.gid
        return req

    # -- one engine tick: admit + prefill + batched decode --------------------
    def _admit(self, g: "_Group"):
        for slot in g.free_slots():
            req = g.queue.pop(0) if g.queue else self._steal_for(g)
            if req is None:
                break
            prompt = req.prompt[None, :]
            logits, cache1 = self._prefill(self.params, {"tokens": prompt})
            nxt = int(jnp.argmax(logits[0]))
            req.generated.append(nxt)
            req.t_first = self._clock()
            # copy single-stream cache into the group slot
            def write(pool, one):
                return jax.tree.map(
                    lambda p, o: p.at[:, slot].set(o[:, 0]) if p.ndim >= 2
                    else p, pool, one)
            g.cache = jax.tree.map(
                lambda pool, one: pool.at[:, slot].set(one[:, 0]),
                g.cache, cache1)
            g.slots[slot] = req
            g.pos = g.pos.at[slot].set(len(req.prompt))
            g.tokens = g.tokens.at[slot, 0].set(nxt)
            self.counters.add("prefills", 1)

    def _decode_tick(self, g: "_Group"):
        if not any(s is not None for s in g.slots):
            return
        logits, g.cache = self._decode(self.params, g.cache, g.tokens, g.pos)
        nxt = jnp.argmax(logits, axis=-1)
        g.pos = g.pos + jnp.where(
            jnp.array([s is not None for s in g.slots]), 1, 0)
        g.tokens = nxt[:, None].astype(jnp.int32)
        g.steps += 1
        now = self._clock()
        for i, req in enumerate(g.slots):
            if req is None:
                continue
            req.generated.append(int(nxt[i]))
            if len(req.generated) >= req.max_new:
                req.t_done = now
                g.slots[i] = None
        self.counters.add("decode_steps", 1)
        self.counters.add("decode_tokens",
                          sum(1 for s in g.slots if s is not None))

    # -- engine task (coroutine per group, scheduled by the task runtime) ----
    def _group_task(self, g: "_Group"):
        while True:
            busy = bool(g.queue) or any(s is not None for s in g.slots)
            others_waiting = any(o.queue for o in self.groups)
            if not busy and not others_waiting:
                return
            self._admit(g)
            self._decode_tick(g)
            yield   # yield point: profiler + possible migration

    def run_until_done(self, *, max_rounds: int = 100000) -> Dict:
        trace: List[int] = []
        for g in self.groups:
            self.runtime.spawn(self._group_task(g), group=g.gid,
                               name=f"group{g.gid}")
        self.runtime.run(concurrency_trace=trace, max_rounds=max_rounds)
        if self.ecfg.adaptive:
            d = self.controller.maybe_reschedule(self.counters)
            if d is not None:
                self.trace.append(dataclasses.asdict(d))
        return {"concurrency": trace, "counters": self.counters.snapshot(),
                "decisions": [dataclasses.asdict(x)
                              for x in self.controller.decisions]}

    # -- latency stats ---------------------------------------------------------
    @staticmethod
    def stats(reqs: List[Request]) -> Dict[str, float]:
        done = [r for r in reqs if r.done]
        if not done:
            return {}
        ttft = [r.t_first - r.arrived for r in done]
        total = [r.t_done - r.arrived for r in done]
        return {
            "n": len(done),
            "ttft_mean": float(np.mean(ttft)),
            "latency_mean": float(np.mean(total)),
            "latency_p95": float(np.percentile(total, 95)),
            "tokens": sum(len(r.generated) for r in done),
        }
