"""Paged KV-block allocator partitioned per chiplet-group memory domain —
the second ARCAS pillar (hardware-aware memory allocation) applied to
serving.

The pool owns ONE physical storage pytree (``models/decode.py`` block-pool
layout) whose block-id space is partitioned into per-chiplet-group *domains*
(the NUMA-bind analogue: on TPU each domain's id range lives in that group's
HBM).  A request holds a :class:`KVTable` — its ring pages as physical block
ids inside exactly one domain, plus one per-stream state slot — instead of a
slot in a monolithic per-replica cache array:

  * admission reserves ``ceil(min(prompt+max_new, W) / block_tokens)`` pages
    (short requests reserve less than the ring width, which is where the
    capacity win over the slot monolith comes from);
  * reservation failure is the serving back-pressure signal: the admission
    coroutine parks on the pool's :class:`~repro.core.tasks.WaitQueue` via
    ``yield BLOCK`` and is woken by ``free``;
  * a relayout re-points block *tables* at the new owner replica of their
    domain; only streams rebalanced onto a replica that does not own their
    domain copy their **used** pages (``migrate``) — never whole cache
    slices;
  * under memory pressure a parked stream's used pages can be SPILLED to a
    host-side swap tier (``spill``/``restore``): its device pages are freed
    to the wait-line head and the table turns host-resident — migrating for
    free (pure domain re-point) — until it is re-granted pages and the
    stream resumes mid-decode, instead of the restart-from-scratch eviction
    that recomputes every token.

Block id 0 and state slot 0 are reserved null entries: empty decode slots
and the unreserved tail of short tables point at them, so gather/scatter
shapes stay static (jit-stable) while null contents are never read (ring
positions past a stream's last token are masked by ``cache_positions``).

PREFIX SHARING (copy-on-write pages).  Physical pages are REFCOUNTED: a
page frees only when its last table releases it, so several tables may
point at the same block.  Completed prompt pages are published into a
prefix index keyed by the running token-hash chain (one blake2b digest per
page, chained, seeded per model config — the prefill chunk size equals the
page size, so chunk boundaries ARE page boundaries); a new request whose
prompt hash-matches a resident chain attaches those pages at admission
(``match_prefix`` + ``reserve(prefix_blocks=)``) and starts prefill at the
match boundary — skipping both the allocation and the fused forward for
every shared page.  Writes never touch a shared page: the engine calls
``fork_pages``/``cow_fork`` before any tick whose ring writes would land
on a refcount>1 page (the divergence write at a full-ring match and
ordinary ring wrap-around are the two triggers), and ``note_writes``
drops the index entry of any registered page about to be overwritten —
pages older than the ring width W are dead and can never be matched.
For models with carried state (rgLRU/SSD), the state slot is position-
dependent: registration snapshots the donor's slot into a checkpoint slot
at the page boundary and a match FORKS that checkpoint into the new
stream's slot (``copy_pool_entries`` state copy).  Freed pages with a
live index entry stay CACHED: they sit on the free list (reclaimable —
allocation prefers uncached blocks and invalidates on reuse) but keep
their entry, so a later identical prompt still hits after its donor
finished.

Budgets are expressed in *bytes* via ``costmodel.kv_cache_bytes`` and
converted to blocks/state slots, so a pool can be sized to exactly the HBM
footprint the old slot-monolith allocator used — or to a fraction of
``ChipletTopology.group_hbm()`` on a real fleet.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import math
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, \
    Tuple

import numpy as np

import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.costmodel import kv_cache_bytes, kv_dedup_bytes, \
    kv_spill_bytes
from repro.core.counters import PerfCounters
from repro.launch.steps import make_prefix_fork, make_rows_gather, \
    make_rows_scatter, make_spill_gather, make_spill_gather_async, \
    make_spill_scatter
from repro.models import decode as dec
from repro.serving.swap import InFlightSpill, SwapTier


def kv_bytes_exact(cfg: ModelConfig, n_tokens: int, max_len: int) -> float:
    """Exact decode-state bytes of ONE stream holding ``n_tokens`` of
    context (ring-capped at ``max_len``) — replaces the old
    ``(prompt+generated)*2`` napkin estimate in migration accounting."""
    s = ShapeConfig("kv", "decode", max(1, min(n_tokens, max_len)), 1)
    return kv_cache_bytes(cfg, s, 1)


@dataclasses.dataclass
class SpillEntry:
    """Host-side payload of a spilled table: its used pages (+ state) as
    numpy leaves in ``jax.tree`` order, waiting in the swap tier until the
    stream is re-granted device pages."""
    pages: int                      # used pages held host-side
    data: List[Any]                 # host leaves from extract_pool_entries
    had_state: bool = False         # a state slot rides in ``data``
    tier: Optional[Any] = None      # SwapTier handle backing ``data`` views
    staged: Optional[List[Any]] = None  # H2D-prefetched device leaves


@dataclasses.dataclass
class PrefixEntry:
    """One published prompt page in the prefix index: the resident block
    holding tokens ``[o*bt, (o+1)*bt)`` of some prompt whose hash chain
    ends at this entry's key, plus — for models with carried rgLRU/SSD
    state — an optional checkpoint slot holding the donor's state at the
    page boundary (0 = none; the entry then cannot END a match for a
    state model, but can still sit in the middle of a longer chain).

    The entry does NOT hold a refcount of its own: while some table holds
    the block it is pinned anyway, and once the last holder releases it
    the block goes back on the free list *still carrying the entry*
    (cached) until allocation reuses it."""
    block: int
    domain: int
    state_ckpt: int = 0


@dataclasses.dataclass
class KVTable:
    """One stream's view into the pool: ring pages + state slot, resident
    in a single chiplet-group domain.

    Reservations are ELASTIC: a lazily-admitted table starts with the pages
    of its first prefill chunk and :meth:`KVBlockPool.grow` appends pages
    in ring order as the stream's ``pos`` crosses page boundaries, up to
    ``cap_pages`` (the eager reservation the PR-2 allocator made up
    front).  ``cap_pages == 0`` means fully reserved at admission.

    A table can be SPILLED to the host swap tier under memory pressure
    (:meth:`KVBlockPool.spill`): its used pages live in ``spill`` and it
    holds no device resources until :meth:`KVBlockPool.restore` — while
    host-resident it migrates between domains by re-pointing ``domain``
    alone (zero device copies)."""
    domain: int
    blocks: List[int]               # reserved physical pages, ring order
    state_slot: int                 # 0 = none (model has no state leaves)
    used_pages: int = 0             # pages actually written (prefill/decode)
    cap_pages: int = 0              # lazy mode: max pages this stream needs
    spill: Optional[SpillEntry] = None   # host payload while spilled
    inflight: bool = False          # D2H spill issued, fence pending: the
    #                                 table still HOLDS its pages (regrant
    #                                 happens only at the fence) and the
    #                                 stream must not advance
    last_touch: int = 0             # pool touch-clock at last decode tick
    #                                 (§4.5 access counter: watermark
    #                                 victims are the coldest-parked)

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    @property
    def spilled(self) -> bool:
        return self.spill is not None


class KVBlockPool:
    """Block pool over ``n_domains`` chiplet-group memory domains.

    Pure host-side bookkeeping (free lists, tables, counters) plus the
    device-side storage pytree; gather/scatter/copy of actual pages happens
    through ``models/decode.py`` view helpers.
    """

    def __init__(self, cfg: ModelConfig, *, n_domains: int, max_len: int,
                 blocks_per_domain: int, states_per_domain: int,
                 block_tokens: int = 16,
                 counters: Optional[PerfCounters] = None,
                 retention: str = "access",
                 topology=None):
        if retention not in ("access", "blind"):
            raise ValueError(f"unknown retention policy {retention!r}")
        self.cfg = cfg
        self.max_len = max_len
        self.n_domains = n_domains
        # cached-tier retention: "access" reclaims the coldest published
        # page by last-hit recency; "blind" keeps the old free-list order
        self.retention = retention
        self._touch_clock = 0
        self._touch: Dict[int, int] = {}
        self.counters = counters or PerfCounters()
        self.spec = dec.cache_view_specs(cfg, max_len)
        W = self.spec.width
        if W:
            bt = self._aligned_block_tokens(W, block_tokens)
            self.block_tokens = bt
            self.pages_per_stream = W // bt
        else:                       # pure-state model (SSM): no ring pages
            self.block_tokens = 1
            self.pages_per_stream = 0
        self.has_state = any(s.token_axis is None for s in self.spec.leaves)
        self.blocks_per_domain = blocks_per_domain if W else 0
        self.states_per_domain = states_per_domain if self.has_state else 0
        # id 0 is the shared null entry; domain d owns
        # [1 + d*per_domain, 1 + (d+1)*per_domain)
        self._free_blocks: List[List[int]] = [
            list(range(1 + d * self.blocks_per_domain,
                       1 + (d + 1) * self.blocks_per_domain))
            for d in range(n_domains)]
        self._free_states: List[List[int]] = [
            list(range(1 + d * self.states_per_domain,
                       1 + (d + 1) * self.states_per_domain))
            for d in range(n_domains)]
        self.storage = dec.init_block_pool(
            cfg, self.spec,
            n_blocks=1 + n_domains * self.blocks_per_domain,
            n_states=1 + n_domains * self.states_per_domain,
            block_tokens=self.block_tokens, max_len=max_len)
        # physical placement: commit the pool onto its chiplet group's
        # devices (domain block-id ranges are contiguous so an even shard
        # of the block axis IS the per-group split; one device — CPU CI —
        # commits everything there).  ``topology`` is advisory: the split
        # follows the visible jax devices either way.
        self.topology = topology
        self.storage = dec.place_block_pool(self.storage, self.spec)
        self._on_free: List[Callable[[], None]] = []
        # swap tier: D2H/H2D copies of a table's used pages + state slot,
        # landing in preallocated (pinned where the platform has it) host
        # buffers sized to one full pool of pages
        self._spill_gather = make_spill_gather(self.spec)
        self._spill_scatter = make_spill_scatter(self.spec)
        self._spill_gather_async = make_spill_gather_async(self.spec)
        self._rows_gather = make_rows_gather(self.spec)
        self._rows_scatter = make_rows_scatter(self.spec)
        # The tier is sized to a multiple of the device pool: under
        # oversubscription the AGGREGATE spilled footprint exceeds device
        # capacity (that is the point of the second tier), so a 1x sizing
        # overflows as soon as two pool-sized victims are parked at once.
        self.swap = SwapTier(
            self.storage, self.spec,
            capacity_pages=4 * n_domains * self.blocks_per_domain,
            capacity_states=4 * n_domains * self.states_per_domain)
        # async transfer engine: issued-but-unfenced D2H spills.  An entry
        # here means its table still holds pages (fence-before-regrant)
        # and its stream is frozen at its park cursor.
        self._inflight: List[InFlightSpill] = []
        self._poll_clock = 0
        # prefix sharing: per-block refcounts (a block frees only when the
        # last table releases it), the hash-chain index of published
        # prompt pages, and its block -> key reverse map for invalidation.
        # The chain seed folds the model config in, so two pools with
        # different families/shapes can never alias a digest.
        self._ref: Dict[int, int] = {}
        self._prefix: Dict[bytes, PrefixEntry] = {}
        self._entry_of_block: Dict[int, bytes] = {}
        self._prefix_seed = hashlib.blake2b(
            repr((cfg, self.block_tokens, max_len)).encode(),
            digest_size=16).digest()
        self._prefix_fork = make_prefix_fork(self.spec)
        self.spilled_tables = 0         # tables currently host-resident
        self.spilled_bytes = 0.0        # swap-tier footprint right now
        self.peak_spilled_bytes = 0.0
        self.peak_used_blocks = 0
        # per-domain high-water marks (blocks in use), so chunked prefill /
        # lazy growth can report byte-accurate per-domain footprints
        self.peak_used_per_domain = [0] * n_domains
        self.active_tables = 0          # reservations currently live
        self.peak_active_tables = 0     # max concurrently admitted streams
        # proactive-spill occupancy watermarks (None = disabled): a domain
        # crossing HIGH is a candidate for ONE early spill; it re-arms only
        # after dipping back under LOW (hysteresis against spill thrash)
        self.wm_high: Optional[float] = None
        self.wm_low: Optional[float] = None
        self._wm_hot = [False] * n_domains

    # -- sizing helpers ----------------------------------------------------
    @staticmethod
    def _aligned_block_tokens(W: int, block_tokens: int) -> int:
        """Largest page size <= block_tokens dividing the ring width."""
        bt = min(block_tokens, W)
        while W % bt:
            bt -= 1
        return bt

    @classmethod
    def blocks_for_streams(cls, cfg: ModelConfig, max_len: int,
                           streams: int, block_tokens: int = 16) -> Dict:
        """Per-domain budget equivalent to a slot monolith of ``streams``
        full-length streams: the byte-for-byte capacity the old allocator
        reserved per replica group."""
        spec = dec.cache_view_specs(cfg, max_len)
        W = spec.width
        # same page-size alignment as __init__, so the budget always covers
        # exactly `streams` full tables regardless of W % block_tokens
        pages = W // cls._aligned_block_tokens(W, block_tokens) if W else 0
        return {"blocks_per_domain": streams * pages,
                "states_per_domain": streams}

    def bytes_per_block(self) -> float:
        """Token-page bytes from the cost model (state slots excluded)."""
        if not self.pages_per_stream:
            return 0.0
        per2 = kv_bytes_exact(self.cfg, 2 * self.block_tokens, self.max_len)
        per1 = kv_bytes_exact(self.cfg, self.block_tokens, self.max_len)
        return max(per2 - per1, 0.0)

    def domain_bytes(self) -> float:
        state_b = (kv_bytes_exact(self.cfg, 1, self.max_len)
                   - self.bytes_per_block() / max(1, self.block_tokens))
        return (self.blocks_per_domain * self.bytes_per_block()
                + self.states_per_domain * max(state_b, 0.0))

    # -- accounting --------------------------------------------------------
    def pages_needed(self, total_tokens: int) -> int:
        if not self.pages_per_stream:
            return 0
        W = self.spec.width
        bt = self.block_tokens
        return min(self.pages_per_stream,
                   max(1, math.ceil(min(total_tokens, W) / bt)))

    def free_blocks(self, domain: int) -> int:
        return len(self._free_blocks[domain])

    def free_states(self, domain: int) -> int:
        return len(self._free_states[domain])

    def used_blocks(self) -> int:
        total = self.n_domains * self.blocks_per_domain
        return total - sum(len(f) for f in self._free_blocks)

    def used_blocks_in(self, domain: int) -> int:
        return self.blocks_per_domain - len(self._free_blocks[domain])

    def total_blocks(self) -> int:
        return self.n_domains * self.blocks_per_domain

    def occupancy(self) -> float:
        """Fraction of pool capacity in use (blocks, or state slots for
        pure-state models)."""
        total = self.total_blocks()
        if not total:
            total = self.n_domains * self.states_per_domain
            used = total - sum(len(f) for f in self._free_states)
            return used / total if total else 0.0
        return self.used_blocks() / total

    def domain_occupancy(self, domain: int) -> float:
        """Fraction of ONE domain's capacity in use (blocks, or state
        slots for pure-state models) — the watermark ladder's input."""
        if self.blocks_per_domain:
            return self.used_blocks_in(domain) / self.blocks_per_domain
        if self.states_per_domain:
            return ((self.states_per_domain
                     - len(self._free_states[domain]))
                    / self.states_per_domain)
        return 0.0

    # -- proactive-spill watermarks ----------------------------------------
    def set_watermarks(self, high: Optional[float],
                       low: Optional[float] = None):
        """Arm per-domain occupancy watermarks for PROACTIVE spill (the
        ladder rung between park and the stall watchdog): a domain whose
        occupancy reaches ``high`` reports itself via
        :meth:`watermark_domains` so the engine can spill one cold parked
        stream BEFORE the allocation stall closes into a deadlock; the
        domain then stays latched (no further proactive spills) until it
        dips back to ``low`` — the hysteresis that prevents spill/restore
        thrash when freed pages are regranted immediately.  ``high=None``
        disables (the watchdog-only default)."""
        if high is None:
            self.wm_high = self.wm_low = None
            self._wm_hot = [False] * self.n_domains
            return
        low = high if low is None else low
        if not (0.0 < low <= high <= 1.0):
            raise ValueError(
                f"watermarks need 0 < low <= high <= 1, got "
                f"high={high} low={low}")
        self.wm_high, self.wm_low = float(high), float(low)
        self._wm_hot = [False] * self.n_domains

    def watermark_domains(self) -> List[int]:
        """Domains whose occupancy has crossed the HIGH mark since last
        dipping under LOW — each is a candidate for one proactive spill.
        Crossing does NOT latch by itself: the caller confirms an actual
        spill with :meth:`watermark_arm` (a hot domain with nothing left
        to spill must stay eligible for the next round)."""
        out: List[int] = []
        if self.wm_high is None:
            return out
        for d in range(self.n_domains):
            occ = self.domain_occupancy(d)
            if self._wm_hot[d]:
                if occ <= self.wm_low:
                    self._wm_hot[d] = False
            elif occ >= self.wm_high:
                out.append(d)
        return out

    def watermark_arm(self, domain: int):
        """Latch a domain after a proactive spill: no further proactive
        spills there until occupancy dips under the LOW mark."""
        self._wm_hot[domain] = True

    def can_reserve(self, domain: int, pages: int) -> bool:
        if not self.state_available(domain):
            return False
        return len(self._free_blocks[domain]) >= pages

    def state_available(self, domain: int) -> bool:
        """A state slot can be produced in ``domain``: one is free, or a
        prefix checkpoint is resident there to reclaim (cached state beats
        a starving admission)."""
        if not self.has_state:
            return True
        if self._free_states[domain]:
            return True
        return any(e.state_ckpt
                   and self._state_domain(e.state_ckpt) == domain
                   for e in self._prefix.values())

    # -- refcounted physical blocks ----------------------------------------
    def ref(self, block: int) -> int:
        return self._ref.get(block, 0)

    def _block_domain(self, b: int) -> int:
        return (b - 1) // self.blocks_per_domain

    def _state_domain(self, s: int) -> int:
        return (s - 1) // self.states_per_domain

    def _touch_block(self, b: int):
        """Record an access to a published page: ``match_prefix`` hits,
        publication, and cached re-attachment all count.  Drives the
        "access" retention order — colder pages are reclaimed first, per
        the measured-access-behavior tiering argument of "Workload
        Behavior Driven Memory Subsystem Design" (PAPERS.md)."""
        self._touch_clock += 1
        self._touch[b] = self._touch_clock

    def _pop_block(self, domain: int) -> int:
        """Take a free block at refcount 1, preferring blocks that do NOT
        cache a published prefix page; when only cached blocks remain,
        retention="access" reclaims the COLDEST one (least recently hit /
        published) and "blind" the oldest-freed, dropping its index
        entry either way."""
        free = self._free_blocks[domain]
        idx = len(free) - 1
        if self._entry_of_block:
            uncached = next((i for i in range(len(free) - 1, -1, -1)
                             if free[i] not in self._entry_of_block), None)
            if uncached is not None:
                idx = uncached
            else:
                if self.retention == "access":
                    idx = min(range(len(free)),
                              key=lambda i: self._touch.get(free[i], 0))
                else:
                    idx = 0
                self.counters.add("kv_cached_reclaims", 1)
        b = free.pop(idx)
        if b in self._entry_of_block:
            self._invalidate_block(b)
        self._touch.pop(b, None)    # content is about to be replaced
        self._ref[b] = 1
        return b

    def _release_block(self, b: int):
        """Drop one reference; the block returns to ITS OWN domain's free
        list only when the last holder lets go — a live index entry rides
        along (cached) until :meth:`_pop_block` reuses the block."""
        r = self._ref.get(b, 0) - 1
        assert r >= 0, f"refcount underflow on block {b}"
        if r > 0:
            self._ref[b] = r
        else:
            self._ref.pop(b, None)
            self._free_blocks[self._block_domain(b)].append(b)

    def _invalidate_block(self, b: int):
        """Drop the prefix entry published on ``b`` (its content is about
        to change, or the cached block is being reallocated), returning
        the entry's state checkpoint to the free list."""
        key = self._entry_of_block.pop(b, None)
        if key is None:
            return
        e = self._prefix.pop(key)
        if e.state_ckpt:
            self._free_states[self._state_domain(e.state_ckpt)].append(
                e.state_ckpt)

    def _take_state(self, domain: int) -> int:
        """Pop a free state slot, reclaiming the oldest-registered prefix
        checkpoint in the domain when none is free (admissions must never
        starve behind cached state)."""
        if self._free_states[domain]:
            return self._free_states[domain].pop()
        for e in self._prefix.values():
            if e.state_ckpt and self._state_domain(e.state_ckpt) == domain:
                s, e.state_ckpt = e.state_ckpt, 0
                self.counters.add("kv_ckpt_reclaims", 1)
                return s
        raise IndexError(f"domain {domain}: no state slots available")

    # -- prefix index: hash-chain keys, match, publish, invalidate ---------
    def prefix_keys(self, tokens) -> List[bytes]:
        """Running hash chain over the prompt's full pages: ``keys[o]``
        digests tokens ``[0, (o+1)*bt)``, so equal keys mean equal whole
        prefixes (not just equal pages).  Capped at the ring width — a
        page past W can never survive to be shared."""
        if not self.pages_per_stream:
            return []
        bt = self.block_tokens
        arr = np.ascontiguousarray(np.asarray(tokens, np.int64))
        n = min(arr.shape[0] // bt, self.pages_per_stream)
        keys, h = [], self._prefix_seed
        for o in range(n):
            h = hashlib.blake2b(h + arr[o * bt:(o + 1) * bt].tobytes(),
                                digest_size=16).digest()
            keys.append(h)
        return keys

    def match_prefix(self, domain: int, keys: Sequence[bytes], *,
                     prompt_len: int) -> Tuple[List[int], int]:
        """Longest run of resident prefix pages in ``domain`` matching the
        prompt's hash chain -> (their blocks, the donor state checkpoint
        at the match boundary; 0 for stateless models).

        The match is capped at ``(prompt_len-1)//bt`` pages so at least
        the prompt's final token is always recomputed — its logits seed
        generation.  For models with carried state the match ends at the
        deepest entry that HAS a checkpoint (the state at the boundary is
        as necessary as the pages)."""
        if not keys or not self.pages_per_stream:
            return [], 0
        limit = min(len(keys), (max(prompt_len, 1) - 1) // self.block_tokens,
                    self.pages_per_stream)
        blocks: List[int] = []
        best, ckpt = 0, 0
        for o in range(limit):
            e = self._prefix.get(keys[o])
            if e is None or e.domain != domain:
                break
            blocks.append(e.block)
            if not self.has_state:
                best = o + 1
            elif e.state_ckpt:
                best, ckpt = o + 1, e.state_ckpt
        for b in blocks[:best]:
            self._touch_block(b)
        return blocks[:best], ckpt

    def register_prefix(self, table: KVTable, keys: Sequence[bytes],
                        pos0: int, new_pos: int, prompt_len: int):
        """Publish the prompt pages a prefill tick just completed (the
        stream advanced ``pos0 -> new_pos``) into the prefix index.

        A page is published only while its content is exactly prompt
        tokens ``[o*bt, (o+1)*bt)``: fully inside the prompt, ordinal
        below the ring width, and not already re-written by ring wrap
        within this same tick.  For models with carried state a
        checkpoint of the stream's slot is snapped when the tick ended
        exactly at the page boundary and a free slot exists (purely
        opportunistic — checkpoints never compete with admissions)."""
        if not self.pages_per_stream or not keys:
            return
        bt, W = self.block_tokens, self.spec.width
        for o in range(max(pos0 // bt, 0),
                       min(new_pos, prompt_len) // bt):
            if o >= min(len(keys), self.pages_per_stream,
                        len(table.blocks)):
                break
            if new_pos > o * bt + W:
                continue        # wrapped inside this very tick: dead page
            key = keys[o]
            b = table.blocks[o]
            if key in self._prefix or b in self._entry_of_block:
                continue        # already published (or block backs a key)
            ckpt = 0
            if self.has_state and new_pos == (o + 1) * bt \
                    and self._free_states[table.domain]:
                ckpt = self._free_states[table.domain].pop()
                self.storage = self._prefix_fork(
                    self.storage, [], [],
                    src_state=table.state_slot, dst_state=ckpt)
            self._prefix[key] = PrefixEntry(b, table.domain, ckpt)
            self._entry_of_block[b] = key
            self._touch_block(b)
            self.counters.add("kv_prefix_pages_published", 1)

    def _write_pages(self, pos: int, n: int, n_blocks: int) -> List[int]:
        """Ring-page indices the next ``n``-token write at ``pos``
        touches (a chunk wider than the ring touches every page)."""
        W = self.spec.width
        bt = self.block_tokens
        if n >= W:
            return list(range(min(self.pages_per_stream, n_blocks)))
        pages = sorted({(p % W) // bt for p in range(pos, pos + n)})
        return [j for j in pages if j < n_blocks]

    def fork_pages(self, table: KVTable, pos: int, n: int) -> List[int]:
        """Ring pages the next tick writes that are SHARED (refcount > 1)
        and must be copied first — the CoW trigger set.  Covers both the
        divergence write of a full-ring match (which wraps straight into
        shared page 0) and ordinary ring wrap-around during decode."""
        if not self.pages_per_stream or table.spill is not None \
                or not table.blocks:
            return []
        return [j for j in self._write_pages(pos, n, len(table.blocks))
                if self._ref.get(table.blocks[j], 0) > 1]

    def cow_fork(self, table: KVTable, page: int) -> bool:
        """Copy-on-write: give ``table`` a private copy of shared ring
        page ``page`` before it is written.  False (no block taken) when
        the table's domain has no free block — the caller parks the
        stream, exactly like a failed grow."""
        old = table.blocks[page]
        if self._ref.get(old, 0) <= 1:
            return True
        if not self._free_blocks[table.domain]:
            self.counters.add("kv_grow_failures", 1)
            return False
        new = self._pop_block(table.domain)
        self.storage = self._prefix_fork(self.storage, [old], [new])
        self._release_block(old)    # other holders keep the original
        table.blocks[page] = new
        self.counters.add("kv_blocks_allocated", 1)
        self.counters.add("kv_cow_forks", 1)
        self._note_usage(table.domain)
        return True

    def note_writes(self, table: KVTable, pos: int, n: int):
        """A write makes a page's content diverge from what the prefix
        index published: drop the entry of every page the next tick
        writes.  (CoW-forked pages already moved the table onto a private
        block, so the OLD block's entry — whose content is untouched —
        survives for its other holders and future matches.)"""
        if not self._entry_of_block or not self.pages_per_stream \
                or table.spill is not None:
            return
        for j in self._write_pages(pos, n, len(table.blocks)):
            b = table.blocks[j]
            if b in self._entry_of_block:
                self._invalidate_block(b)

    # -- shared-page gauges ------------------------------------------------
    def shared_pages(self) -> int:
        """Physical pages currently held by more than one table."""
        return sum(1 for r in self._ref.values() if r > 1)

    def shared_extra_refs(self) -> int:
        """Table->page references served WITHOUT a resident copy of their
        own — the dedup win in pages."""
        return sum(r - 1 for r in self._ref.values() if r > 1)

    def cached_pages(self) -> int:
        """Free-list blocks still carrying a published prefix page."""
        return sum(1 for b in self._entry_of_block
                   if self._ref.get(b, 0) == 0)

    def shared_bytes(self) -> float:
        """Bytes NOT resident thanks to page dedup (costmodel-priced)."""
        return kv_dedup_bytes(self.cfg, self.shared_extra_refs(),
                              self.block_tokens)

    # -- alloc / free ------------------------------------------------------
    def reserve(self, domain: int, total_tokens: int, *,
                first_tokens: Optional[int] = None,
                headroom: int = 0,
                min_free: int = 0,
                count_failure: bool = True,
                prefix_blocks: Optional[Sequence[int]] = None,
                prefix_state: int = 0) -> Optional[KVTable]:
        """Reserve a table for a stream of ``total_tokens`` context in
        ``domain``; None when the domain cannot serve it right now.

        With ``first_tokens`` the reservation is ELASTIC: only the pages
        covering the first ``first_tokens`` positions are taken now (one
        prefill chunk) and the table records ``cap_pages`` — the eager
        footprint — as its growth bound for :meth:`grow`.  The budget check
        still uses the CAP: a stream whose full ring cannot fit a domain
        can never complete, lazily or not.

        ``headroom`` is the admission-control knob for elastic mode: grant
        only when the domain would keep ``headroom`` free blocks AFTER the
        reservation, so lazy growth of already-admitted streams is less
        likely to close the incremental-allocation deadlock in the first
        place.  ``headroom=0`` is exactly the unguarded grant; the knob is
        clamped so an EMPTY domain can always admit (a too-large k must
        throttle, never livelock).

        ``min_free`` is a HARD free-block floor the grant must leave
        behind — the size-aware bypass safety bound: a request granted
        past a blocked line head passes the head's provable restore/grow
        need here, so the grant can never consume a page the head is
        waiting for.  Unlike ``headroom`` it is NEVER clamped: a floor
        that cannot be kept refuses the grant outright.

        ``count_failure=False`` lets a caller probing several domains count
        one logical failure instead of one per domain.

        ``prefix_blocks`` (from :meth:`match_prefix`, same domain) are
        ALREADY-RESIDENT pages the new table attaches by reference — the
        budget charges only the unshared tail, so a fully-cached prompt
        admits even at high occupancy (its pages are free by definition).
        ``prefix_state`` forks the donor's carried-state checkpoint at the
        match boundary into the fresh slot."""
        cap = self.pages_needed(total_tokens)
        if cap > max(self.blocks_per_domain, 0) and cap:
            raise ValueError(
                f"request needs {cap} pages but a domain only has "
                f"{self.blocks_per_domain}: raise the pool budget")
        if self.has_state and self.states_per_domain == 0:
            raise ValueError("pool has no state slots but model needs them")
        shared = list(prefix_blocks or ())
        pages = cap if first_tokens is None else \
            min(cap, self.pages_needed(first_tokens))
        # prefix hits are already resident: charge only the unshared tail.
        # CACHED hits (refcount 0) do sit on the free list though — the
        # attach below pulls them off, so they count against it here or
        # _pop_block would run the list dry.
        pages = max(pages - len(shared), 0)
        cached = sum(1 for b in shared if self._ref.get(b, 0) == 0)
        headroom = min(headroom if pages else 0,
                       max(0, self.blocks_per_domain - pages))
        if not self.can_reserve(domain, pages + cached + headroom
                                + max(min_free, 0)):
            if count_failure:
                self.counters.add("kv_alloc_failures", 1)
            return None
        for b in shared:        # attach AFTER the capacity check
            r = self._ref.get(b, 0)
            if r == 0:          # cached page comes back off the free list
                self._free_blocks[domain].remove(b)
                self.counters.add("kv_cached_page_hits", 1)
            self._ref[b] = r + 1
            self._touch_block(b)
        blocks = shared + [self._pop_block(domain) for _ in range(pages)]
        slot = self._take_state(domain) if self.has_state else 0
        if self.has_state:
            # the slot is position-dependent: fork the donor's rgLRU/SSD
            # checkpoint at the match boundary — or, with no donor, SCRUB
            # the slot (a recycled slot still holds its dead stream's
            # final state, which the recurrence would read at token 0)
            self.storage = self._prefix_fork(
                self.storage, [], [],
                src_state=prefix_state, dst_state=slot)
        self.counters.add("kv_blocks_allocated", pages)
        self.counters.add("kv_reservations", 1)
        if shared:
            self.counters.add("kv_prefix_hits", 1)
            self.counters.add("kv_prefix_pages", len(shared))
            self.counters.add("prefill_tokens_skipped",
                              len(shared) * self.block_tokens)
        self.active_tables += 1
        self.peak_active_tables = max(self.peak_active_tables,
                                      self.active_tables)
        self._note_usage(domain)
        table = KVTable(domain, blocks, slot,
                        cap_pages=cap if first_tokens is not None else 0)
        table.used_pages = len(shared)   # matched pages are valid content
        return table

    def grow(self, table: KVTable, n_pages: int) -> bool:
        """Append ``n_pages`` ring pages to an elastic table (same domain),
        committing bytes only when the stream's ``pos`` actually crosses a
        page boundary.  False (no side effects) when the domain lacks free
        pages — the caller parks its stream mid-decode and retries on the
        pool's free callback."""
        if n_pages <= 0:
            return True
        if table.inflight:
            # the victim is frozen until its D2H lands (fence-before-
            # regrant): growing would advance a stream whose landed
            # payload no longer matches.  Parks like a full domain; the
            # landing's free callback retries.
            self.counters.add("kv_grow_failures", 1)
            return False
        cap = table.cap_pages or self.pages_per_stream
        if len(table.blocks) + n_pages > cap:
            raise ValueError(
                f"growing past the table's cap ({len(table.blocks)}+"
                f"{n_pages} > {cap} pages)")
        if len(self._free_blocks[table.domain]) < n_pages:
            self.counters.add("kv_grow_failures", 1)
            return False
        table.blocks.extend(self._pop_block(table.domain)
                            for _ in range(n_pages))
        self.counters.add("kv_blocks_allocated", n_pages)
        self.counters.add("kv_lazy_grows", 1)
        self._note_usage(table.domain)
        return True

    def free(self, table: KVTable):
        """Return a table's pages + state slot and fire the free callbacks
        (which unblock BLOCK-parked admission coroutines).  Freeing a
        SPILLED table drops its host payload too (the restart-eviction
        fallback path).  Shared pages only DECREF — they stay resident for
        their other holders (and for future prefix matches: a page whose
        last holder lets go parks on the free list still cached).  A table
        with a spill IN FLIGHT is fenced first — its payload lands, then
        drops — so the transfer engine never references a dead table."""
        if table.inflight:
            self.spill_fence(table, count_wait=False)
        for b in sorted(table.blocks):
            self._release_block(b)
        if self.has_state and table.state_slot:
            self._free_states[table.domain].append(table.state_slot)
        self.counters.add("kv_blocks_freed", len(table.blocks))
        if table.spill is not None:
            self._drop_spill(table)
        table.blocks = []
        table.state_slot = 0
        table.used_pages = 0
        self.active_tables -= 1
        self._gauges()
        for cb in self._on_free:
            cb()

    def on_free(self, cb: Callable[[], None]):
        self._on_free.append(cb)

    # -- swap tier: spill parked pages to host instead of discarding them --
    #
    # The transfer engine splits a spill into ISSUE / POLL / FENCE phases:
    # ``spill_issue`` dispatches the device-side gather and returns
    # immediately (JAX async dispatch — the D2H copy drains while decode
    # ticks keep running); ``spill_poll`` lands every transfer whose
    # arrays report ready; ``spill_fence`` blocks until specific (or all)
    # transfers land.  The victim's pages are RE-GRANTED ONLY AT THE
    # LANDING (fence-before-regrant): until then the table keeps its
    # blocks and the free callbacks stay silent, so nobody can allocate a
    # page whose bytes are still in motion.  The victim stream itself is
    # frozen at its park cursor — the gather snapshotted issue-time bytes
    # (functional storage update), so advancing the stream before the
    # fence would decode against pages the landed payload no longer
    # matches.  The synchronous ``spill`` is issue + immediate fence:
    # byte-identical semantics to the PR-4 path for every existing caller.
    def touch_table(self, table: KVTable):
        """§4.5 access counter: stamp a table at every decode tick it ran
        in.  Parked tables stop accumulating, so the coldest-parked victim
        (min ``last_touch``) is the one whose pages have gone longest
        without an access."""
        self._touch_clock += 1
        table.last_touch = self._touch_clock

    def spill_issue(self, table: KVTable) -> int:
        """Issue the D2H copy of a table's used pages (+ state slot) and
        return immediately — the transfer drains behind the token loop.
        Returns the pages now in flight (0 = already spilled or already
        in flight)."""
        if table.spill is not None or table.inflight:
            return 0
        used = min(table.used_pages, len(table.blocks))
        had_state = bool(self.has_state and table.state_slot)
        leaves = self._spill_gather_async(
            self.storage, table.blocks[:used],
            state_slot=table.state_slot if had_state else None)
        rec = InFlightSpill(
            table=table, pages=used, had_state=had_state, leaves=leaves,
            issue_clock=self._poll_clock,
            n_bytes=kv_spill_bytes(self.cfg, used, self.block_tokens,
                                   had_state))
        table.inflight = True
        self._inflight.append(rec)
        self.counters.add("kv_spill_issues", 1)
        self.counters.add("kv_d2h_bytes", rec.n_bytes)
        self._gauges()
        return used

    def _land_spill(self, rec: InFlightSpill):
        """Completion half of a spill (the old synchronous tail): copy the
        landed payload into the swap tier, free the victim's device pages
        to the wait-line head, and fire the free callbacks."""
        table = rec.table
        host = [np.asarray(leaf) if leaf is not None else None
                for leaf in rec.leaves]
        handle = self.swap.store(host, rec.pages, rec.had_state)
        table.spill = SpillEntry(pages=rec.pages, data=handle.views,
                                 had_state=rec.had_state, tier=handle)
        # the payload COPIED every used page (shared ones included), so
        # releasing shared pages here is safe: the other holders keep the
        # device copy, this table restores a private one
        for b in sorted(table.blocks):
            self._release_block(b)
        if rec.had_state:
            self._free_states[table.domain].append(table.state_slot)
        self.counters.add("kv_blocks_freed", len(table.blocks))
        self.counters.add("kv_spills", 1)
        self.counters.add("kv_spilled_pages", rec.pages)
        self.counters.add("kv_spill_overlap_rounds",
                          self._poll_clock - rec.issue_clock)
        table.blocks = []
        table.state_slot = 0
        table.inflight = False
        self.spilled_tables += 1
        self.spilled_bytes += rec.n_bytes
        self.peak_spilled_bytes = max(self.peak_spilled_bytes,
                                      self.spilled_bytes)
        self._gauges()
        for cb in self._on_free:
            cb()

    def spill_poll(self) -> int:
        """Land every in-flight spill whose device arrays report ready;
        never blocks.  One call per engine round is the poll phase of the
        pressure ladder (and the overlap clock: rounds between issue and
        landing are decode rounds the transfer hid behind)."""
        self._poll_clock += 1
        done = [r for r in self._inflight if r.ready()]
        for r in done:
            self._inflight.remove(r)
            self._land_spill(r)
        return len(done)

    def spill_fence(self, table: Optional[KVTable] = None, *,
                    count_wait: bool = True) -> int:
        """Block until the given table's transfer (or ALL transfers with
        ``table=None``) lands — the drain path for shutdown, relayout,
        eviction and the watchdog's stalled rung.  ``count_wait`` records
        a ``kv_fence_waits`` event when the fence actually had to wait
        (synchronous ``spill`` fences unconditionally and doesn't count)."""
        recs = [r for r in self._inflight
                if table is None or r.table is table]
        waited = any(not r.ready() for r in recs)
        for r in recs:
            for leaf in r.leaves:
                if leaf is not None:
                    leaf.block_until_ready()
            self._inflight.remove(r)
            self._land_spill(r)
        if recs and waited and count_wait:
            self.counters.add("kv_fence_waits", 1)
        return len(recs)

    def drain(self) -> int:
        """Fence every outstanding transfer (shutdown/relayout path)."""
        return self.spill_fence(None, count_wait=False)

    def inflight_tables(self) -> int:
        return len(self._inflight)

    def inflight_pages(self) -> int:
        return sum(r.pages for r in self._inflight)

    def inflight_bytes(self) -> float:
        return sum(r.n_bytes for r in self._inflight)

    def inflight_domains(self) -> set:
        """Domains with a spill in flight — their frees are already in
        the pipe, so the watermark rung must not double-spill them."""
        return {r.table.domain for r in self._inflight}

    def spill(self, table: KVTable) -> int:
        """Move a table's USED pages (+ state slot) into the host swap
        tier and free its device resources to the wait-line head.

        The table stays live (``active_tables`` unchanged — the stream is
        still admitted, just host-resident) but holds zero device blocks
        until :meth:`restore`; its saved decode cursor makes the
        spill/restore cycle invisible in the token output.  Returns the
        number of pages spilled (0 = already spilled, nothing to do).
        This is the SYNCHRONOUS path: issue + immediate fence."""
        if table.inflight:
            self.spill_fence(table, count_wait=False)
            return 0
        used = self.spill_issue(table)
        if table.inflight:
            self.spill_fence(table, count_wait=False)
        return used

    def restore(self, table: KVTable) -> bool:
        """Re-grant device pages to a spilled table in its CURRENT domain
        (re-point ``migrate`` first to restore somewhere else) and scatter
        the host payload back; False (no side effects) when the domain
        lacks pages or a state slot.  The stream resumes mid-decode at its
        saved cursor — zero recomputed tokens."""
        if table.inflight:
            self.spill_fence(table, count_wait=False)
        sp = table.spill
        if sp is None:
            return True
        d = table.domain
        if (len(self._free_blocks[d]) < sp.pages
                or not self.state_available(d)):
            self.counters.add("kv_restore_failures", 1)
            return False
        blocks = [self._pop_block(d) for _ in range(sp.pages)]
        slot = self._take_state(d) if self.has_state else 0
        data = sp.staged if sp.staged is not None else sp.data
        self.storage = self._spill_scatter(
            self.storage, blocks, data,
            state_slot=slot if sp.had_state else None)
        table.blocks = blocks
        table.state_slot = slot
        table.used_pages = sp.pages
        n_bytes = kv_spill_bytes(self.cfg, sp.pages, self.block_tokens,
                                 sp.had_state)
        self._drop_spill(table)
        self.counters.add("kv_blocks_allocated", sp.pages)
        self.counters.add("kv_restores", 1)
        self.counters.add("kv_h2d_bytes", n_bytes)
        self._note_usage(d)
        return True

    def restore_into(self, table: KVTable, domain: int,
                     grow_by: int = 0) -> bool:
        """One ATOMIC restore-sweep leg: land a spilled table in
        ``domain`` with ``grow_by`` extra ring pages, reserving pages +
        grow + state slot all-or-nothing.  False leaves ZERO side effects
        — no re-point, no popped page, no consumed state checkpoint — so
        a failed leg of the engine's domain sweep can never strand the
        stream half-restored or leak a slot (the PR-10 bugfix: the old
        sweep re-pointed, restored, then grew in separate steps and a
        late grow failure left a restored-but-unready stream holding a
        reclaimed checkpoint)."""
        if table.inflight:
            self.spill_fence(table, count_wait=False)
        sp = table.spill
        if sp is None:
            return False
        cap = table.cap_pages or self.pages_per_stream
        grow_by = min(max(0, grow_by), max(0, cap - sp.pages))
        if (len(self._free_blocks[domain]) < sp.pages + grow_by
                or not self.state_available(domain)):
            return False
        if not self.migrate(table, domain):     # spilled: pure re-point
            return False
        blocks = [self._pop_block(domain)
                  for _ in range(sp.pages + grow_by)]
        slot = self._take_state(domain) if self.has_state else 0
        data = sp.staged if sp.staged is not None else sp.data
        self.storage = self._spill_scatter(
            self.storage, blocks[:sp.pages], data,
            state_slot=slot if sp.had_state else None)
        table.blocks = blocks
        table.state_slot = slot
        table.used_pages = sp.pages
        n_bytes = kv_spill_bytes(self.cfg, sp.pages, self.block_tokens,
                                 sp.had_state)
        self._drop_spill(table)
        self.counters.add("kv_blocks_allocated", sp.pages + grow_by)
        self.counters.add("kv_restores", 1)
        self.counters.add("kv_h2d_bytes", n_bytes)
        if grow_by:
            self.counters.add("kv_lazy_grows", 1)
        self._note_usage(domain)
        return True

    def restore_prefetch(self, table: KVTable) -> bool:
        """Stage a spilled table's payload H2D ahead of the re-grant —
        called while the stream waits in line, so the upload drains
        behind the ticks ahead of it and the eventual restore scatter
        reads device-resident arrays.  Idempotent; False when there is
        nothing to stage."""
        sp = table.spill
        if sp is None or sp.staged is not None:
            return False
        sp.staged = [jnp.asarray(h) if h is not None else None
                     for h in sp.data]
        self.counters.add("kv_restore_prefetches", 1)
        return True

    def _drop_spill(self, table: KVTable):
        sp = table.spill
        self.spilled_tables -= 1
        self.spilled_bytes -= kv_spill_bytes(self.cfg, sp.pages,
                                             self.block_tokens, sp.had_state)
        self.swap.release(sp.tier)
        table.spill = None

    # -- speculative checkpoint / rollback ---------------------------------
    def checkpoint_pages(self, table: KVTable, pos: int, n: int,
                         pages: bool = True) -> dict:
        """Host snapshot of the carried-state slot — and, with ``pages``,
        exactly the pages — an ``n``-token write at ``pos`` will touch,
        taken BEFORE a speculative verify forward commits optimistically.
        Engines serving pure-attention models skip the page gather
        entirely (``pages=False``): a rejected draft suffix only leaves
        dead bytes at cursor-masked positions there, whereas a recurrent
        state slot genuinely needs its pre-verify value back.

        Must run AFTER the tick's growth/CoW phase: the touched blocks are
        then private (refcount 1), so a later :meth:`rollback_pages` can
        restore them in place without disturbing any sharer.  Reuses the
        swap tier's gather, so the snapshot is the same host-leaf layout a
        spill produces."""
        idx = self._write_pages(pos, n, len(table.blocks)) if pages else []
        blocks = [table.blocks[j] for j in idx]
        slot = table.state_slot if (self.has_state and table.state_slot) \
            else None
        data = self._spill_gather(self.storage, blocks, state_slot=slot)
        self.counters.add("kv_spec_ckpts", 1)
        self.counters.add("kv_spec_ckpt_pages", len(blocks))
        return {"blocks": blocks, "data": data, "slot": slot}

    def rollback_pages(self, table: KVTable, ckpt: dict):
        """Restore a :meth:`checkpoint_pages` snapshot: every snapshotted
        page and the state slot return to their pre-verify bytes, erasing
        the rejected draft suffix's effect.  The accepted prefix is then
        re-applied by a masked chunk forward — NOT by trusting the
        optimistic write — so the restored state advances by exactly the
        accepted tokens."""
        self.storage = self._spill_scatter(self.storage, ckpt["blocks"],
                                           ckpt["data"],
                                           state_slot=ckpt["slot"])
        self.counters.add("kv_spec_rollback_pages", len(ckpt["blocks"]))

    def checkpoint_rows(self, rows: Sequence[Tuple[KVTable, int, int, bool]]
                        ) -> List[dict]:
        """Batched :meth:`checkpoint_pages` for ALL drafted rows of a
        speculative tick: ONE device gather over the concatenation of
        every row's write-touched pages + every hybrid row's state slot,
        instead of a host round-trip per row (the PR-8 leftover).  The
        snapshot stays DEVICE-resident — most checkpoints are dropped
        untouched when the draft fully accepts, so no host copy ever
        happens for them; :meth:`rollback_rows` scatters the rejected
        rows' slices straight back.  ``rows`` entries are
        ``(table, pos, n, pages)`` with the same per-row contract."""
        metas = []
        all_blocks: List[int] = []
        slots: List[int] = []
        for table, pos, n, pages in rows:
            idx = self._write_pages(pos, n, len(table.blocks)) if pages \
                else []
            blocks = [table.blocks[j] for j in idx]
            slot = table.state_slot if (self.has_state and table.state_slot) \
                else None
            metas.append((blocks, slot, len(all_blocks),
                          len(slots) if slot is not None else -1))
            all_blocks.extend(blocks)
            if slot is not None:
                slots.append(slot)
            self.counters.add("kv_spec_ckpts", 1)
            self.counters.add("kv_spec_ckpt_pages", len(blocks))
        leaves = self._rows_gather(self.storage, all_blocks,
                                   state_slots=slots) \
            if (all_blocks or slots) else None
        return [{"blocks": blocks, "slot": slot, "rows": leaves,
                 "off": off, "soff": soff}
                for blocks, slot, off, soff in metas]

    def rollback_rows(self, ckpts: Sequence[dict]):
        """Batched :meth:`rollback_pages` for the rows that REJECTED: one
        device scatter restores every rolled-back row's pages + state slot
        from the shared :meth:`checkpoint_rows` gather."""
        live = [c for c in ckpts if c["blocks"] or c["slot"] is not None]
        if not live:
            return
        groups: Dict[int, List[dict]] = {}
        for c in live:          # rows from distinct ticks scatter apart
            groups.setdefault(id(c["rows"]), []).append(c)
        for group in groups.values():
            leaves = group[0]["rows"]
            blk_src: List[int] = []     # indices into the shared gather
            dst_blocks: List[int] = []
            st_src: List[int] = []
            dst_slots: List[int] = []
            for c in group:
                blk_src.extend(range(c["off"], c["off"] + len(c["blocks"])))
                dst_blocks.extend(c["blocks"])
                if c["slot"] is not None and c["soff"] >= 0:
                    st_src.append(c["soff"])
                    dst_slots.append(c["slot"])
                self.counters.add("kv_spec_rollback_pages",
                                  len(c["blocks"]))
            vals = []
            for leaf, s in zip(leaves, self.spec.leaves):
                if leaf is None:
                    vals.append(None)
                elif s.token_axis is not None:
                    vals.append(jnp.take(leaf, jnp.asarray(blk_src,
                                                           jnp.int32),
                                         axis=s.batch_axis)
                                if blk_src else None)
                else:
                    vals.append(jnp.take(leaf, jnp.asarray(st_src,
                                                           jnp.int32),
                                         axis=s.batch_axis)
                                if st_src else None)
            self.storage = self._rows_scatter(self.storage, dst_blocks,
                                              vals, state_slots=dst_slots)

    # -- migration ---------------------------------------------------------
    def migrate(self, table: KVTable, new_domain: int) -> bool:
        """Move a table into ``new_domain``: re-reserve there, copy only the
        **used** pages (+ state slot) on device, free the old reservation.
        Returns False (no side effects) when the target domain lacks space.
        """
        if table.domain == new_domain:
            return True
        if table.inflight:
            # a relayout/steal hitting an in-flight victim: fence — the
            # payload lands, the table turns host-resident, and the move
            # below becomes the free re-point
            self.spill_fence(table, count_wait=False)
        if table.spill is not None:
            # host-resident: the table holds no device resources, so a
            # migration (relayout rebalance, steal into the thief's domain)
            # is a pure re-point — zero device copies, can never fail
            table.domain = new_domain
            self.counters.add("kv_spill_repoints", 1)
            return True
        pages = len(table.blocks)
        if (len(self._free_blocks[new_domain]) < pages
                or not self.state_available(new_domain)):
            return False
        new_blocks = [self._pop_block(new_domain) for _ in range(pages)]
        new_slot = self._take_state(new_domain) if self.has_state else 0
        used = table.used_pages
        if used or (self.has_state and table.state_slot):
            self.storage = dec.copy_pool_entries(
                self.storage, self.spec,
                table.blocks[:used], new_blocks[:used],
                src_state=table.state_slot if self.has_state else None,
                dst_state=new_slot if self.has_state else None)
        # migration COPIES used pages into the new domain, so the moved
        # table's copies are private; shared originals decref and remain
        # with their other holders (relayout of a refcount>1 table works
        # without ever re-pointing someone else's pages)
        for b in sorted(table.blocks):
            self._release_block(b)
        if self.has_state and table.state_slot:
            self._free_states[table.domain].append(table.state_slot)
        self.counters.add("kv_blocks_migrated", used)
        self.counters.add("kv_tables_migrated", 1)
        table.domain = new_domain
        table.blocks = new_blocks
        table.state_slot = new_slot
        self._note_usage(new_domain)
        for cb in self._on_free:      # the old domain gained capacity
            cb()
        return True

    def _note_usage(self, domain: int):
        self.peak_used_blocks = max(self.peak_used_blocks, self.used_blocks())
        self.peak_used_per_domain[domain] = max(
            self.peak_used_per_domain[domain], self.used_blocks_in(domain))
        self._gauges()

    def _gauges(self):
        self.counters.set("kv_pool_used_blocks", float(self.used_blocks()))
        self.counters.set("kv_pool_total_blocks", float(self.total_blocks()))
        self.counters.set("kv_pool_occupancy", self.occupancy())
        self.counters.set("kv_active_tables", float(self.active_tables))
        self.counters.set("kv_spilled_tables", float(self.spilled_tables))
        self.counters.set("kv_spilled_bytes", self.spilled_bytes)
        self.counters.set("kv_shared_pages", float(self.shared_pages()))
        self.counters.set("kv_shared_bytes", self.shared_bytes())
        self.counters.set("kv_cached_pages", float(self.cached_pages()))
        self.counters.set("kv_spill_inflight_pages",
                          float(self.inflight_pages()))
        self.counters.set("kv_spill_inflight_bytes", self.inflight_bytes())

    # -- consistency -------------------------------------------------------
    def audit(self, tables: Iterable[KVTable] = ()):
        """Assert exact free-list AND refcount accounting: free lists hold
        unique ids inside their domain's range at refcount 0, every held
        block's refcount equals EXACTLY the number of live tables pointing
        at it (sharing is legal only through the refcount), unique held
        blocks + free covers the pool EXACTLY, and the prefix index is
        consistent — every entry's block is resident (held or cached on
        the free list), the block->key reverse map is a bijection, and
        state checkpoints are disjoint from held/free slots with
        held + free + checkpoints covering all slots.  ``tables`` must be
        every live table (a block in neither a table nor a free list is a
        leak).  The stress suites call this after every
        spill/restore/migrate/free/fork; raises AssertionError on any
        leak."""
        held = collections.Counter()
        held_states: List[int] = []
        for t in tables:
            if t.spill is not None:
                assert not t.blocks and not t.state_slot, \
                    f"spilled table holds device resources: {t}"
                assert not t.inflight, \
                    "table both landed-spilled and in flight"
            held.update(t.blocks)
            if self.has_state and t.state_slot:
                held_states.append(t.state_slot)
        # in-flight transfers: fence-before-regrant means the victim still
        # HOLDS its pages (counted above like any live table) and its
        # payload is not yet in the swap tier; pages in flight must match
        # the records exactly
        for r in self._inflight:
            assert r.table.inflight, \
                "in-flight record on a table not marked inflight"
            assert r.table.spill is None, \
                "in-flight record on an already-landed table"
            assert r.pages == min(r.table.used_pages,
                                  len(r.table.blocks)), \
                f"in-flight pages {r.pages} drifted from table " \
                f"{min(r.table.used_pages, len(r.table.blocks))}"
        # refcounts are exact: one count per live table holding the block
        for b, c in held.items():
            assert self._ref.get(b, 0) == c, \
                f"block {b}: refcount {self._ref.get(b, 0)} != {c} holders"
        assert set(self._ref) == set(held), \
            f"refcount on unheld blocks: {set(self._ref) - set(held)}"
        assert len(held_states) == len(set(held_states)), \
            "live tables share a state slot"
        for d in range(self.n_domains):
            lo = 1 + d * self.blocks_per_domain
            free = self._free_blocks[d]
            assert len(free) == len(set(free)), f"domain {d}: dup free ids"
            assert all(lo <= b < lo + self.blocks_per_domain for b in free), \
                f"domain {d}: free id outside range"
            slo = 1 + d * self.states_per_domain
            sfree = self._free_states[d]
            assert len(sfree) == len(set(sfree)), f"domain {d}: dup states"
            assert all(slo <= s < slo + self.states_per_domain
                       for s in sfree), f"domain {d}: state outside range"
        all_free = [b for f in self._free_blocks for b in f]
        assert not set(held) & set(all_free), "block is both free and held"
        all_sfree = [s for f in self._free_states for s in f]
        assert not set(held_states) & set(all_sfree), \
            "state slot is both free and held"
        assert len(set(held)) + len(all_free) == self.total_blocks(), \
            f"block leak: {len(set(held))} held + {len(all_free)} free " \
            f"!= {self.total_blocks()} total"
        # prefix index: entries point at resident blocks, reverse map is a
        # bijection, checkpoints account exactly
        free_set = set(all_free)
        for key, e in self._prefix.items():
            assert self._entry_of_block.get(e.block) == key, \
                f"prefix entry {key.hex()} reverse map broken"
            assert e.block in held or e.block in free_set, \
                f"prefix entry points at non-resident block {e.block}"
            assert self._block_domain(e.block) == e.domain, \
                f"prefix entry domain mismatch on block {e.block}"
        assert len(self._entry_of_block) == len(self._prefix), \
            "block->key map out of sync with the prefix index"
        ckpts = [e.state_ckpt for e in self._prefix.values()
                 if e.state_ckpt]
        assert len(ckpts) == len(set(ckpts)), "duplicate state checkpoints"
        assert not set(ckpts) & set(all_sfree), \
            "state checkpoint is also free"
        assert not set(ckpts) & set(held_states), \
            "state checkpoint is also held by a table"
        total_states = self.n_domains * self.states_per_domain
        assert len(held_states) + len(all_sfree) + len(ckpts) \
            == total_states, \
            f"state-slot leak: {len(held_states)} held + " \
            f"{len(all_sfree)} free + {len(ckpts)} ckpt " \
            f"!= {total_states} total"

    # -- stats -------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        snap = self.counters.totals
        fails = snap.get("kv_alloc_failures", 0.0)
        grants = snap.get("kv_reservations", 0.0)
        from repro.core.costmodel import prefill_chunk_bytes
        return {
            "occupancy": self.occupancy(),
            "peak_used_blocks": float(self.peak_used_blocks),
            "peak_used_per_domain": [float(x)
                                     for x in self.peak_used_per_domain],
            "peak_active_tables": float(self.peak_active_tables),
            "total_blocks": float(self.total_blocks()),
            "alloc_failures": fails,
            "park_rate": fails / max(1.0, fails + grants),
            "blocks_migrated": snap.get("kv_blocks_migrated", 0.0),
            "tables_migrated": snap.get("kv_tables_migrated", 0.0),
            "lazy_grows": snap.get("kv_lazy_grows", 0.0),
            "grow_failures": snap.get("kv_grow_failures", 0.0),
            "mid_decode_parks": snap.get("kv_mid_decode_parks", 0.0),
            "prefill_chunks": snap.get("prefill_chunks", 0.0),
            "spills": snap.get("kv_spills", 0.0),
            "spilled_pages": snap.get("kv_spilled_pages", 0.0),
            "restores": snap.get("kv_restores", 0.0),
            "restore_failures": snap.get("kv_restore_failures", 0.0),
            "spill_repoints": snap.get("kv_spill_repoints", 0.0),
            "spilled_tables": float(self.spilled_tables),
            "peak_spilled_bytes": self.peak_spilled_bytes,
            # async transfer engine: issue/poll/fence overlap surface
            "spill_issues": snap.get("kv_spill_issues", 0.0),
            "spill_inflight_pages": float(self.inflight_pages()),
            "spill_inflight_bytes": self.inflight_bytes(),
            "spill_overlap_rounds": snap.get("kv_spill_overlap_rounds",
                                             0.0),
            "fence_waits": snap.get("kv_fence_waits", 0.0),
            "d2h_bytes": snap.get("kv_d2h_bytes", 0.0),
            "h2d_bytes": snap.get("kv_h2d_bytes", 0.0),
            "restore_prefetches": snap.get("kv_restore_prefetches", 0.0),
            "swap_tier": self.swap.stats(),
            "bytes_per_domain": self.domain_bytes(),
            "prefill_chunk_bytes": prefill_chunk_bytes(
                self.cfg, self.block_tokens, self.max_len),
            # prefix sharing: hits/pages are totals, shared/cached are
            # right-now gauges; resident bytes are PHYSICAL (each shared
            # page counted once) vs the logical sum over tables
            "prefix_hits": snap.get("kv_prefix_hits", 0.0),
            "prefix_pages": snap.get("kv_prefix_pages", 0.0),
            "prefill_tokens_skipped": snap.get("prefill_tokens_skipped",
                                               0.0),
            "prefix_pages_published": snap.get("kv_prefix_pages_published",
                                               0.0),
            "cow_forks": snap.get("kv_cow_forks", 0.0),
            "ckpt_reclaims": snap.get("kv_ckpt_reclaims", 0.0),
            "shared_pages": float(self.shared_pages()),
            "shared_extra_refs": float(self.shared_extra_refs()),
            "cached_pages": float(self.cached_pages()),
            # cached-tier retention (access-ordered vs blind)
            "retention": self.retention,
            "cached_page_hits": snap.get("kv_cached_page_hits", 0.0),
            "cached_reclaims": snap.get("kv_cached_reclaims", 0.0),
            # speculative decode rollback traffic (engine-side accept
            # counters live in kv_stats; these are the pool's halves)
            "spec_ckpts": snap.get("kv_spec_ckpts", 0.0),
            "spec_ckpt_pages": snap.get("kv_spec_ckpt_pages", 0.0),
            "spec_rollback_pages": snap.get("kv_spec_rollback_pages", 0.0),
            "shared_bytes": self.shared_bytes(),
            "resident_kv_bytes": self.used_blocks() * self.bytes_per_block(),
            "logical_kv_bytes": (self.used_blocks()
                                 + self.shared_extra_refs())
            * self.bytes_per_block(),
        }
