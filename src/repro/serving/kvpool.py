"""Paged KV-block allocator partitioned per chiplet-group memory domain —
the second ARCAS pillar (hardware-aware memory allocation) applied to
serving.

The pool owns ONE physical storage pytree (``models/decode.py`` block-pool
layout) whose block-id space is partitioned into per-chiplet-group *domains*
(the NUMA-bind analogue: on TPU each domain's id range lives in that group's
HBM).  A request holds a :class:`KVTable` — its ring pages as physical block
ids inside exactly one domain, plus one per-stream state slot — instead of a
slot in a monolithic per-replica cache array:

  * admission reserves ``ceil(min(prompt+max_new, W) / block_tokens)`` pages
    (short requests reserve less than the ring width, which is where the
    capacity win over the slot monolith comes from);
  * reservation failure is the serving back-pressure signal: the admission
    coroutine parks on the pool's :class:`~repro.core.tasks.WaitQueue` via
    ``yield BLOCK`` and is woken by ``free``;
  * a relayout re-points block *tables* at the new owner replica of their
    domain; only streams rebalanced onto a replica that does not own their
    domain copy their **used** pages (``migrate``) — never whole cache
    slices;
  * under memory pressure a parked stream's used pages can be SPILLED to a
    host-side swap tier (``spill``/``restore``): its device pages are freed
    to the wait-line head and the table turns host-resident — migrating for
    free (pure domain re-point) — until it is re-granted pages and the
    stream resumes mid-decode, instead of the restart-from-scratch eviction
    that recomputes every token.

Block id 0 and state slot 0 are reserved null entries: empty decode slots
and the unreserved tail of short tables point at them, so gather/scatter
shapes stay static (jit-stable) while null contents are never read (ring
positions past a stream's last token are masked by ``cache_positions``).

Budgets are expressed in *bytes* via ``costmodel.kv_cache_bytes`` and
converted to blocks/state slots, so a pool can be sized to exactly the HBM
footprint the old slot-monolith allocator used — or to a fraction of
``ChipletTopology.group_hbm()`` on a real fleet.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Iterable, List, Optional

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.costmodel import kv_cache_bytes, kv_spill_bytes
from repro.core.counters import PerfCounters
from repro.launch.steps import make_spill_gather, make_spill_scatter
from repro.models import decode as dec


def kv_bytes_exact(cfg: ModelConfig, n_tokens: int, max_len: int) -> float:
    """Exact decode-state bytes of ONE stream holding ``n_tokens`` of
    context (ring-capped at ``max_len``) — replaces the old
    ``(prompt+generated)*2`` napkin estimate in migration accounting."""
    s = ShapeConfig("kv", "decode", max(1, min(n_tokens, max_len)), 1)
    return kv_cache_bytes(cfg, s, 1)


@dataclasses.dataclass
class SpillEntry:
    """Host-side payload of a spilled table: its used pages (+ state) as
    numpy leaves in ``jax.tree`` order, waiting in the swap tier until the
    stream is re-granted device pages."""
    pages: int                      # used pages held host-side
    data: List[Any]                 # host leaves from extract_pool_entries
    had_state: bool = False         # a state slot rides in ``data``


@dataclasses.dataclass
class KVTable:
    """One stream's view into the pool: ring pages + state slot, resident
    in a single chiplet-group domain.

    Reservations are ELASTIC: a lazily-admitted table starts with the pages
    of its first prefill chunk and :meth:`KVBlockPool.grow` appends pages
    in ring order as the stream's ``pos`` crosses page boundaries, up to
    ``cap_pages`` (the eager reservation the PR-2 allocator made up
    front).  ``cap_pages == 0`` means fully reserved at admission.

    A table can be SPILLED to the host swap tier under memory pressure
    (:meth:`KVBlockPool.spill`): its used pages live in ``spill`` and it
    holds no device resources until :meth:`KVBlockPool.restore` — while
    host-resident it migrates between domains by re-pointing ``domain``
    alone (zero device copies)."""
    domain: int
    blocks: List[int]               # reserved physical pages, ring order
    state_slot: int                 # 0 = none (model has no state leaves)
    used_pages: int = 0             # pages actually written (prefill/decode)
    cap_pages: int = 0              # lazy mode: max pages this stream needs
    spill: Optional[SpillEntry] = None   # host payload while spilled

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    @property
    def spilled(self) -> bool:
        return self.spill is not None


class KVBlockPool:
    """Block pool over ``n_domains`` chiplet-group memory domains.

    Pure host-side bookkeeping (free lists, tables, counters) plus the
    device-side storage pytree; gather/scatter/copy of actual pages happens
    through ``models/decode.py`` view helpers.
    """

    def __init__(self, cfg: ModelConfig, *, n_domains: int, max_len: int,
                 blocks_per_domain: int, states_per_domain: int,
                 block_tokens: int = 16,
                 counters: Optional[PerfCounters] = None):
        self.cfg = cfg
        self.max_len = max_len
        self.n_domains = n_domains
        self.counters = counters or PerfCounters()
        self.spec = dec.cache_view_specs(cfg, max_len)
        W = self.spec.width
        if W:
            bt = self._aligned_block_tokens(W, block_tokens)
            self.block_tokens = bt
            self.pages_per_stream = W // bt
        else:                       # pure-state model (SSM): no ring pages
            self.block_tokens = 1
            self.pages_per_stream = 0
        self.has_state = any(s.token_axis is None for s in self.spec.leaves)
        self.blocks_per_domain = blocks_per_domain if W else 0
        self.states_per_domain = states_per_domain if self.has_state else 0
        # id 0 is the shared null entry; domain d owns
        # [1 + d*per_domain, 1 + (d+1)*per_domain)
        self._free_blocks: List[List[int]] = [
            list(range(1 + d * self.blocks_per_domain,
                       1 + (d + 1) * self.blocks_per_domain))
            for d in range(n_domains)]
        self._free_states: List[List[int]] = [
            list(range(1 + d * self.states_per_domain,
                       1 + (d + 1) * self.states_per_domain))
            for d in range(n_domains)]
        self.storage = dec.init_block_pool(
            cfg, self.spec,
            n_blocks=1 + n_domains * self.blocks_per_domain,
            n_states=1 + n_domains * self.states_per_domain,
            block_tokens=self.block_tokens, max_len=max_len)
        self._on_free: List[Callable[[], None]] = []
        # swap tier: D2H/H2D copies of a table's used pages + state slot
        self._spill_gather = make_spill_gather(self.spec)
        self._spill_scatter = make_spill_scatter(self.spec)
        self.spilled_tables = 0         # tables currently host-resident
        self.spilled_bytes = 0.0        # swap-tier footprint right now
        self.peak_spilled_bytes = 0.0
        self.peak_used_blocks = 0
        # per-domain high-water marks (blocks in use), so chunked prefill /
        # lazy growth can report byte-accurate per-domain footprints
        self.peak_used_per_domain = [0] * n_domains
        self.active_tables = 0          # reservations currently live
        self.peak_active_tables = 0     # max concurrently admitted streams

    # -- sizing helpers ----------------------------------------------------
    @staticmethod
    def _aligned_block_tokens(W: int, block_tokens: int) -> int:
        """Largest page size <= block_tokens dividing the ring width."""
        bt = min(block_tokens, W)
        while W % bt:
            bt -= 1
        return bt

    @classmethod
    def blocks_for_streams(cls, cfg: ModelConfig, max_len: int,
                           streams: int, block_tokens: int = 16) -> Dict:
        """Per-domain budget equivalent to a slot monolith of ``streams``
        full-length streams: the byte-for-byte capacity the old allocator
        reserved per replica group."""
        spec = dec.cache_view_specs(cfg, max_len)
        W = spec.width
        # same page-size alignment as __init__, so the budget always covers
        # exactly `streams` full tables regardless of W % block_tokens
        pages = W // cls._aligned_block_tokens(W, block_tokens) if W else 0
        return {"blocks_per_domain": streams * pages,
                "states_per_domain": streams}

    def bytes_per_block(self) -> float:
        """Token-page bytes from the cost model (state slots excluded)."""
        if not self.pages_per_stream:
            return 0.0
        per2 = kv_bytes_exact(self.cfg, 2 * self.block_tokens, self.max_len)
        per1 = kv_bytes_exact(self.cfg, self.block_tokens, self.max_len)
        return max(per2 - per1, 0.0)

    def domain_bytes(self) -> float:
        state_b = (kv_bytes_exact(self.cfg, 1, self.max_len)
                   - self.bytes_per_block() / max(1, self.block_tokens))
        return (self.blocks_per_domain * self.bytes_per_block()
                + self.states_per_domain * max(state_b, 0.0))

    # -- accounting --------------------------------------------------------
    def pages_needed(self, total_tokens: int) -> int:
        if not self.pages_per_stream:
            return 0
        W = self.spec.width
        bt = self.block_tokens
        return min(self.pages_per_stream,
                   max(1, math.ceil(min(total_tokens, W) / bt)))

    def free_blocks(self, domain: int) -> int:
        return len(self._free_blocks[domain])

    def free_states(self, domain: int) -> int:
        return len(self._free_states[domain])

    def used_blocks(self) -> int:
        total = self.n_domains * self.blocks_per_domain
        return total - sum(len(f) for f in self._free_blocks)

    def used_blocks_in(self, domain: int) -> int:
        return self.blocks_per_domain - len(self._free_blocks[domain])

    def total_blocks(self) -> int:
        return self.n_domains * self.blocks_per_domain

    def occupancy(self) -> float:
        """Fraction of pool capacity in use (blocks, or state slots for
        pure-state models)."""
        total = self.total_blocks()
        if not total:
            total = self.n_domains * self.states_per_domain
            used = total - sum(len(f) for f in self._free_states)
            return used / total if total else 0.0
        return self.used_blocks() / total

    def can_reserve(self, domain: int, pages: int) -> bool:
        if self.has_state and not self._free_states[domain]:
            return False
        return len(self._free_blocks[domain]) >= pages

    # -- alloc / free ------------------------------------------------------
    def reserve(self, domain: int, total_tokens: int, *,
                first_tokens: Optional[int] = None,
                headroom: int = 0,
                count_failure: bool = True) -> Optional[KVTable]:
        """Reserve a table for a stream of ``total_tokens`` context in
        ``domain``; None when the domain cannot serve it right now.

        With ``first_tokens`` the reservation is ELASTIC: only the pages
        covering the first ``first_tokens`` positions are taken now (one
        prefill chunk) and the table records ``cap_pages`` — the eager
        footprint — as its growth bound for :meth:`grow`.  The budget check
        still uses the CAP: a stream whose full ring cannot fit a domain
        can never complete, lazily or not.

        ``headroom`` is the admission-control knob for elastic mode: grant
        only when the domain would keep ``headroom`` free blocks AFTER the
        reservation, so lazy growth of already-admitted streams is less
        likely to close the incremental-allocation deadlock in the first
        place.  ``headroom=0`` is exactly the unguarded grant; the knob is
        clamped so an EMPTY domain can always admit (a too-large k must
        throttle, never livelock).

        ``count_failure=False`` lets a caller probing several domains count
        one logical failure instead of one per domain."""
        cap = self.pages_needed(total_tokens)
        if cap > max(self.blocks_per_domain, 0) and cap:
            raise ValueError(
                f"request needs {cap} pages but a domain only has "
                f"{self.blocks_per_domain}: raise the pool budget")
        if self.has_state and self.states_per_domain == 0:
            raise ValueError("pool has no state slots but model needs them")
        pages = cap if first_tokens is None else \
            min(cap, self.pages_needed(first_tokens))
        headroom = min(headroom if pages else 0,
                       max(0, self.blocks_per_domain - pages))
        if not self.can_reserve(domain, pages + headroom):
            if count_failure:
                self.counters.add("kv_alloc_failures", 1)
            return None
        blocks = [self._free_blocks[domain].pop() for _ in range(pages)]
        slot = self._free_states[domain].pop() if self.has_state else 0
        self.counters.add("kv_blocks_allocated", pages)
        self.counters.add("kv_reservations", 1)
        self.active_tables += 1
        self.peak_active_tables = max(self.peak_active_tables,
                                      self.active_tables)
        self._note_usage(domain)
        return KVTable(domain, blocks, slot,
                       cap_pages=cap if first_tokens is not None else 0)

    def grow(self, table: KVTable, n_pages: int) -> bool:
        """Append ``n_pages`` ring pages to an elastic table (same domain),
        committing bytes only when the stream's ``pos`` actually crosses a
        page boundary.  False (no side effects) when the domain lacks free
        pages — the caller parks its stream mid-decode and retries on the
        pool's free callback."""
        if n_pages <= 0:
            return True
        cap = table.cap_pages or self.pages_per_stream
        if len(table.blocks) + n_pages > cap:
            raise ValueError(
                f"growing past the table's cap ({len(table.blocks)}+"
                f"{n_pages} > {cap} pages)")
        if len(self._free_blocks[table.domain]) < n_pages:
            self.counters.add("kv_grow_failures", 1)
            return False
        table.blocks.extend(self._free_blocks[table.domain].pop()
                            for _ in range(n_pages))
        self.counters.add("kv_blocks_allocated", n_pages)
        self.counters.add("kv_lazy_grows", 1)
        self._note_usage(table.domain)
        return True

    def free(self, table: KVTable):
        """Return a table's pages + state slot and fire the free callbacks
        (which unblock BLOCK-parked admission coroutines).  Freeing a
        SPILLED table drops its host payload too (the restart-eviction
        fallback path)."""
        self._free_blocks[table.domain].extend(sorted(table.blocks))
        if self.has_state and table.state_slot:
            self._free_states[table.domain].append(table.state_slot)
        self.counters.add("kv_blocks_freed", len(table.blocks))
        if table.spill is not None:
            self._drop_spill(table)
        table.blocks = []
        table.state_slot = 0
        table.used_pages = 0
        self.active_tables -= 1
        self._gauges()
        for cb in self._on_free:
            cb()

    def on_free(self, cb: Callable[[], None]):
        self._on_free.append(cb)

    # -- swap tier: spill parked pages to host instead of discarding them --
    def spill(self, table: KVTable) -> int:
        """Move a table's USED pages (+ state slot) into the host swap
        tier and free its device resources to the wait-line head.

        The table stays live (``active_tables`` unchanged — the stream is
        still admitted, just host-resident) but holds zero device blocks
        until :meth:`restore`; its saved decode cursor makes the
        spill/restore cycle invisible in the token output.  Returns the
        number of pages spilled (0 = already spilled, nothing to do)."""
        if table.spill is not None:
            return 0
        used = min(table.used_pages, len(table.blocks))
        had_state = bool(self.has_state and table.state_slot)
        data = self._spill_gather(
            self.storage, table.blocks[:used],
            state_slot=table.state_slot if had_state else None)
        table.spill = SpillEntry(pages=used, data=data, had_state=had_state)
        self._free_blocks[table.domain].extend(sorted(table.blocks))
        if had_state:
            self._free_states[table.domain].append(table.state_slot)
        self.counters.add("kv_blocks_freed", len(table.blocks))
        self.counters.add("kv_spills", 1)
        self.counters.add("kv_spilled_pages", used)
        table.blocks = []
        table.state_slot = 0
        self.spilled_tables += 1
        self.spilled_bytes += kv_spill_bytes(self.cfg, used,
                                             self.block_tokens, had_state)
        self.peak_spilled_bytes = max(self.peak_spilled_bytes,
                                      self.spilled_bytes)
        self._gauges()
        for cb in self._on_free:
            cb()
        return used

    def restore(self, table: KVTable) -> bool:
        """Re-grant device pages to a spilled table in its CURRENT domain
        (re-point ``migrate`` first to restore somewhere else) and scatter
        the host payload back; False (no side effects) when the domain
        lacks pages or a state slot.  The stream resumes mid-decode at its
        saved cursor — zero recomputed tokens."""
        sp = table.spill
        if sp is None:
            return True
        d = table.domain
        if (len(self._free_blocks[d]) < sp.pages
                or (self.has_state and not self._free_states[d])):
            self.counters.add("kv_restore_failures", 1)
            return False
        blocks = [self._free_blocks[d].pop() for _ in range(sp.pages)]
        slot = self._free_states[d].pop() if self.has_state else 0
        self.storage = self._spill_scatter(
            self.storage, blocks, sp.data,
            state_slot=slot if sp.had_state else None)
        table.blocks = blocks
        table.state_slot = slot
        table.used_pages = sp.pages
        self._drop_spill(table)
        self.counters.add("kv_blocks_allocated", sp.pages)
        self.counters.add("kv_restores", 1)
        self._note_usage(d)
        return True

    def _drop_spill(self, table: KVTable):
        sp = table.spill
        self.spilled_tables -= 1
        self.spilled_bytes -= kv_spill_bytes(self.cfg, sp.pages,
                                             self.block_tokens, sp.had_state)
        table.spill = None

    # -- migration ---------------------------------------------------------
    def migrate(self, table: KVTable, new_domain: int) -> bool:
        """Move a table into ``new_domain``: re-reserve there, copy only the
        **used** pages (+ state slot) on device, free the old reservation.
        Returns False (no side effects) when the target domain lacks space.
        """
        if table.domain == new_domain:
            return True
        if table.spill is not None:
            # host-resident: the table holds no device resources, so a
            # migration (relayout rebalance, steal into the thief's domain)
            # is a pure re-point — zero device copies, can never fail
            table.domain = new_domain
            self.counters.add("kv_spill_repoints", 1)
            return True
        pages = len(table.blocks)
        if (len(self._free_blocks[new_domain]) < pages
                or (self.has_state and not self._free_states[new_domain])):
            return False
        new_blocks = [self._free_blocks[new_domain].pop()
                      for _ in range(pages)]
        new_slot = (self._free_states[new_domain].pop()
                    if self.has_state else 0)
        used = table.used_pages
        if used or (self.has_state and table.state_slot):
            self.storage = dec.copy_pool_entries(
                self.storage, self.spec,
                table.blocks[:used], new_blocks[:used],
                src_state=table.state_slot if self.has_state else None,
                dst_state=new_slot if self.has_state else None)
        self._free_blocks[table.domain].extend(sorted(table.blocks))
        if self.has_state and table.state_slot:
            self._free_states[table.domain].append(table.state_slot)
        self.counters.add("kv_blocks_migrated", used)
        self.counters.add("kv_tables_migrated", 1)
        table.domain = new_domain
        table.blocks = new_blocks
        table.state_slot = new_slot
        self._note_usage(new_domain)
        for cb in self._on_free:      # the old domain gained capacity
            cb()
        return True

    def _note_usage(self, domain: int):
        self.peak_used_blocks = max(self.peak_used_blocks, self.used_blocks())
        self.peak_used_per_domain[domain] = max(
            self.peak_used_per_domain[domain], self.used_blocks_in(domain))
        self._gauges()

    def _gauges(self):
        self.counters.set("kv_pool_used_blocks", float(self.used_blocks()))
        self.counters.set("kv_pool_total_blocks", float(self.total_blocks()))
        self.counters.set("kv_pool_occupancy", self.occupancy())
        self.counters.set("kv_active_tables", float(self.active_tables))
        self.counters.set("kv_spilled_tables", float(self.spilled_tables))
        self.counters.set("kv_spilled_bytes", self.spilled_bytes)

    # -- consistency -------------------------------------------------------
    def audit(self, tables: Iterable[KVTable] = ()):
        """Assert exact free-list accounting: free lists hold unique ids
        inside their domain's range, every live table's blocks are disjoint
        from the free lists and from each other, and held + free covers the
        pool EXACTLY — ``tables`` must therefore be every live table (a
        block in neither a table nor a free list is a leak).  The
        oversubscription stress suite calls this after every
        spill/restore/free cycle; raises AssertionError on any leak."""
        held_blocks: List[int] = []
        held_states: List[int] = []
        for t in tables:
            if t.spill is not None:
                assert not t.blocks and not t.state_slot, \
                    f"spilled table holds device resources: {t}"
            held_blocks.extend(t.blocks)
            if self.has_state and t.state_slot:
                held_states.append(t.state_slot)
        assert len(held_blocks) == len(set(held_blocks)), \
            "live tables share physical blocks"
        for d in range(self.n_domains):
            lo = 1 + d * self.blocks_per_domain
            free = self._free_blocks[d]
            assert len(free) == len(set(free)), f"domain {d}: dup free ids"
            assert all(lo <= b < lo + self.blocks_per_domain for b in free), \
                f"domain {d}: free id outside range"
            slo = 1 + d * self.states_per_domain
            sfree = self._free_states[d]
            assert len(sfree) == len(set(sfree)), f"domain {d}: dup states"
            assert all(slo <= s < slo + self.states_per_domain
                       for s in sfree), f"domain {d}: state outside range"
        all_free = [b for f in self._free_blocks for b in f]
        assert not set(held_blocks) & set(all_free), \
            "block is both free and held"
        all_sfree = [s for f in self._free_states for s in f]
        assert not set(held_states) & set(all_sfree), \
            "state slot is both free and held"
        assert len(held_blocks) + len(all_free) == self.total_blocks(), \
            f"block leak: {len(held_blocks)} held + {len(all_free)} free " \
            f"!= {self.total_blocks()} total"
        total_states = self.n_domains * self.states_per_domain
        assert len(held_states) + len(all_sfree) == total_states, \
            f"state-slot leak: {len(held_states)} held + " \
            f"{len(all_sfree)} free != {total_states} total"

    # -- stats -------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        snap = self.counters.totals
        fails = snap.get("kv_alloc_failures", 0.0)
        grants = snap.get("kv_reservations", 0.0)
        from repro.core.costmodel import prefill_chunk_bytes
        return {
            "occupancy": self.occupancy(),
            "peak_used_blocks": float(self.peak_used_blocks),
            "peak_used_per_domain": [float(x)
                                     for x in self.peak_used_per_domain],
            "peak_active_tables": float(self.peak_active_tables),
            "total_blocks": float(self.total_blocks()),
            "alloc_failures": fails,
            "park_rate": fails / max(1.0, fails + grants),
            "blocks_migrated": snap.get("kv_blocks_migrated", 0.0),
            "tables_migrated": snap.get("kv_tables_migrated", 0.0),
            "lazy_grows": snap.get("kv_lazy_grows", 0.0),
            "grow_failures": snap.get("kv_grow_failures", 0.0),
            "mid_decode_parks": snap.get("kv_mid_decode_parks", 0.0),
            "prefill_chunks": snap.get("prefill_chunks", 0.0),
            "spills": snap.get("kv_spills", 0.0),
            "spilled_pages": snap.get("kv_spilled_pages", 0.0),
            "restores": snap.get("kv_restores", 0.0),
            "restore_failures": snap.get("kv_restore_failures", 0.0),
            "spill_repoints": snap.get("kv_spill_repoints", 0.0),
            "spilled_tables": float(self.spilled_tables),
            "peak_spilled_bytes": self.peak_spilled_bytes,
            "bytes_per_domain": self.domain_bytes(),
            "prefill_chunk_bytes": prefill_chunk_bytes(
                self.cfg, self.block_tokens, self.max_len),
        }
