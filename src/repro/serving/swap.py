"""Swap-tier transfer engine: preallocated host buffers + in-flight
transfer records (ISSUE 10).

Two pieces make the pool's second tier physical instead of ad-hoc:

``SwapTier``
    The host side of the hierarchy.  Spilled payloads land in
    PREALLOCATED per-leaf buffers (one page-extent + optional state slot
    per spilled stream) instead of fresh numpy allocations per spill —
    on real hardware these are the pinned staging buffers D2H DMA
    requires; the tier probes whether the platform exposes a
    ``pinned_host`` memory space and records the answer (TPU yes, CPU CI
    no — plain numpy there, same layout).  A first-fit extent allocator
    keeps page ranges contiguous so a landed spill is one slice view per
    leaf, and an overflow path falls back to ad-hoc arrays (counted)
    when the preallocation is exhausted rather than failing the spill.

``InFlightSpill``
    One issued-but-unfenced D2H copy.  ``KVBlockPool.spill_issue``
    dispatches the device-side gather (JAX async dispatch: ``jnp.take``
    returns immediately) and parks one of these in the pool's in-flight
    table; decode ticks keep running while the copy drains.  The
    victim's pages are re-granted only when the transfer completes —
    the fence-before-regrant invariant — and the functional storage
    update means the gather snapshots issue-time bytes no matter what
    later ticks write.  ``ready()`` is the poll; the pool's
    ``spill_fence`` is the blocking fence.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import numpy as np

import jax


def pinned_host_available() -> bool:
    """Probe whether the default device exposes a ``pinned_host`` memory
    space (TPU runtimes do; CPU does not)."""
    try:
        dev = jax.devices()[0]
        return any(m.kind == "pinned_host"
                   for m in dev.addressable_memories())
    except Exception:
        return False


@dataclasses.dataclass
class TierHandle:
    """One landed spill's home in the tier: a contiguous page extent +
    optional state slot, or an overflow allocation."""
    start: int                    # first page of the extent (-1: overflow)
    pages: int
    state_idx: int                # tier state slot (-1: none/overflow)
    views: List[Any]              # per-leaf numpy views holding the bytes
    overflow: bool = False


@dataclasses.dataclass
class InFlightSpill:
    """An issued, unfenced D2H spill: the victim table, its device-side
    gathered payload, and overlap bookkeeping."""
    table: Any
    pages: int
    had_state: bool
    leaves: List[Any]             # device arrays (async gather result)
    issue_clock: int              # pool poll-clock at issue
    n_bytes: float

    def ready(self) -> bool:
        return all(leaf.is_ready() for leaf in self.leaves
                   if leaf is not None)


class SwapTier:
    """Preallocated host-side storage for spilled pages + state slots.

    Buffers mirror the pool's leaf layout: every token leaf gets a
    ``capacity_pages``-page buffer, every state leaf a
    ``capacity_states``-slot buffer.  ``store`` copies a landed payload
    into a first-fit extent and returns per-leaf views (what
    ``SpillEntry.data`` holds — the restore path scatters them back
    unchanged); ``release`` returns the extent.  When the preallocation
    is full the payload keeps its ad-hoc arrays (``overflow_allocs``
    counts how often — sizing feedback, not an error).
    """

    def __init__(self, storage, spec, capacity_pages: int,
                 capacity_states: int):
        self.spec = spec
        self.capacity_pages = int(capacity_pages)
        self.capacity_states = int(capacity_states)
        self.pinned = pinned_host_available()
        self.overflow_allocs = 0
        self._bufs: List[Optional[np.ndarray]] = []
        for leaf, s in zip(jax.tree.leaves(storage), spec.leaves):
            ax = s.batch_axis
            if s.token_axis is not None:
                shape = (leaf.shape[:ax] + (self.capacity_pages,)
                         + leaf.shape[ax + 1:])
            else:
                shape = (leaf.shape[:ax] + (self.capacity_states,)
                         + leaf.shape[ax + 1:])
            self._bufs.append(np.zeros(shape, dtype=leaf.dtype))
        # first-fit free extents over the page axis + state slot free list
        self._extents: List[Tuple[int, int]] = [(0, self.capacity_pages)]
        self._free_states: List[int] = list(range(self.capacity_states))

    # -- extent allocator --------------------------------------------------
    def _alloc_extent(self, pages: int) -> int:
        for i, (start, length) in enumerate(self._extents):
            if length >= pages:
                if length == pages:
                    self._extents.pop(i)
                else:
                    self._extents[i] = (start + pages, length - pages)
                return start
        return -1

    def _free_extent(self, start: int, pages: int):
        self._extents.append((start, pages))
        # coalesce neighbours so long runs stay allocatable
        self._extents.sort()
        merged: List[Tuple[int, int]] = []
        for s, n in self._extents:
            if merged and merged[-1][0] + merged[-1][1] == s:
                merged[-1] = (merged[-1][0], merged[-1][1] + n)
            else:
                merged.append((s, n))
        self._extents = merged

    # -- store / release ---------------------------------------------------
    def store(self, host_leaves: List[Any], pages: int,
              had_state: bool) -> TierHandle:
        """Copy a landed payload into the tier; returns the handle whose
        ``views`` are the payload's long-term home."""
        start = self._alloc_extent(pages) if pages else 0
        state_idx = -1
        if had_state and self._free_states:
            state_idx = self._free_states.pop()
        need_state = had_state and state_idx < 0
        if (pages and start < 0) or need_state:
            if start >= 0 and pages:
                self._free_extent(start, pages)
            if state_idx >= 0:
                self._free_states.append(state_idx)
            self.overflow_allocs += 1
            views = [np.asarray(h) if h is not None else None
                     for h in host_leaves]
            return TierHandle(-1, pages, -1, views, overflow=True)
        views: List[Any] = []
        for buf, host, s in zip(self._bufs, host_leaves, self.spec.leaves):
            if host is None:
                views.append(None)
                continue
            ax = s.batch_axis
            if s.token_axis is not None:
                view = buf[(slice(None),) * ax
                           + (slice(start, start + pages),)]
            else:
                view = buf[(slice(None),) * ax
                           + (slice(state_idx, state_idx + 1),)]
            view[...] = np.asarray(host)
            views.append(view)
        return TierHandle(start, pages, state_idx, views)

    def release(self, handle: Optional[TierHandle]):
        if handle is None or handle.overflow:
            return
        if handle.pages:
            self._free_extent(handle.start, handle.pages)
        if handle.state_idx >= 0:
            self._free_states.append(handle.state_idx)

    # -- introspection -----------------------------------------------------
    def free_pages(self) -> int:
        return sum(n for _, n in self._extents)

    def stats(self) -> dict:
        return {"capacity_pages": self.capacity_pages,
                "capacity_states": self.capacity_states,
                "free_pages": self.free_pages(),
                "pinned_host": self.pinned,
                "overflow_allocs": self.overflow_allocs}
