"""Draft-token proposers for speculative decoding (ISSUE 8).

The engine's speculative path is drafter-agnostic: anything with a
``draft(req, k) -> list[int]`` method can propose up to k tokens for a
decode stream, and the verify-in-one-forward + greedy-acceptance machinery
guarantees token identity with non-speculative decoding regardless of
what the drafter returns (a bad drafter only wastes work, never changes
output).  The default is a prompt-lookup n-gram drafter over the stream's
OWN committed tokens — zero extra model state, surprisingly effective on
repetitive continuations — structured so a small draft model from
``configs/`` can slot in behind the same protocol later (a model drafter
would carry per-stream cache state keyed off ``req``, which is why the
protocol takes the request rather than a bare token list).
"""
from __future__ import annotations

from typing import List, Protocol


class Drafter(Protocol):
    def draft(self, req, k: int) -> List[int]:
        """Propose up to k tokens to follow ``req.prompt + req.generated``.

        May return fewer than k (or none).  Proposals are suggestions
        only — the engine verifies every one through the fused chunk
        forward and keeps just the greedy-matching prefix."""
        ...


class NGramDrafter:
    """Prompt-lookup drafting: find the most recent earlier occurrence of
    the committed stream's trailing n-gram and propose the tokens that
    followed it.  Tries the longest configured n-gram first (longer
    matches are more trustworthy), falling back to shorter ones; no match
    means no draft, and the tick decays to a plain single-token decode.
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError("need 1 <= min_ngram <= max_ngram")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def draft(self, req, k: int) -> List[int]:
        committed = list(req.prompt) + list(req.generated)
        n_c = len(committed)
        for n in range(min(self.max_ngram, n_c - 1), self.min_ngram - 1, -1):
            tail = committed[n_c - n:]
            # most recent prior occurrence: scan right-to-left, excluding
            # the trailing match itself
            for s in range(n_c - n - 1, -1, -1):
                if committed[s:s + n] == tail:
                    return committed[s + n:s + n + k]
        return []


def make_drafter(kind: str, *, ngram: int = 3) -> Drafter:
    """Drafter registry.  "ngram" is the only built-in today; a "model"
    kind backed by a small config from ``configs/`` is the intended next
    entry (same protocol, per-stream KV state)."""
    if kind == "ngram":
        return NGramDrafter(max_ngram=ngram)
    raise ValueError(f"unknown drafter kind {kind!r}")
