"""Launch layer: production mesh, sharding rules, step functions, dry-run."""
