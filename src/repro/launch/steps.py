"""Step functions: train_step (fwd+bwd+AdamW), serve_prefill, serve_step.

Each factory closes over the (hashable, frozen) ModelConfig so the returned
function is a clean pytree->pytree map for jax.jit with explicit
in_shardings / out_shardings.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import decode as dec
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.optim.quantized import adamw8bit_update


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    grad_transform=None, microbatches: int = 1,
                    opt_impl: str = "adamw", gather_specs=None,
                    ef_transform=None):
    """(params, opt_state, batch) -> (params', opt_state', metrics).

    ``microbatches > 1`` splits the global batch and accumulates gradients
    in f32 over a scan — activation memory scales with the microbatch while
    the optimizer still sees the full-batch gradient.  ``grad_transform``
    hooks in a stateless gradient transform.  ``ef_transform`` hooks in
    *stateful* cross-pod gradient compression (repro.compression): the step
    becomes (params, opt_state, batch, ef) -> (params', opt_state',
    metrics, ef') so the error-feedback state threads through the jit
    instead of being baked in as a traced constant.
    """

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: T.loss_fn(p, cfg, batch, gather_specs=gather_specs),
            has_aux=True)(params)

    def train_step(params, opt_state, batch, ef=None):
        if microbatches == 1:
            (loss, parts), grads = grads_of(params, batch)
        else:
            m = microbatches

            def split(x):
                return x.reshape((x.shape[0] // m, m) + x.shape[1:]) \
                    .swapaxes(0, 1) if x.ndim >= 1 else x

            def split_tree(b):
                out = {}
                for k, v in b.items():
                    if k == "position_ids":       # (3, B, S): batch is dim 1
                        out[k] = v.reshape(
                            (3, v.shape[1] // m, m) + v.shape[2:]) \
                            .transpose(2, 0, 1, *range(3, v.ndim + 1))
                    else:
                        out[k] = split(v)
                return out

            mb = split_tree(batch)

            def body(carry, mbatch):
                gsum, lsum, csum, asum = carry
                (l, parts), g = grads_of(params, mbatch)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l, csum + parts["ce"],
                        asum + parts["aux"]), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            z = jnp.zeros((), jnp.float32)
            (gsum, lsum, csum, asum), _ = jax.lax.scan(
                body, (g0, z, z, z), mb)
            grads = jax.tree.map(lambda g: g / m, gsum)
            loss = lsum / m
            parts = {"ce": csum / m, "aux": asum / m}

        new_ef = ef
        if ef_transform is not None:
            grads, new_ef = ef_transform(grads, ef)
        elif grad_transform is not None:
            grads = grad_transform(grads)
        update = adamw8bit_update if opt_impl == "adamw8bit" else adamw_update
        new_params, new_opt, om = update(grads, opt_state, params, opt_cfg)
        metrics = {"loss": loss, "ce": parts["ce"], "aux": parts["aux"], **om}
        if ef_transform is not None:
            return new_params, new_opt, metrics, new_ef
        return new_params, new_opt, metrics

    if ef_transform is None:
        # keep the legacy 3-arg signature for stateless callers
        stateless = train_step
        def train_step(params, opt_state, batch):   # noqa: F811
            return stateless(params, opt_state, batch)

    return train_step


def make_train_step_smap(cfg: ModelConfig, opt_cfg: AdamWConfig, mesh,
                         pspecs, batch_specs, *, microbatches: int = 1,
                         opt_impl: str = "adamw", compress_pod: bool = False):
    """Data-parallel-manual train step: ONE gradient sync per step.

    Under plain GSPMD, weight-gradient partial sums inside scans are
    all-reduced at every carry boundary — per MoE token-block and per
    microbatch (measured 6.1-27 TB/device/step on grok-1).  Here the batch
    axes ("pod","data") are MANUAL via jax.shard_map: every shard computes
    local gradients (model axes stay auto/GSPMD for TP), and the data-axis
    reduction happens exactly once:

      * FSDP leaves (a 'data'-sharded dim) are all-gathered per layer on
        use; their gradient sync is the all-gather VJP — a reduce-scatter
        (ZeRO-2 for free);
      * replicated leaves get a single psum;
      * with ``compress_pod``, the cross-pod hop quantizes to int8 with
        error feedback before the pod psum (the DCN compression point).

    The AdamW update runs outside the shard_map under normal GSPMD.
    """
    from jax.sharding import PartitionSpec as P

    manual = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def manualize(spec):
        return P(*(a if a in manual else None for a in spec))

    pspecs_m = jax.tree.map(manualize, pspecs,
                            is_leaf=lambda x: isinstance(x, P))

    def fsdp_dim(spec):
        # -1 = no data-sharded dim (None would vanish as a pytree leaf)
        for i, a in enumerate(spec):
            if a == "data":
                return i
        return -1

    gdims = jax.tree.map(fsdp_dim, pspecs, is_leaf=lambda x: isinstance(x, P))

    import functools as _ft

    @_ft.partial(jax.custom_vjp, nondiff_argnums=(1,))
    def _gather_cv(w, dim):
        g = jax.lax.all_gather(w, "data", axis=dim, tiled=False)
        shp = list(w.shape)
        shp[dim] = w.shape[dim] * g.shape[dim]   # shard axis inserted AT dim
        return g.reshape(shp)

    def _gather_fwd(w, dim):
        return _gather_cv(w, dim), w.shape[dim]

    def _gather_bwd(dim, local_len, ct):
        # psum + local slice instead of reduce-scatter: the native
        # all_gather VJP (psum_scatter) trips an XLA CHECK ("Invalid binary
        # instruction opcode copy") inside vjp'd scans at >=64 host devices
        ct = jax.lax.psum(ct, "data")
        idx = jax.lax.axis_index("data") * local_len
        return (jax.lax.dynamic_slice_in_dim(ct, idx, local_len, axis=dim),)

    _gather_cv.defvjp(_gather_fwd, _gather_bwd)

    def gather_leaf(w, dim):
        if dim < 0:
            return w
        return _gather_cv(w, dim)

    # per-layer gather callables threaded to the layer scans via gather_specs
    gtree = {}
    for sub in ("layers", "groups", "enc_layers", "dec_layers", "tail"):
        if isinstance(gdims, dict) and sub in gdims:
            if sub == "tail":            # tail leaves are unstacked
                dsub = gdims[sub]
            else:                        # scanned leaves lose the layer dim
                dsub = jax.tree.map(lambda d: d - 1 if d >= 1 else -1,
                                    gdims[sub])
            gtree[sub] = jax.tree.map(
                lambda d: (lambda w, d=d: gather_leaf(w, d)), dsub)
    any_fsdp = any(d >= 0 for d in jax.tree.leaves(gdims))

    def local_step(params, batch):
        # every gather happens INSIDE the differentiated region, so each
        # fsdp leaf's gradient comes back local & data-reduced via the
        # all_gather VJP (reduce-scatter)
        def loss_of(p, b):
            p = dict(p)
            for k in ("embed", "head"):
                if k in p:
                    p[k] = gather_leaf(p[k], gdims[k])
            return T.loss_fn(p, cfg, b,
                             gather_specs=gtree if any_fsdp else None)

        def grads_of(p, b):
            return jax.value_and_grad(loss_of, has_aux=True)(p, b)

        p2 = params
        if microbatches == 1:
            (loss, parts), grads = grads_of(p2, batch)
        else:
            m = microbatches

            def split_tree(b):
                out = {}
                for k, v in b.items():
                    if k == "position_ids":
                        out[k] = v.reshape(
                            (3, v.shape[1] // m, m) + v.shape[2:]) \
                            .transpose(2, 0, 1, *range(3, v.ndim + 1))
                    else:
                        out[k] = v.reshape(
                            (v.shape[0] // m, m) + v.shape[1:]).swapaxes(0, 1)
                return out

            def body(carry, mb):
                gsum, lsum, csum, asum = carry
                (l, parts), g = grads_of(p2, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l, csum + parts["ce"],
                        asum + parts["aux"]), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), p2)
            z = jnp.zeros((), jnp.float32)
            (gsum, lsum, csum, asum), _ = jax.lax.scan(
                body, (g0, z, z, z), split_tree(batch))
            grads = jax.tree.map(lambda g: g / m, gsum)
            loss, parts = lsum / m, {"ce": csum / m, "aux": asum / m}

        # fsdp leaves are already local + data-reduced (all_gather VJP =
        # reduce-scatter); replicated leaves get their single psum here.
        # Every sync divides by the shard count: each shard's loss is a
        # LOCAL mean, so the sum over shards must be averaged back.
        nsh = 1
        for a in manual:
            nsh *= mesh.shape[a]

        def sync(g, dim):
            if dim >= 0:
                if "pod" in manual:
                    g = jax.lax.psum(g, "pod")
                return g / nsh
            if compress_pod and "pod" in manual:
                g = jax.lax.psum(g, "data")
                scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
                q = jnp.clip(jnp.round(g / scale), -127, 127)
                return jax.lax.psum(q, "pod") * scale / nsh  # int8 payload
            return jax.lax.psum(g, manual) / nsh

        grads = jax.tree.map(sync, grads, gdims)
        loss = jax.lax.pmean(loss, manual)
        parts = jax.tree.map(lambda x: jax.lax.pmean(x, manual), parts)
        return grads, loss, parts

    bspecs_m = jax.tree.map(manualize, batch_specs,
                            is_leaf=lambda x: isinstance(x, P))
    smapped = jax.shard_map(
        local_step, mesh=mesh, in_specs=(pspecs_m, bspecs_m),
        out_specs=(pspecs_m, P(), P()),
        axis_names=set(manual), check_vma=False)

    def train_step(params, opt_state, batch):
        grads, loss, parts = smapped(params, batch)
        update = adamw8bit_update if opt_impl == "adamw8bit" else adamw_update
        new_params, new_opt, om = update(grads, opt_state, params, opt_cfg)
        return new_params, new_opt, {"loss": loss, "ce": parts["ce"],
                                     "aux": parts["aux"], **om}

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, parts = T.loss_fn(params, cfg, batch)
        return {"loss": loss, **parts}
    return eval_step


def make_prefill(cfg: ModelConfig, max_len: int, gather_specs=None):
    """(params, batch) -> (logits (B, V), cache)."""

    def serve_prefill(params, batch):
        extras = {k: v for k, v in batch.items() if k != "tokens"}
        return dec.prefill(params, cfg, batch["tokens"], extras,
                           max_len=max_len, gather_specs=gather_specs)

    return serve_prefill


def make_serve_step(cfg: ModelConfig, gather_specs=None):
    """(params, cache, tokens, pos[, extras]) -> (logits, cache')."""

    def serve_step(params, cache, tokens, pos, extras=None):
        return dec.decode_step(params, cfg, cache, tokens, pos, extras,
                               gather_specs=gather_specs)

    return serve_step


def make_serve_chunk_step(cfg: ModelConfig, spec, gather_specs=None,
                          mode: str = "scan", chunk_kernel: str = "dense"):
    """(params, cache, tokens (B,C), pos, n_tokens[, extras]) ->
    (last-active-token logits, cache').  The continuous-batching mixed
    step: prefill chunks and decode streams share one batched call with
    per-stream lengths (``spec`` is the cache's ``CacheViewSpec``).

    ``mode`` selects the SECOND COMPILED PATH: "scan" (the reference —
    ``chunk_decode_step`` masks a per-token scan of ``decode_step``, bit-
    identical to single-token stepping, C sequential model steps per
    chunk) or "parallel" (``prefill_chunk_step`` — one fused multi-token
    forward per tick, matching the scan to tolerance).  ``chunk_kernel``
    picks the parallel path's attention: "dense" (einsum reference) or
    "blocked" (Pallas online-softmax tiles); the scan path ignores it."""
    if mode not in ("scan", "parallel"):
        raise ValueError(f"unknown chunk-step mode {mode!r}")
    if chunk_kernel not in ("dense", "blocked"):
        raise ValueError(f"unknown chunk kernel {chunk_kernel!r}")

    def serve_chunk_step(params, cache, tokens, pos, n_tokens, extras=None):
        if mode == "parallel":
            return dec.prefill_chunk_step(params, cfg, spec, cache, tokens,
                                          pos, n_tokens, extras,
                                          gather_specs=gather_specs,
                                          chunk_kernel=chunk_kernel)
        return dec.chunk_decode_step(params, cfg, spec, cache, tokens, pos,
                                     n_tokens, extras)

    return serve_chunk_step


def make_spec_verify_step(cfg: ModelConfig, spec, gather_specs=None,
                          mode: str = "scan", chunk_kernel: str = "dense"):
    """(params, cache, tokens (B,C), pos, n_tokens[, extras]) ->
    (per-position logits (B, C, V), cache').  The speculative-decode
    VERIFY step: same masked chunk forward as ``make_serve_chunk_step``
    (same ``mode`` / ``chunk_kernel`` contract) but it returns the logits
    after EVERY fed token, not just the last active one — the engine
    compares each draft token against the argmax one position earlier and
    keeps the longest matching prefix, so greedy output is token-identical
    to non-speculative decoding by construction.  Positions at or past
    ``n_tokens[i]`` come back NEG_INF-poisoned; the host must still gate
    on its own lengths before trusting an argmax."""
    if mode not in ("scan", "parallel"):
        raise ValueError(f"unknown chunk-step mode {mode!r}")
    if chunk_kernel not in ("dense", "blocked"):
        raise ValueError(f"unknown chunk kernel {chunk_kernel!r}")

    def spec_verify_step(params, cache, tokens, pos, n_tokens, extras=None):
        if mode == "parallel":
            return dec.prefill_chunk_step(params, cfg, spec, cache, tokens,
                                          pos, n_tokens, extras,
                                          gather_specs=gather_specs,
                                          chunk_kernel=chunk_kernel,
                                          all_logits=True)
        return dec.chunk_decode_step(params, cfg, spec, cache, tokens, pos,
                                     n_tokens, extras, all_logits=True)

    return spec_verify_step


def make_spill_gather(spec):
    """(storage, blocks, state_slot) -> host leaf list.  The device->host
    half of a swap-tier KV spill: DMAs exactly a stream's used pages (and
    state slot) out of the block pool (``spec`` is the pool's
    ``CacheViewSpec``)."""

    def spill_gather(storage, blocks, state_slot=None):
        return dec.extract_pool_entries(storage, spec, blocks,
                                        state_slot=state_slot)

    return spill_gather


def make_spill_scatter(spec):
    """(storage, blocks, host_leaves, state_slot) -> storage'.  The
    host->device half of a swap-tier restore: writes spilled pages back
    into a fresh reservation's physical blocks."""

    def spill_scatter(storage, blocks, host_leaves, state_slot=None):
        return dec.insert_pool_entries(storage, spec, blocks, host_leaves,
                                       state_slot=state_slot)

    return spill_scatter


def make_spill_gather_async(spec):
    """(storage, blocks, state_slot) -> DEVICE leaf list.  The issue half
    of an asynchronous spill: same payload as ``make_spill_gather`` but
    the gather only dispatches — the transfer engine polls ``.is_ready()``
    and lands the bytes into the swap tier at the fence."""

    def spill_gather_async(storage, blocks, state_slot=None):
        return dec.extract_pool_entries_async(storage, spec, blocks,
                                              state_slot=state_slot)

    return spill_gather_async


def make_rows_gather(spec):
    """(storage, blocks, state_slots) -> device leaf list.  One batched
    gather of MANY streams' pages + state slots — the spec-decode
    checkpoint path, all drafted rows snapshotted in a single device
    copy."""

    def rows_gather(storage, blocks, state_slots=()):
        return dec.gather_pool_rows(storage, spec, blocks,
                                    state_slots=state_slots)

    return rows_gather


def make_rows_scatter(spec):
    """(storage, blocks, leaves, state_slots) -> storage'.  Batched
    inverse of ``make_rows_gather`` for the rows that roll back."""

    def rows_scatter(storage, blocks, leaves, state_slots=()):
        return dec.scatter_pool_rows(storage, spec, blocks, leaves,
                                     state_slots=state_slots)

    return rows_scatter


def make_prefix_fork(spec):
    """(storage, src_blocks, dst_blocks[, src_state, dst_state]) ->
    storage'.  The device-side copy behind prefix-sharing copy-on-write:
    duplicate a shared ring page into a private block before a stream
    writes it (the divergence / ring-wrap fork), and/or fork a carried
    rgLRU/SSD state slot — a prefix-cache hit copying the donor's
    checkpoint at the match boundary into the new stream's slot, or
    registration snapshotting a checkpoint the other way."""

    def prefix_fork(storage, src_blocks, dst_blocks,
                    src_state=None, dst_state=None):
        if not src_blocks and src_state is not None:
            if src_state == 0:          # no donor: scrub to the init state
                return dec.zero_state_slot(storage, spec, dst_state)
            return dec.fork_state_slot(storage, spec, src_state, dst_state)
        return dec.copy_pool_entries(storage, spec, src_blocks, dst_blocks,
                                     src_state=src_state,
                                     dst_state=dst_state)

    return prefix_fork


def make_generate(cfg: ModelConfig, steps: int, temperature: float = 0.0):
    """Greedy/temperature loop over serve_step (used by examples/serving)."""
    serve_step = make_serve_step(cfg)

    def generate(params, cache, tokens, pos, key):
        def body(carry, _):
            cache, tok, pos, key = carry
            logits, cache = serve_step(params, cache, tok, pos)
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / temperature, -1)
            else:
                nxt = jnp.argmax(logits, -1)
            nxt = nxt[:, None].astype(jnp.int32)
            return (cache, nxt, pos + 1, key), nxt[:, 0]

        (cache, _, pos, _), toks = jax.lax.scan(
            body, (cache, tokens, pos, key), None, length=steps)
        return toks.T, cache, pos  # (B, steps)

    return generate
