import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  This script — and only this script — sees 512
# placeholder CPU devices standing in for the production TPU fleet.

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable  # noqa: E402
from repro.launch import analysis as an          # noqa: E402
from repro.launch import hlo_analysis as ha      # noqa: E402
from repro.launch import sharding as sh          # noqa: E402
from repro.launch.inputs import input_specs, ENCDEC_SRC_LEN  # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.launch.steps import (make_prefill, make_serve_step,   # noqa: E402
                                make_train_step, make_train_step_smap)
from repro.core.costmodel import model_flops     # noqa: E402
from repro.models.params import abstract_params  # noqa: E402
from repro.optim.adamw import AdamWConfig, init_opt_state    # noqa: E402
from repro.optim.quantized import init_opt_state_8bit        # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _shard_abstract(tree, mesh, specs):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree, specs, is_leaf=lambda x: hasattr(x, "shape"))


def cell_config(arch: str, shape_name: str):
    cfg = get_config(arch)
    cfg = dataclasses.replace(cfg, head_pad_to=16)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        # "full" remat (only the per-layer residual carry is checkpointed;
        # the "block" dots policy would save every projection output) +
        # 8-way microbatch gradient accumulation so saved activations scale
        # with the microbatch.  Sequence-sharding the carry (Megatron-SP)
        # was tried and REVERTED: GSPMD resolves the seq-sharded carry vs
        # the q-block dynamic-slice by involuntary full rematerialization
        # (see EXPERIMENTS.md §Perf, hypothesis log).
        cfg = dataclasses.replace(cfg, remat="full", seq_shard=False)
    return cfg, shape


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             skip_analysis: bool = False, spread_rate: int | None = None,
             tag: str = "", train_impl: str = "gspmd",
             microbatches: int = 8) -> dict:
    """Lower + compile one (arch x shape x mesh) cell; return the record."""
    cfg, shape = cell_config(arch, shape_name)
    if multi_pod:
        cfg = dataclasses.replace(cfg, batch_axes=("pod", "data"))
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    fsdp = sh.needs_fsdp(cfg, shape, chips, mesh.shape["model"])
    pspecs = sh.param_specs(cfg, mesh, fsdp=fsdp)
    gspecs = sh.gather_specs(cfg, mesh) if fsdp else None
    aparams = _shard_abstract(abstract_params(cfg), mesh, pspecs)

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        # FSDP-scale models (grok-1): 8-bit moments, else f32 moments would
        # not leave room for params+grads on a 16 GB chip.
        opt_impl = "adamw8bit" if fsdp else "adamw"
        init_fn = init_opt_state_8bit if fsdp else init_opt_state
        aopt = jax.eval_shape(init_fn, aparams)
        ospecs = sh.opt_specs_for(cfg, mesh, pspecs, aopt)
        aopt = _shard_abstract(aopt, mesh, ospecs)
        batch = input_specs(cfg, shape, mesh)
        if train_impl == "smap":
            bsp = sh.batch_specs(cfg, shape, mesh)
            bsp = {k: v for k, v in bsp.items() if k in batch}
            step = make_train_step_smap(
                cfg, opt_cfg, mesh, pspecs, bsp,
                microbatches=microbatches, opt_impl=opt_impl,
                compress_pod=multi_pod)
        else:
            step = make_train_step(cfg, opt_cfg, microbatches=microbatches,
                                   opt_impl=opt_impl, gather_specs=gspecs)
        psh = sh.named(mesh, pspecs)
        osh = sh.named(mesh, ospecs)
        jitted = jax.jit(step, out_shardings=(psh, osh, None),
                         donate_argnums=(0, 1))
        with mesh:
            lowered = jitted.lower(aparams, aopt, batch)
    elif shape.kind == "prefill":
        batch = input_specs(cfg, shape, mesh)
        step = make_prefill(cfg, max_len=shape.seq_len, gather_specs=gspecs)
        with mesh:
            lowered = jax.jit(step).lower(aparams, batch)
    else:
        ins = input_specs(cfg, shape, mesh)
        step = make_serve_step(cfg, gather_specs=gspecs)
        args = (aparams, ins["cache"], ins["tokens"], ins["pos"])
        jitted = jax.jit(step, donate_argnums=(1,))   # cache updated in place
        with mesh:
            if "extras" in ins:
                lowered = jitted.lower(*args, ins["extras"])
            else:
                lowered = jitted.lower(*args)

    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
        "output_bytes": getattr(ma, "output_size_in_bytes", 0),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
    }
    mem["peak_per_device"] = (mem["argument_bytes"] + mem["output_bytes"]
                              + mem["temp_bytes"] - mem["alias_bytes"])
    mem["fits_hbm_16gb"] = bool(mem["peak_per_device"] <= 16e9)

    hlo = compiled.as_text()
    colls = ha.collective_bytes(hlo, multi_pod=multi_pod)
    ca = compiled.cost_analysis()

    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "tag": tag, "status": "ok",
        "chips": chips, "fsdp": fsdp, "remat": cfg.remat,
        "compile_s": round(t_compile, 1),
        "memory": mem,
        "collectives": {
            "per_class_bytes": colls.per_class_bytes,
            "per_op_bytes": colls.per_op_bytes,
            "n_ops": colls.n_ops,
            "total_per_dev": colls.total_bytes,
            "remote_per_dev": colls.remote_bytes,
        },
        "full_step_cost_analysis": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "note": "while bodies counted once; see decomposed",
        },
    }

    if not skip_analysis:
        t1 = time.time()
        dc = an.decomposed_cost(cfg, shape, mesh, fsdp=fsdp)
        mf = model_flops(cfg, shape)
        hbm_lb = an.analytic_hbm_bytes(
            cfg, shape, mesh, fsdp=fsdp,
            microbatches=8 if shape.kind == "train" else 1)
        rl = ha.roofline(
            flops_per_dev=dc["flops_per_dev"],
            bytes_per_dev=hbm_lb,
            coll_bytes_per_dev=colls.total_bytes,
            model_flops_total=mf, chips=chips)
        rec["decomposed"] = {k: v for k, v in dc.items() if k != "detail"}
        rec["decomposed"]["detail"] = dc["detail"]
        rec["roofline"] = rl.to_dict()
        rec["roofline"]["bytes_per_dev_hlo_upper"] = dc["bytes_per_dev"]
        rec["roofline"]["memory_s_hlo_upper"] = dc["bytes_per_dev"] / ha.HBM_BW
        rec["analysis_s"] = round(time.time() - t1, 1)

    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="architecture id (or all)")
    ap.add_argument("--shape", default=None, help="shape name (or all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-analysis", action="store_true",
                    help="compile + memory + collectives only")
    ap.add_argument("--train-impl", default="gspmd",
                    choices=["gspmd", "smap"])
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--suffix", default="")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args(argv)

    archs = list(ARCH_IDS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                name = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}"
                name += args.suffix
                path = os.path.join(args.out, name + ".json")
                try:
                    # roofline decomposition is a single-pod deliverable;
                    # multi-pod cells prove compile + sharding + memory
                    rec = run_cell(arch, shape, multi_pod=mp,
                                   skip_analysis=args.skip_analysis or mp,
                                   train_impl=args.train_impl,
                                   microbatches=args.microbatches,
                                   tag=args.train_impl)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "status": "error", "error": repr(e)}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1, default=float)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f" compile={rec['compile_s']}s "
                             f"mem/dev={rec['memory']['peak_per_device']/1e9:.2f}GB "
                             f"coll/dev={rec['collectives']['total_per_dev']/1e9:.3f}GB")
                    if "roofline" in rec:
                        r = rec["roofline"]
                        extra += (f" dom={r['dominant']}"
                                  f" frac={r['roofline_fraction']:.3f}")
                print(f"[dryrun] {name}: {status}{extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
