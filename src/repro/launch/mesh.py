"""Production meshes.

``make_production_mesh`` is the pinned deliverable mesh: a 16x16 pod
(256 chips; axes data x model) or 2x16x16 (512 chips; pod x data x model).
Defined as a function so importing this module never touches jax device
state.

In ARCAS terms the production mesh is the ``spread_rate = 1`` layout: each
model line of 16 chips is one contiguous chiplet group (ICI neighborhood).
The layout *family* around it — (256/m, m) factorizations with
locality-aware device order — comes from ``repro.core.layout.Layout``.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # jax < 0.5 has no AxisType / axis_types kwarg (Auto is the default)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def batch_axes(mesh) -> tuple:
    """Mesh axes that carry the batch dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]


def data_axis_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n
