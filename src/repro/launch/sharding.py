"""Sharding rules: logical parameter axes -> mesh PartitionSpecs.

Default mapping (the "megatron" discipline):
  vocab/heads/kv_heads/ff/lru/ssd_* -> "model"   (when divisible)
  embed (d_model)                   -> None, or "data"-sharded under FSDP
  expert                            -> None (TP runs inside each expert)
  batch dims                        -> ("pod","data")

FSDP (weight sharding over the data axis with per-layer all-gather) turns on
automatically when the training-state bytes per chip would exceed the HBM
budget — grok-1-314B needs it on 256 chips; see ``needs_fsdp``.

Non-divisible dims are replicated (llama3.2's 24 heads and qwen2's 12 heads
against a model axis of 16) — recorded per-cell in the roofline notes.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.params import logical_axes, model_def, param_bytes, n_params


HBM_PER_CHIP = 16e9
TRAIN_BYTES_PER_PARAM = 12.0   # bf16 p + bf16 g + f32 m + f32 v


def needs_fsdp(cfg: ModelConfig, shape: ShapeConfig, n_chips: int,
               model_size: int) -> bool:
    if shape.kind != "train":
        # serving: params only; spread over model axis must fit
        return (param_bytes(cfg) / model_size) > 0.6 * HBM_PER_CHIP
    per_chip = n_params(cfg) * TRAIN_BYTES_PER_PARAM / n_chips
    return per_chip > 0.35 * HBM_PER_CHIP


def axis_rules(cfg: ModelConfig, mesh, *, fsdp: bool) -> Dict[str, Optional[str]]:
    msize = mesh.shape["model"]
    dname = "data"

    def fits(dim: int) -> Optional[str]:
        return "model" if dim % msize == 0 and dim >= msize else None

    def fits_heads(hq: int, hkv: int) -> Optional[str]:
        if not hq:
            return None
        if hq % msize == 0:
            return "model"
        if cfg.head_pad_to:
            # compute-time group padding makes the activation shardable,
            # but the PARAM stays at hq heads -> keep params replicated
            return None
        return None

    return {
        "vocab": "model",                       # GSPMD pads uneven vocab
        "embed": dname if fsdp else None,
        "heads": fits_heads(cfg.n_heads or 0, cfg.n_kv_heads or 0),
        "kv_heads": fits(cfg.n_kv_heads or 0),
        "ff": fits(cfg.d_ff or 0),
        "expert": None,
        "lru": fits(cfg.lru_width or 0),
        "ssd_inner": fits(cfg.d_inner if cfg.ssm_state else 0),
        "ssd_bc": fits(cfg.ssm_groups * cfg.ssm_state if cfg.ssm_state else 0),
        "ssd_heads": fits(cfg.ssm_heads if cfg.ssm_state else 0),
        "layer": None,
        None: None,
    }


def param_specs(cfg: ModelConfig, mesh, *, fsdp: bool = False):
    """Pytree of PartitionSpecs matching ``model_def`` params."""
    rules = axis_rules(cfg, mesh, fsdp=fsdp)
    axes = logical_axes(cfg)

    def to_spec(ax_tuple):
        spec = []
        used = set()
        for ax in ax_tuple:
            m = rules.get(ax)
            if m is None or m in used:
                spec.append(None)
            else:
                spec.append(m)
                used.add(m)
        return P(*spec)

    specs = jax.tree.map(to_spec, axes,
                         is_leaf=lambda x: isinstance(x, tuple))
    if not cfg.tie_embeddings:
        # Untied table: shard d_model, not vocab, so the token gather stays
        # local (a vocab-sharded table forces a full-table all-gather).
        # Under FSDP the vocab dim absorbs the data axis.
        specs["embed"] = P("data" if fsdp else None, "model")
    return specs


def opt_specs(cfg: ModelConfig, mesh, pspecs, *, zero: bool = True):
    """ZeRO-1: moments take the param spec + 'data' on the first replicated
    divisible dim.  ``count`` stays replicated."""
    defs = model_def(cfg)
    dsize = mesh.shape["data"]

    def zspec(spec, pdef):
        if not zero:
            return spec
        parts = list(spec) + [None] * (len(pdef.shape) - len(spec))
        if "data" in parts:        # already data-sharded (FSDP params)
            return P(*parts)
        for i, (ax, dim) in enumerate(zip(parts, pdef.shape)):
            if ax is None and dim % dsize == 0 and dim >= dsize:
                parts[i] = "data"
                break
        return P(*parts)

    from repro.models.params import ParamDef
    mv = jax.tree.map(zspec, pspecs, defs,
                      is_leaf=lambda x: isinstance(x, (P, ParamDef)))
    return {"m": mv, "v": mv, "count": P()}


def gather_specs(cfg: ModelConfig, mesh):
    """Per-layer compute-time weight specs (FSDP gather targets).

    Under FSDP, weights at rest are sharded over ("data", "model"); inside
    the layer scan each layer's weights must be explicitly constrained back
    to their model-only specs, otherwise GSPMD contracts over the data axis
    and replicates the *batch* instead (observed on grok-1).  Returns a
    pytree shaped like the scanned param subtrees with the leading 'layer'
    axis stripped.
    """
    full = param_specs(cfg, mesh, fsdp=False)

    def strip(spec):
        return P(*spec[1:]) if len(spec) else spec

    out = {}
    for key in ("layers", "groups", "enc_layers", "dec_layers"):
        if key in full:
            out[key] = jax.tree.map(strip, full[key],
                                    is_leaf=lambda x: isinstance(x, P))
    if "tail" in full:
        out["tail"] = full["tail"]
    return out


def opt_specs_for(cfg: ModelConfig, mesh, pspecs, aopt, *, zero: bool = True):
    """Specs matching an abstract opt-state pytree (f32 or 8-bit moments).

    8-bit moments are {"q": int8 like param, "scale": f32 (..., 1)}: q takes
    the ZeRO'd param spec; scale takes the same spec with the last dim
    replicated."""
    base = opt_specs(cfg, mesh, pspecs, zero=zero)

    def is8(x):
        return isinstance(x, dict) and set(x) == {"q", "scale"}

    sample = jax.tree.leaves(aopt["m"], is_leaf=is8)
    if not sample or not is8(sample[0]):
        return base

    def expand(spec):
        parts = list(spec)
        return {"q": spec, "scale": P(*parts[:-1], None) if parts else P()}

    mv = jax.tree.map(expand, base["m"], is_leaf=lambda x: isinstance(x, P))
    return {"m": mv, "v": mv, "count": P()}


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """PartitionSpecs for the input batch pytree."""
    b = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bp = P(b)
    specs = {"tokens": P(b, None), "targets": P(b, None), "mask": P(b, None)}
    if cfg.family == "vlm":
        specs["vision_embeds"] = P(b, None, None)
        specs["position_ids"] = P(None, b, None)
    if cfg.family == "encdec":
        specs["frame_embeds"] = P(b, None, None)
    return specs


def cache_specs(cfg: ModelConfig, mesh, cache_tree):
    """Decode cache: batch over ("pod","data"); the long axis over "model".

    Attention KV rings shard their window axis over "model" (decode-time
    context parallelism: scores stay sharded, softmax reduces with a tiny
    all-reduce).  Recurrent/SSM states shard channels/heads over "model".
    """
    b = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    msize = mesh.shape["model"]

    bshards = 1
    for a in b:
        bshards *= mesh.shape[a]

    def spec_for(path, leaf):
        # rank-agnostic (leading layer dims optional):
        #   (..., B, W, Hkv, dh) attn/cross; (..., B, K-1, C) conv;
        #   (..., B, H, P, N) ssm; (..., B, W_lru) lru h state
        nd = leaf.ndim
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        parts = [None] * nd

        def set_model(idx, dim):
            if dim % msize == 0 and dim >= msize:
                parts[idx] = "model"

        if name in ("k", "v") or name.startswith("cross_"):
            bi = nd - 4
            set_model(nd - 3, leaf.shape[nd - 3])       # window axis
        elif name == "h":
            bi = nd - 2
            set_model(nd - 1, leaf.shape[nd - 1])       # lru width
        elif name == "ssm":
            bi = nd - 4
            set_model(nd - 3, leaf.shape[nd - 3])       # heads
        elif name.startswith("conv"):
            bi = nd - 3
            set_model(nd - 1, leaf.shape[nd - 1])       # channels
        else:
            return P(*parts)
        if leaf.shape[bi] % bshards == 0 and leaf.shape[bi] >= bshards:
            parts[bi] = b
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec_for, cache_tree)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
