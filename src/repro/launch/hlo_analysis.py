"""Post-compile HLO analysis: collective bytes (trip-count aware) + roofline.

``cost_analysis()`` counts a while-loop body ONCE (verified empirically), so
every quantity extracted from a scanned program must be multiplied by the
loop trip count.  This module parses the optimized HLO text of the compiled
per-device module:

  * builds a computation table (name -> instruction lines)
  * finds while ops, extracts each loop's trip count from its condition
    (the ``compare(get-tuple-element, constant)`` pattern), and propagates
    nested multipliers
  * sums collective operand bytes x multiplier, classified by link class
    (intra-group / cross-group / cross-pod) from the replica groups and the
    production mesh coordinate map.

All byte numbers are PER DEVICE (the compiled module is the per-device SPMD
program), so roofline terms divide by per-chip peaks directly.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}


def shape_bytes(type_str: str) -> int:
    """'f32[128,1024]{1,0}' -> bytes.  Tuples handled by summing."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    op: str
    line: str


def parse_computations(hlo: str) -> Dict[str, List[Instruction]]:
    """Computation headers look like ``%name (args...) -> type {`` where the
    argument list may contain nested parens (tuple types), so headers are
    detected structurally (assignment-free line with '->' ending in '{')."""
    comps: Dict[str, List[Instruction]] = {}
    current = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if ("->" in stripped and stripped.endswith("{")
                and "=" not in stripped.split("(")[0]):
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", stripped)
            if m:
                current = m.group(1)
                comps[current] = []
                continue
        if current is None:
            continue
        im = re.match(r"(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\]{},\d/ ]+?))\s+([\w\-]+)\(", stripped)
        if im:
            comps[current].append(Instruction(
                name=im.group(1), type_str=im.group(2),
                op=im.group(3), line=stripped))
    return comps


def while_trip_counts(comps: Dict[str, List[Instruction]]) -> Dict[str, float]:
    """computation name -> multiplier (product of enclosing loop trips)."""
    # find while ops: body=%X, condition=%Y
    body_of: Dict[str, Tuple[str, str, str]] = {}  # body comp -> (cond, parent, while name)
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.line)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                if bm and cm:
                    body_of[bm.group(1)] = (cm.group(1), cname, ins.name)

    def trip_of_cond(cond_name: str) -> float:
        best = None
        for ins in comps.get(cond_name, []):
            if ins.op == "constant":
                m = re.search(r"constant\((-?\d+)\)", ins.line)
                if m:
                    v = int(m.group(1))
                    if v > 0:
                        best = v if best is None else max(best, v)
        return float(best) if best else 1.0

    # multiplier of a computation = product over chain of enclosing whiles
    mult: Dict[str, float] = {}

    def resolve(comp: str, seen=()) -> float:
        if comp in mult:
            return mult[comp]
        if comp in seen:
            return 1.0
        m = 1.0
        if comp in body_of:
            cond, parent, _ = body_of[comp]
            m = trip_of_cond(cond) * resolve(parent, seen + (comp,))
        mult[comp] = m
        return m

    for comp in comps:
        resolve(comp)
    # computations called from loop bodies (fusions etc.) are inlined in HLO
    # text as separate computations referenced via calls= / to_apply=; their
    # instructions' collectives appear at the call site in optimized HLO, so
    # body-level multipliers suffice.
    return mult


def while_loops(comps: Dict[str, List[Instruction]]) -> Dict[str, float]:
    """body computation name -> that loop's OWN trip count (no enclosing
    multipliers; see ``while_trip_counts`` for the propagated product)."""
    loops: Dict[str, float] = {}
    for instrs in comps.values():
        for ins in instrs:
            if ins.op != "while":
                continue
            bm = re.search(r"body=%?([\w\.\-]+)", ins.line)
            cm = re.search(r"condition=%?([\w\.\-]+)", ins.line)
            if not (bm and cm):
                continue
            best = None
            for ci in comps.get(cm.group(1), []):
                if ci.op == "constant":
                    m = re.search(r"constant\((-?\d+)\)", ci.line)
                    if m and int(m.group(1)) > 0:
                        v = int(m.group(1))
                        best = v if best is None else max(best, v)
            loops[bm.group(1)] = float(best) if best else 1.0
    return loops


def model_steps_per_call(hlo: str, layer_trips) -> float:
    """Sequential MODEL steps one call of a compiled serve step executes,
    measured from the optimized HLO rather than assumed structurally.

    A "model step" is one trip through the per-layer scan, so the layer
    loop is the probe: find the while loop whose own trip count matches a
    known layer-scan length (``layer_trips`` — n_layers, hybrid n_groups,
    or enc-dec dec_layers) and divide its PROPAGATED multiplier by that
    trip.  A fused chunk step leaves the layer loop at top level
    (multiplier == trip -> 1 step); the scan-mode reference nests it in a
    C-trip token loop (multiplier == C * trip -> C steps).  If XLA
    unrolled the layer scan entirely, fall back to the deepest surviving
    loop's multiplier (a remaining token loop still reports its C; a
    fully unrolled program is 1 step).  This is what makes the
    accepted-tokens-per-model-step metric MEASURED: a "fused" path that
    actually compiled to a token loop shows its real step count here."""
    comps = parse_computations(hlo)
    mult = while_trip_counts(comps)
    loops = while_loops(comps)
    probe = set(float(t) for t in layer_trips)
    cands = [mult.get(b, 1.0) / t for b, t in loops.items() if t in probe]
    if cands:
        return max(cands)
    return max((mult.get(b, 1.0) for b in loops), default=1.0)


# ---------------------------------------------------------------------------
# Replica-group parsing + link classification
# ---------------------------------------------------------------------------

def parse_replica_groups(line: str) -> Optional[List[List[int]]]:
    m = re.search(r"replica_groups=\{(\{[^=]*\})\}", line)
    if m:
        groups = re.findall(r"\{([\d,]+)\}", m.group(1))
        return [[int(x) for x in g.split(",")] for g in groups]
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
                  line)
    if m:
        ngroups, gsize = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        flat = ids.reshape(ngroups, gsize)
        return [list(map(int, row)) for row in flat]
    return None


def classify_group(devs: List[int], *, multi_pod: bool) -> str:
    """Production-mesh coords: id = ((pod*16)+data)*16 + model."""
    def coords(d):
        model = d % 16
        rest = d // 16
        if multi_pod:
            return rest // 16, rest % 16, model  # pod, data, model
        return 0, rest, model

    cs = [coords(d) for d in devs]
    pods = {c[0] for c in cs}
    rows = {(c[0], c[1]) for c in cs}
    if len(pods) > 1:
        return "cross_pod"
    if len(rows) > 1:
        return "intra_pod"       # crosses chiplet groups within a pod
    return "intra_group"


# ---------------------------------------------------------------------------
# Collective bytes
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CollectiveStats:
    per_class_bytes: Dict[str, float]
    per_op_bytes: Dict[str, float]
    n_ops: int
    details: List[Dict]

    @property
    def total_bytes(self) -> float:
        return sum(self.per_class_bytes.values())

    @property
    def remote_bytes(self) -> float:
        return (self.per_class_bytes.get("intra_pod", 0.0)
                + self.per_class_bytes.get("cross_pod", 0.0))


def collective_bytes(hlo: str, *, multi_pod: bool) -> CollectiveStats:
    comps = parse_computations(hlo)
    mult = while_trip_counts(comps)
    per_class: Dict[str, float] = {}
    per_op: Dict[str, float] = {}
    details = []
    n = 0
    for cname, instrs in comps.items():
        types = {ins.name: ins.type_str for ins in instrs}
        m = mult.get(cname, 1.0)
        for ins in instrs:
            base_op = ins.op.replace("-start", "")
            if base_op not in COLLECTIVE_OPS:
                continue
            if ins.op.endswith("-done"):
                continue
            # operand bytes: sum types of operand names
            ops = re.findall(r"\(([^)]*)\)", ins.line)
            operand_names = re.findall(r"%([\w\.\-]+)", ops[0]) if ops else []
            ob = sum(shape_bytes(types.get(o, "")) for o in operand_names)
            if ob == 0:
                ob = shape_bytes(ins.type_str)
            groups = parse_replica_groups(ins.line)
            cls = "intra_group"
            if groups:
                cls = classify_group(groups[0], multi_pod=multi_pod)
            b = ob * m
            per_class[cls] = per_class.get(cls, 0.0) + b
            per_op[base_op] = per_op.get(base_op, 0.0) + b
            n += 1
            details.append({"op": base_op, "comp": cname, "bytes": ob,
                            "mult": m, "class": cls})
    return CollectiveStats(per_class, per_op, n, details)


# ---------------------------------------------------------------------------
# Roofline
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    model_flops_total: float
    chips: int

    @property
    def dominant(self) -> str:
        t = {"compute": self.compute_s, "memory": self.memory_s,
             "collective": self.collective_s}
        return max(t, key=t.get)

    @property
    def hlo_flops_total(self) -> float:
        return self.flops_per_dev * self.chips

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (remat / redundancy waste indicator)."""
        return self.model_flops_total / max(self.hlo_flops_total, 1.0)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / achievable step time (higher is better)."""
        useful = self.model_flops_total / (self.chips * PEAK_FLOPS)
        return useful / max(self.bound_s, 1e-30)

    def to_dict(self):
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "flops_per_dev": self.flops_per_dev,
            "bytes_per_dev": self.bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "model_flops_total": self.model_flops_total,
            "chips": self.chips, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline(*, flops_per_dev: float, bytes_per_dev: float,
             coll_bytes_per_dev: float, model_flops_total: float,
             chips: int) -> Roofline:
    return Roofline(
        compute_s=flops_per_dev / PEAK_FLOPS,
        memory_s=bytes_per_dev / HBM_BW,
        collective_s=coll_bytes_per_dev / LINK_BW,
        flops_per_dev=flops_per_dev,
        bytes_per_dev=bytes_per_dev,
        coll_bytes_per_dev=coll_bytes_per_dev,
        model_flops_total=model_flops_total,
        chips=chips)
