"""Decomposed FLOP/byte accounting for the roofline.

``cost_analysis()`` counts while-loop bodies once, so the full scanned step
under-reports layer work by ~n_layers.  Instead we lower ONE layer of each
block type (attention block-loops statically unrolled, MoE token blocks
unrolled) on the same mesh/shardings, take its per-device cost, and combine:

    flops_dev = sum_type  count_type * k * flops_layer(B_eff)
    bytes_dev = sum_type  count_type * (W_local + k * (bytes_layer - W_local))

where B_eff is a reduced batch (1 sample per batch shard) and k the exact
linear scale back to the full batch — exact for everything linear in batch
(attention is quadratic in S but linear in B, so S stays full).  W_local
(per-device weight bytes) is computed exactly from the PartitionSpecs.

Analysis attention blocks are 2048x2048 — a realistic v5e VMEM-resident
flash-kernel tile, so the KV re-read factor in the byte term matches the
kernel the model would actually run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import sharding as sh
from repro.launch.inputs import seq_split, ENCDEC_SRC_LEN
from repro.models import layers as L
from repro.models import transformer as T
from repro.models import decode as dec
from repro.models.params import ParamDef, layer_def, model_def

ANALYSIS_BLOCK = 2048


def _is_def(x):
    return isinstance(x, ParamDef)


def _leaf_specs_for_layer(cfg, mesh, fsdp, ltype):
    """PartitionSpecs for ONE layer (no leading 'layer' axis)."""
    rules = sh.axis_rules(cfg, mesh, fsdp=fsdp)
    ldef = layer_def(cfg, ltype)

    def to_spec(pd: ParamDef):
        spec, used = [], set()
        for ax in pd.axes:
            m = rules.get(ax)
            if m is None or m in used:
                spec.append(None)
            else:
                spec.append(m)
                used.add(m)
        return P(*spec)

    return (jax.tree.map(to_spec, ldef, is_leaf=_is_def), ldef)


def _abstract_layer(cfg, mesh, specs, ldef):
    dt = jnp.dtype(cfg.param_dtype)
    return jax.tree.map(
        lambda pd, s: jax.ShapeDtypeStruct(pd.shape, dt,
                                           sharding=NamedSharding(mesh, s)),
        ldef, specs, is_leaf=_is_def)


def _local_weight_bytes(cfg, mesh, specs, ldef) -> float:
    """Exact per-device bytes of one layer's weights under the specs."""
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    total = 0.0
    for pd, spec in zip(jax.tree.leaves(ldef, is_leaf=_is_def),
                        jax.tree.leaves(specs,
                                        is_leaf=lambda x: isinstance(x, P))):
        shard = 1
        for ax in spec:
            if ax is not None:
                shard *= mesh.shape[ax]
        total += math.prod(pd.shape) * itemsize / shard
    return total


def _batch_shards(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def _bspec(mesh, B):
    b = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(b) if B % _batch_shards(mesh) == 0 and B >= _batch_shards(mesh) \
        else P()


@dataclasses.dataclass
class LayerCost:
    flops: float          # per device, full batch
    bytes: float          # per device, full batch


def _analysis_cfg(cfg: ModelConfig) -> ModelConfig:
    import dataclasses as dc
    return dc.replace(cfg, attn_block_q=ANALYSIS_BLOCK,
                      attn_block_kv=ANALYSIS_BLOCK,
                      moe_block=min(cfg.moe_block, 2048))


def _cost_of(fn, *args, mesh=None) -> Tuple[float, float]:
    if mesh is not None:
        with mesh:
            compiled = jax.jit(fn).lower(*args).compile()
    else:
        compiled = jax.jit(fn).lower(*args).compile()
    ca = compiled.cost_analysis()
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def layer_cost(cfg: ModelConfig, shape: ShapeConfig, mesh, *, fsdp: bool,
               ltype: str, train: bool, hybrid: bool = False,
               seq_len: Optional[int] = None) -> LayerCost:
    """Per-device cost of one block of ``ltype`` at the cell's shape."""
    acfg = _analysis_cfg(cfg)
    B = shape.global_batch
    S = seq_len if seq_len is not None else shape.seq_len
    shards = _batch_shards(mesh)
    if shape.is_decode:
        B_eff, k = B, 1.0
        S_eff = 1
    else:
        B_eff = shards if B % shards == 0 and B >= shards else B
        k = B / B_eff
        S_eff = S

    specs, ldef = _leaf_specs_for_layer(acfg, mesh, fsdp, ltype)
    lp = _abstract_layer(acfg, mesh, specs, ldef)
    bspec = _bspec(mesh, B_eff)
    xs = jax.ShapeDtypeStruct(
        (B_eff, S_eff, cfg.d_model), jnp.dtype(cfg.compute_dtype),
        sharding=NamedSharding(mesh, P(*bspec, None, None)))

    window = (cfg.local_window if hybrid else cfg.window) if ltype == "attn" \
        else 0

    if shape.is_decode:
        lc = dec._layer_cache(acfg, ltype, B_eff,
                              min(S, window) if window else S,
                              hybrid=hybrid)
        cspecs = sh.cache_specs(acfg, mesh, {"layers": lc})["layers"]
        lc = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(
                l.shape, l.dtype, sharding=NamedSharding(mesh, s)),
            jax.eval_shape(lambda: lc), cspecs)
        pos = jax.ShapeDtypeStruct((B_eff,), jnp.int32,
                                   sharding=NamedSharding(mesh, bspec))

        def f(x, lp, lc, pos):
            rope1 = (None if acfg.rope_type == "none" else
                     L.rope_tables(pos[:, None], acfg.head_dim,
                                   acfg.rope_theta))
            return dec._decode_layer(x, lp, lc, acfg, ltype, rope1, pos,
                                     hybrid=hybrid)

        flops, bts = _cost_of(f, xs, lp, lc, pos, mesh=mesh)
        return LayerCost(flops * k, bts * k)

    rope_static = None
    if acfg.rope_type == "rope" or (acfg.rope_type == "mrope"):
        # rope tables computed outside the layer in the real model; cheap
        rope_static = L.rope_tables(
            jnp.arange(S_eff)[None].astype(jnp.int32) *
            jnp.ones((B_eff, 1), jnp.int32), acfg.head_dim, acfg.rope_theta)

    def fwd(x, lp):
        y, _, aux = T.apply_layer(x, lp, acfg, "attn" if ltype == "enc"
                                  else ltype, rope_static, window=window,
                                  unroll=True, causal=ltype != "enc")
        return y

    if not train:
        flops, bts = _cost_of(fwd, xs, lp, mesh=mesh)
    else:
        body = fwd
        if cfg.remat != "none":
            policy = (jax.checkpoint_policies.nothing_saveable
                      if cfg.remat == "full" else
                      jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            body = jax.checkpoint(fwd, policy=policy)

        def fb(x, lp, ct):
            y, vjp = jax.vjp(body, x, lp)
            dx, dlp = vjp(ct)
            return y, dx, dlp

        flops, bts = _cost_of(fb, xs, lp, xs, mesh=mesh)

    wl = _local_weight_bytes(acfg, mesh, specs, ldef)
    return LayerCost(flops * k, wl + k * max(bts - wl, 0.0))


def outer_cost(cfg: ModelConfig, shape: ShapeConfig, mesh, *, fsdp: bool,
               train: bool) -> LayerCost:
    """Embedding + head + (train: chunked-CE loss fwd/bwd) per device."""
    acfg = _analysis_cfg(cfg)
    B = shape.global_batch
    shards = _batch_shards(mesh)
    if shape.is_decode:
        B_eff, k, S_eff = B, 1.0, 1
    else:
        B_eff = shards if B % shards == 0 and B >= shards else B
        k = B / B_eff
        S_eff, _ = seq_split(cfg, shape.seq_len)

    rules = sh.axis_rules(acfg, mesh, fsdp=fsdp)
    V = cfg.vocab_padded
    if cfg.tie_embeddings:
        vspec = P("model", rules["embed"])
    else:  # untied: d_model-sharded table (local gather)
        vspec = P("data" if fsdp else None, "model")
    embed = jax.ShapeDtypeStruct((V, cfg.d_model),
                                 jnp.dtype(cfg.param_dtype),
                                 sharding=NamedSharding(mesh, vspec))
    pouter = {"embed": embed}
    if not cfg.tie_embeddings:
        pouter["head"] = jax.ShapeDtypeStruct(
            (cfg.d_model, V), jnp.dtype(cfg.param_dtype),
            sharding=NamedSharding(mesh, P(rules["embed"], "model")))
    pouter["final_norm"] = jax.ShapeDtypeStruct(
        (cfg.d_model,), jnp.dtype(cfg.param_dtype),
        sharding=NamedSharding(mesh, P(None)))
    bspec = _bspec(mesh, B_eff)
    toks = jax.ShapeDtypeStruct((B_eff, S_eff), jnp.int32,
                                sharding=NamedSharding(mesh, P(*bspec, None)))
    xs = jax.ShapeDtypeStruct((B_eff, S_eff, cfg.d_model),
                              jnp.dtype(cfg.compute_dtype),
                              sharding=NamedSharding(mesh, P(*bspec, None, None)))

    if shape.is_decode:
        def f(p, tok, x):
            e = T.embed_tokens(p, acfg, tok)
            xn = L.rms_norm(x + 0 * e[:, :1], p["final_norm"], acfg.norm_eps)
            return T.head_logits(p, acfg, xn[:, 0])
        flops, bts = _cost_of(f, pouter,
                              jax.ShapeDtypeStruct(
                                  (B_eff, 1), jnp.int32,
                                  sharding=NamedSharding(mesh, P(*bspec, None))),
                              jax.ShapeDtypeStruct(
                                  (B_eff, 1, cfg.d_model),
                                  jnp.dtype(cfg.compute_dtype),
                                  sharding=NamedSharding(mesh,
                                                         P(*bspec, None, None))),
                              mesh=mesh)
        return LayerCost(flops * k, bts * k)

    mask = jax.ShapeDtypeStruct((B_eff, S_eff), jnp.float32,
                                sharding=NamedSharding(mesh, P(*bspec, None)))

    def f(p, tok, x, tgt, m):
        e = T.embed_tokens(p, acfg, tok)
        xn = L.rms_norm(x + e, p["final_norm"], acfg.norm_eps)
        return T.chunked_ce_loss(p, acfg, xn, tgt, m, unroll=True)

    if train:
        def g(p, tok, x, tgt, m):
            loss, vjp = jax.vjp(lambda p, x: f(p, tok, x, tgt, m), p, x)
            return loss, vjp(jnp.ones((), jnp.float32))
        flops, bts = _cost_of(g, pouter, toks, xs, toks, mask, mesh=mesh)
    else:
        flops, bts = _cost_of(f, pouter, toks, xs, toks, mask, mesh=mesh)

    # exact local weight bytes of embed/head
    wl = 0.0
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    msize = mesh.shape["model"]
    dsize = mesh.shape["data"] if fsdp else 1
    wl += cfg.vocab_padded * cfg.d_model * itemsize / (msize * dsize)
    if not cfg.tie_embeddings:
        wl += cfg.vocab_padded * cfg.d_model * itemsize / (msize * dsize)
    return LayerCost(flops * k, wl + k * max(bts - wl, 0.0))


def decomposed_cost(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                    fsdp: bool) -> Dict[str, float]:
    """Total per-device (flops, bytes) = sum over block types + outer."""
    train = shape.kind == "train"
    remat_note = cfg.remat
    counts: Dict[Tuple[str, bool], int] = {}
    if cfg.family == "encdec":
        counts[("enc", False)] = cfg.enc_layers
        counts[("dec", False)] = cfg.dec_layers
    else:
        for lt in cfg.layer_types():
            key = (lt, cfg.family == "hybrid")
            counts[key] = counts.get(key, 0) + 1

    flops = bts = 0.0
    detail = {}
    for (lt, hybrid), n in counts.items():
        if lt == "dec":
            lc = _decoder_layer_cost(cfg, shape, mesh, fsdp=fsdp, train=train)
        elif lt == "enc" and not shape.is_decode:
            _, ss = seq_split(cfg, shape.seq_len)
            lc = layer_cost(cfg, shape, mesh, fsdp=fsdp, ltype="enc",
                            train=train, seq_len=ss)
        elif lt == "enc" and shape.is_decode:
            continue  # encoder not run at decode
        else:
            lc = layer_cost(cfg, shape, mesh, fsdp=fsdp, ltype=lt,
                            train=train, hybrid=hybrid)
        flops += n * lc.flops
        bts += n * lc.bytes
        detail[lt] = {"n": n, "flops": lc.flops, "bytes": lc.bytes}

    oc = outer_cost(cfg, shape, mesh, fsdp=fsdp, train=train)
    flops += oc.flops
    bts += oc.bytes
    detail["outer"] = {"n": 1, "flops": oc.flops, "bytes": oc.bytes}
    return {"flops_per_dev": flops, "bytes_per_dev": bts, "detail": detail,
            "remat": remat_note}


def analytic_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                       fsdp: bool, microbatches: int = 1) -> float:
    """Per-device HBM-traffic LOWER BOUND (bytes no implementation avoids).

    Counts: weight streaming (fwd + bwd-recompute + grad pass per
    microbatch), optimizer state read/write, saved residual carries, one
    read+write of the layer I/O activations, decode KV/state streaming.
    Fusion cannot remove these; the HLO 'bytes accessed' metric is the
    matching UPPER bound (every unfused operand).
    """
    from repro.models.params import param_bytes
    msize = mesh.shape["model"]
    dsize = mesh.shape["data"] * (mesh.shape.get("pod", 1))
    chips = mesh.size
    pb_local = param_bytes(cfg) / (msize * (dsize if fsdp else 1))
    n_par = param_bytes(cfg) / 2

    D = cfg.d_model
    act_bytes = 2  # bf16
    if shape.kind == "train":
        tokens_local = shape.global_batch * shape.seq_len / dsize
        # weights: fwd + bwd recompute (remat=full) + grad production
        w = pb_local * 3 * microbatches
        # optimizer: read m,v + params, write all (f32 moments)
        opt = (n_par * 8 / (msize * dsize)) * 2 + pb_local * 2
        # activations: residual carry saved+read per layer; layer I/O rw
        acts = tokens_local * D * act_bytes * cfg.n_layers * 4
        return w + opt + acts
    if shape.kind == "prefill":
        tokens_local = shape.global_batch * shape.seq_len / dsize
        return pb_local + tokens_local * D * act_bytes * cfg.n_layers * 2
    # decode: params once + full cache read+write
    from repro.core.costmodel import kv_cache_bytes
    cache_local = kv_cache_bytes(cfg, shape, shape.global_batch) / chips
    return pb_local + 2 * cache_local


def _decoder_layer_cost(cfg, shape, mesh, *, fsdp, train) -> LayerCost:
    """Enc-dec decoder layer (self + cross + ffn)."""
    acfg = _analysis_cfg(cfg)
    B = shape.global_batch
    shards = _batch_shards(mesh)
    if shape.is_decode:
        B_eff, k, S_eff = B, 1.0, 1
        S_src = ENCDEC_SRC_LEN
    else:
        B_eff = shards if B % shards == 0 and B >= shards else B
        k = B / B_eff
        S_eff, S_src = seq_split(cfg, shape.seq_len)

    specs, ldef = _leaf_specs_for_layer(acfg, mesh, fsdp, "dec")
    lp = _abstract_layer(acfg, mesh, specs, ldef)
    bspec = _bspec(mesh, B_eff)
    cdt = jnp.dtype(cfg.compute_dtype)
    xs = jax.ShapeDtypeStruct((B_eff, S_eff, cfg.d_model), cdt,
                              sharding=NamedSharding(mesh, P(*bspec, None, None)))
    enc = jax.ShapeDtypeStruct((B_eff, S_src, cfg.d_model), cdt,
                               sharding=NamedSharding(mesh, P(*bspec, None, None)))

    if shape.is_decode:
        W = shape.seq_len
        lc = {"self_c": dec._attn_cache(acfg, B_eff, W)}
        hd = (B_eff, S_src, cfg.n_kv_heads, cfg.head_dim)
        lc["ck"] = jnp.zeros(hd, cdt)
        lc["cv"] = jnp.zeros(hd, cdt)
        lc = jax.eval_shape(lambda: lc)
        cspec = {"self_c": sh.cache_specs(acfg, mesh, {"layers": lc["self_c"]})["layers"],
                 "ck": P(*bspec, "model" if S_src % mesh.shape["model"] == 0 else None, None, None),
                 "cv": P(*bspec, "model" if S_src % mesh.shape["model"] == 0 else None, None, None)}
        lc = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(
                l.shape, l.dtype, sharding=NamedSharding(mesh, s)),
            lc, cspec, is_leaf=lambda x: hasattr(x, "shape"))
        pos = jax.ShapeDtypeStruct((B_eff,), jnp.int32,
                                   sharding=NamedSharding(mesh, bspec))
        x1 = jax.ShapeDtypeStruct((B_eff, 1, cfg.d_model), cdt,
                                  sharding=NamedSharding(mesh, P(*bspec, None, None)))

        def f(x, lp, lc, pos):
            rope1 = L.rope_tables(pos[:, None], acfg.head_dim, acfg.rope_theta)
            # reuse the decode body from decode_step's encdec branch
            xin = L.rms_norm(x, lp["ln1"], acfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", xin, lp["attn"]["wq"]).astype(x.dtype)
            kk = jnp.einsum("bsd,dhk->bshk", xin, lp["attn"]["wk"]).astype(x.dtype)
            vv = jnp.einsum("bsd,dhk->bshk", xin, lp["attn"]["wv"]).astype(x.dtype)
            kc, vc = L.cache_update(lc["self_c"]["k"], lc["self_c"]["v"], kk, vv, pos)
            kv_pos = L.cache_positions(pos, kc.shape[1])
            o = L.decode_attention(q, kc, vc, kv_pos, pos)
            h = x + T._attn_out(o, lp["attn"], x.dtype)
            xin = L.rms_norm(h, lp["ln2"], acfg.norm_eps)
            cq = jnp.einsum("bsd,dhk->bshk", xin, lp["cross"]["wq"]).astype(x.dtype)
            src_pos = jnp.broadcast_to(jnp.arange(S_src)[None], (B_eff, S_src))
            co = L.decode_attention(cq, lc["ck"], lc["cv"], src_pos,
                                    jnp.full((B_eff,), 2**30, jnp.int32))
            h = h + T._attn_out(co, lp["cross"], x.dtype)
            f_, _ = T._ffn(L.rms_norm(h, lp["ln3"], acfg.norm_eps), lp, acfg)
            return h + f_

        flops, bts = _cost_of(f, x1, lp, lc, pos, mesh=mesh)
        return LayerCost(flops * k, bts * k)

    rope_static = L.rope_tables(
        jnp.arange(S_eff)[None].astype(jnp.int32) *
        jnp.ones((B_eff, 1), jnp.int32), acfg.head_dim, acfg.rope_theta)

    def fwd(x, lp, enc_out):
        a, _ = T.attn_block(L.rms_norm(x, lp["ln1"], acfg.norm_eps),
                            lp["attn"], acfg, rope_static, causal=True,
                            unroll=True)
        h = x + a
        cq = jnp.einsum("bsd,dhk->bshk",
                        L.rms_norm(h, lp["ln2"], acfg.norm_eps),
                        lp["cross"]["wq"]).astype(x.dtype)
        ck = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wk"]).astype(x.dtype)
        cv = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wv"]).astype(x.dtype)
        co = L.blocked_attention(cq, ck, cv, causal=False,
                                 block_q=ANALYSIS_BLOCK,
                                 block_kv=ANALYSIS_BLOCK, unroll=True)
        h = h + T._attn_out(co, lp["cross"], x.dtype)
        ff, _ = T._ffn(L.rms_norm(h, lp["ln3"], acfg.norm_eps), lp, acfg,
                       unroll=True)
        return h + ff

    if train:
        body = fwd
        if cfg.remat != "none":
            body = jax.checkpoint(
                fwd, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

        def fb(x, lp, enc_out, ct):
            y, vjp = jax.vjp(body, x, lp, enc_out)
            return vjp(ct)

        flops, bts = _cost_of(fb, xs, lp, enc, xs, mesh=mesh)
    else:
        flops, bts = _cost_of(fwd, xs, lp, enc, mesh=mesh)
    wl = _local_weight_bytes(acfg, mesh, specs, ldef)
    return LayerCost(flops * k, wl + k * max(bts - wl, 0.0))
