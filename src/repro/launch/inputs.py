"""``input_specs``: ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, no device allocation — the dry-run lowers
against these.  Decode shapes include the full KV-cache / state pytree for a
``shape.seq_len``-deep context (ring-buffer-bounded for SWA/hybrid/SSM).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import decode as dec
from repro.launch import sharding as sh

ENCDEC_SRC_LEN = 4096          # decode-time encoder context (audio frames)


def _sds(shape, dtype, mesh=None, spec=None):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _batch_tuple(mesh, B: int):
    """Batch mesh axes, or () when B isn't divisible (replicate batch)."""
    if mesh is None:
        return ("data",)
    b = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    shards = 1
    for a in b:
        shards *= mesh.shape[a]
    return b if (B % shards == 0 and B >= shards) else ()


def seq_split(cfg: ModelConfig, S: int) -> Tuple[int, int]:
    """(text_len, vision_len) for VLM; (tgt_len, src_len) for enc-dec."""
    if cfg.family == "vlm":
        sv = int(S * cfg.vision_frac)
        return S - sv, sv
    if cfg.family == "encdec":
        return S // 2, S // 2
    return S, 0


def train_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh=None) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    b = _batch_tuple(mesh, B)
    out = {}
    if cfg.family == "vlm":
        st, sv = seq_split(cfg, S)
        out["tokens"] = _sds((B, st), jnp.int32, mesh, P(b, None))
        out["vision_embeds"] = _sds((B, sv, cfg.d_model), jnp.bfloat16, mesh,
                                    P(b, None, None))
        out["position_ids"] = _sds((3, B, S), jnp.int32, mesh, P(None, b, None))
        out["targets"] = _sds((B, S), jnp.int32, mesh, P(b, None))
        out["mask"] = _sds((B, S), jnp.float32, mesh, P(b, None))
    elif cfg.family == "encdec":
        st, ss = seq_split(cfg, S)
        out["frame_embeds"] = _sds((B, ss, cfg.d_model), jnp.bfloat16, mesh,
                                   P(b, None, None))
        out["tokens"] = _sds((B, st), jnp.int32, mesh, P(b, None))
        out["targets"] = _sds((B, st), jnp.int32, mesh, P(b, None))
        out["mask"] = _sds((B, st), jnp.float32, mesh, P(b, None))
    else:
        out["tokens"] = _sds((B, S), jnp.int32, mesh, P(b, None))
        out["targets"] = _sds((B, S), jnp.int32, mesh, P(b, None))
        out["mask"] = _sds((B, S), jnp.float32, mesh, P(b, None))
    return out


def prefill_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh=None) -> Dict:
    t = train_inputs(cfg, shape, mesh)
    t.pop("targets", None)
    t.pop("mask", None)
    return t


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh=None) -> Dict:
    """(cache, tokens, pos[, extras]) stand-ins for one serve_step."""
    B, S = shape.global_batch, shape.seq_len
    b = _batch_tuple(mesh, B)
    src = ENCDEC_SRC_LEN if cfg.family == "encdec" else 0
    cache = dec.abstract_cache(cfg, B, S, src_len=src)
    if mesh is not None:
        specs = sh.cache_specs(cfg, mesh, cache)
        cache = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(
                l.shape, l.dtype, sharding=NamedSharding(mesh, s)),
            cache, specs)
    out = {
        "cache": cache,
        "tokens": _sds((B, 1), jnp.int32, mesh, P(b, None)),
        "pos": _sds((B,), jnp.int32, mesh, P(b)),
    }
    if cfg.rope_type == "mrope":
        out["extras"] = {"position_ids": _sds((3, B, 1), jnp.int32, mesh,
                                              P(None, b, None))}
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh=None) -> Dict:
    if shape.kind == "train":
        return train_inputs(cfg, shape, mesh)
    if shape.kind == "prefill":
        return prefill_inputs(cfg, shape, mesh)
    return decode_inputs(cfg, shape, mesh)
