"""Fault tolerance primitives: heartbeats, failure injection, stragglers.

On a real fleet each host runs a ``HeartbeatMonitor`` against the job
coordinator; a missed beat triggers checkpoint-restart on the survivors
(see ``runtime.elastic``).  In this single-process repo the same objects are
driven by tests/benchmarks with injected failures and injected slowness.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional


class SimulatedFailure(RuntimeError):
    """Raised by FailureInjector at the configured step (host crash)."""


class FailureInjector:
    def __init__(self, fail_at_step: Optional[int] = None,
                 fail_host: int = 0):
        self.fail_at_step = fail_at_step
        self.fail_host = fail_host
        self.fired = False

    def check(self, step: int, host: int = 0):
        if (self.fail_at_step is not None and not self.fired
                and step >= self.fail_at_step and host == self.fail_host):
            self.fired = True
            raise SimulatedFailure(
                f"injected failure: host {host} died at step {step}")


class HeartbeatMonitor:
    """Tracks per-host beats; calls ``on_dead(host)`` after ``timeout``."""

    def __init__(self, hosts: List[int], timeout: float = 5.0,
                 on_dead: Optional[Callable[[int], None]] = None,
                 clock=time.monotonic):
        self._clock = clock
        self.timeout = timeout
        self.on_dead = on_dead
        self.last_beat: Dict[int, float] = {h: clock() for h in hosts}
        self.dead: List[int] = []
        self._lock = threading.Lock()

    def beat(self, host: int):
        with self._lock:
            self.last_beat[host] = self._clock()

    def check(self) -> List[int]:
        now = self._clock()
        newly_dead = []
        with self._lock:
            for h, t in self.last_beat.items():
                if h not in self.dead and now - t > self.timeout:
                    self.dead.append(h)
                    newly_dead.append(h)
        for h in newly_dead:
            if self.on_dead:
                self.on_dead(h)
        return newly_dead


@dataclasses.dataclass
class StragglerDetector:
    """Flags steps slower than ``factor`` x running median.

    The ARCAS controller treats a persistent straggler group like a
    high-remote-access condition: migrate work off it (relayout /
    elastic downscale).
    """
    factor: float = 2.0
    window: int = 32
    min_samples: int = 5

    def __post_init__(self):
        self.samples: List[float] = []
        self.events: List[int] = []
        self._step = 0

    def observe(self, step_time: float) -> bool:
        self._step += 1
        self.samples.append(step_time)
        if len(self.samples) > self.window:
            self.samples.pop(0)
        if len(self.samples) < self.min_samples:
            return False
        med = sorted(self.samples)[len(self.samples) // 2]
        if step_time > self.factor * med:
            self.events.append(self._step)
            return True
        return False
