from repro.runtime.trainer import Trainer, TrainerConfig, SimulatedFailure
from repro.runtime.failure import FailureInjector, HeartbeatMonitor
from repro.runtime.elastic import degraded_mesh, rebatch_for
