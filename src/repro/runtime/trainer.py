"""Training loop integrating every substrate:

  data -> device_put(batch shardings) -> jitted train_step ->
  ARCAS scheduler (counters + Algorithm 1 + migration) ->
  checkpoint (atomic/async) -> failure injection / straggler detection.

The per-step "remote access" counter (Algorithm 1's cache-fill events) is
fed from the compiled step's HLO collective parse — on relayout the step is
re-jitted on the new mesh and the counter constants refresh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.checkpoint.manager import CheckpointManager
from repro.compression.grad_compress import (init_compression,
                                             int8_compress_transform)
from repro.core.controller import ControllerConfig
from repro.core.counters import PerfCounters
from repro.core.layout import Layout
from repro.core.scheduler import GlobalScheduler
from repro.core.topology import ChipletTopology
from repro.launch import sharding as shlib
from repro.launch import hlo_analysis as ha
from repro.launch.steps import make_train_step
from repro.models.params import abstract_params, init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.runtime.failure import (FailureInjector, SimulatedFailure,
                                   StragglerDetector)


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    microbatches: int = 1
    seed: int = 0
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    compress_cross_pod: bool = False
    arcas: bool = True
    log_every: int = 10
    async_ckpt: bool = False


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh, loader, tcfg: TrainerConfig,
                 *, topology: Optional[ChipletTopology] = None,
                 controller_cfg: Optional[ControllerConfig] = None,
                 failure: Optional[FailureInjector] = None,
                 log: Callable[[str], None] = print):
        self.cfg = cfg
        self.mesh = mesh
        self.loader = loader
        self.tcfg = tcfg
        self.failure = failure
        self.log = log
        self.counters = PerfCounters()
        self.straggler = StragglerDetector()
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.scheduler = None
        if tcfg.arcas and topology is not None:
            self.scheduler = GlobalScheduler(
                topology, controller_cfg, counters=self.counters)
        self.step = 0
        self._build()

    # ------------------------------------------------------------------
    def _build(self, restore: bool = False):
        cfg, mesh = self.cfg, self.mesh
        fsdp = False
        self.pspecs = shlib.param_specs(cfg, mesh, fsdp=fsdp)
        self.psh = shlib.named(mesh, self.pspecs)
        key = jax.random.PRNGKey(self.tcfg.seed)
        params_host = init_params(cfg, key)
        self.params = jax.device_put(params_host, self.psh)
        self.opt_state = init_opt_state(self.params)
        ospecs = shlib.opt_specs(cfg, mesh, self.pspecs)
        self.osh = shlib.named(mesh, ospecs)
        self.opt_state = jax.device_put(self.opt_state, self.osh)

        transform = None
        if self.tcfg.compress_cross_pod:
            self._ef = init_compression(self.params)["ef"]

            def transform(grads):
                g, self._ef_new = int8_compress_transform(grads, self._ef)
                return g

        step_fn = make_train_step(cfg, self.tcfg.opt,
                                  grad_transform=transform,
                                  microbatches=self.tcfg.microbatches)
        self._jit_step = jax.jit(
            step_fn, out_shardings=(self.psh, self.osh, None),
            donate_argnums=(0, 1))
        self._batch_sharding = shlib.named(
            mesh, shlib.batch_specs(cfg, None, mesh))
        self._hlo_bytes = None  # filled after first compile

    def _put_batch(self, np_batch: Dict[str, np.ndarray]):
        out = {}
        for k, v in np_batch.items():
            shd = self._batch_sharding.get(k)
            out[k] = jax.device_put(v, shd)
        return out

    # ------------------------------------------------------------------
    def resume_if_possible(self) -> bool:
        latest = self.ckpt.latest()
        if latest is None:
            return False
        state = {"params": self.params, "opt": self.opt_state}
        shardings = {"params": self.psh, "opt": self.osh}
        restored, meta = self.ckpt.restore(state, shardings=shardings)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.step = int(meta["step"])
        if "loader" in meta:
            self.loader.load_state_dict(meta["loader"])
        self.log(f"[trainer] resumed from step {self.step}")
        return True

    def _collective_feed(self, compiled_text: str):
        stats = ha.collective_bytes(compiled_text, multi_pod=False)
        self._hlo_bytes = {
            "remote": stats.remote_bytes,
            "local": stats.per_class_bytes.get("intra_group", 0.0),
        }

    # ------------------------------------------------------------------
    def run(self, steps: Optional[int] = None) -> Dict[str, Any]:
        steps = steps or self.tcfg.steps
        losses = []
        t_train0 = time.monotonic()
        while self.step < steps:
            if self.failure is not None:
                self.failure.check(self.step)
            block = self.loader.next()
            from repro.data.pipeline import make_batch
            batch = self._put_batch(make_batch(self.cfg, block))
            t0 = time.monotonic()
            self.params, self.opt_state, metrics = self._jit_step(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            losses.append(loss)
            self.step += 1

            if self._hlo_bytes is None:
                try:
                    # pull collective constants from the compiled step once
                    txt = self._jit_step.lower(
                        self.params, self.opt_state, batch).compile().as_text()
                    self._collective_feed(txt)
                except Exception:   # noqa: BLE001
                    self._hlo_bytes = {"remote": 0.0, "local": 0.0}

            slow = self.straggler.observe(dt)
            self.counters.record_step(
                step_time=dt,
                remote_bytes=self._hlo_bytes["remote"] * (2 if slow else 1),
                local_bytes=self._hlo_bytes["local"])
            if self.scheduler is not None:
                self.scheduler.after_step()

            if self.step % self.tcfg.log_every == 0:
                self.log(f"[trainer] step {self.step} loss {loss:.4f} "
                         f"({dt*1e3:.0f} ms)")
            if self.step % self.tcfg.ckpt_every == 0 or self.step == steps:
                self.ckpt.save(
                    self.step,
                    {"params": self.params, "opt": self.opt_state},
                    metadata={"loader": self.loader.state_dict()},
                    blocking=not self.tcfg.async_ckpt)
        self.ckpt.wait()
        return {"losses": losses, "steps": self.step,
                "wall": time.monotonic() - t_train0,
                "straggler_events": list(self.straggler.events),
                "counters": self.counters.snapshot()}
