"""Training loop integrating every substrate:

  data -> device_put(batch shardings) -> jitted train_step ->
  ARCAS scheduler (counters + Algorithm 1 + migration) ->
  checkpoint (atomic/async) -> failure injection / straggler detection.

The per-step "remote access" counter (Algorithm 1's cache-fill events) is
fed from the compiled step's HLO collective parse — on relayout the step is
re-jitted on the new mesh and the counter constants refresh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.checkpoint.manager import CheckpointManager
from repro.compression.grad_compress import (init_compression,
                                             int8_compress_transform)
from repro.core.controller import ControllerConfig
from repro.core.counters import PerfCounters
from repro.core.layout import Layout
from repro.core.scheduler import GlobalScheduler, migrate_pytree
from repro.core.topology import ChipletTopology
from repro.launch import sharding as shlib
from repro.launch import hlo_analysis as ha
from repro.launch.steps import make_train_step
from repro.models.params import abstract_params, init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.runtime.failure import (FailureInjector, SimulatedFailure,
                                   StragglerDetector)


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    microbatches: int = 1
    seed: int = 0
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    compress_cross_pod: bool = False
    arcas: bool = True
    log_every: int = 10
    async_ckpt: bool = False


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh, loader, tcfg: TrainerConfig,
                 *, topology: Optional[ChipletTopology] = None,
                 controller_cfg: Optional[ControllerConfig] = None,
                 failure: Optional[FailureInjector] = None,
                 log: Callable[[str], None] = print):
        self.cfg = cfg
        self.mesh = mesh
        self.loader = loader
        self.tcfg = tcfg
        self.failure = failure
        self.log = log
        self.counters = PerfCounters()
        self.straggler = StragglerDetector()
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.scheduler = None
        if tcfg.arcas and topology is not None:
            self.scheduler = GlobalScheduler(
                topology, controller_cfg, counters=self.counters)
            self.scheduler.register_relayout(self._on_relayout)
        self.step = 0
        self._build()

    # ------------------------------------------------------------------
    def _build(self, restore: bool = False):
        cfg, mesh = self.cfg, self.mesh
        fsdp = False
        self.pspecs = shlib.param_specs(cfg, mesh, fsdp=fsdp)
        self.psh = shlib.named(mesh, self.pspecs)
        key = jax.random.PRNGKey(self.tcfg.seed)
        params_host = init_params(cfg, key)
        self.params = jax.device_put(params_host, self.psh)
        self.opt_state = init_opt_state(self.params)
        ospecs = shlib.opt_specs(cfg, mesh, self.pspecs)
        self.osh = shlib.named(mesh, ospecs)
        self.opt_state = jax.device_put(self.opt_state, self.osh)

        if self.tcfg.compress_cross_pod and not hasattr(self, "_ef"):
            self._ef = init_compression(self.params)["ef"]
        self._compile_step(mesh)

    def _compile_step(self, mesh):
        """(Re-)jit the train step for ``mesh`` (initial build + relayout).

        With compression on, the error-feedback state threads through the
        jitted step as an explicit carry (in/out), so it actually updates
        every step instead of being baked in as a traced constant.
        """
        compress = self.tcfg.compress_cross_pod
        step_fn = make_train_step(
            self.cfg, self.tcfg.opt,
            ef_transform=int8_compress_transform if compress else None,
            microbatches=self.tcfg.microbatches)
        if compress:
            self._jit_step = jax.jit(
                step_fn, out_shardings=(self.psh, self.osh, None, None),
                donate_argnums=(0, 1, 3))
        else:
            self._jit_step = jax.jit(
                step_fn, out_shardings=(self.psh, self.osh, None),
                donate_argnums=(0, 1))
        self._batch_sharding = shlib.named(
            mesh, shlib.batch_specs(self.cfg, None, mesh))
        self._hlo_bytes = None  # (re-)filled after next compile

    # -- relayout handler: migrate live training state to the new layout ----
    def _on_relayout(self, new_layout: Layout, decision) -> None:
        """Invoked by the GlobalScheduler control loop on a spread change.

        With a full fleet attached this rebuilds the mesh and reshards the
        live params/optimizer pytrees (``migrate_pytree``); on smaller
        hosts the relayout is logical — recorded, counters reset, but state
        stays put.
        """
        self.counters.add("relayouts", 1)
        self.log(f"[trainer] relayout s={decision.old_spread}->"
                 f"{decision.new_spread} ({decision.reason})")
        if len(jax.devices()) < new_layout.topology.total_chips:
            return
        mesh = new_layout.make_mesh()
        self.mesh = mesh
        self.pspecs = shlib.param_specs(self.cfg, mesh, fsdp=False)
        self.psh = shlib.named(mesh, self.pspecs)
        ospecs = shlib.opt_specs(self.cfg, mesh, self.pspecs)
        self.osh = shlib.named(mesh, ospecs)
        self.params = migrate_pytree(self.params, self.psh)
        self.opt_state = migrate_pytree(self.opt_state, self.osh)
        if hasattr(self, "_ef"):
            # error-feedback state mirrors params; the re-jitted step
            # captures it, so it must move to the new mesh too
            self._ef = migrate_pytree(self._ef, self.psh)
        self._compile_step(mesh)

    def _put_batch(self, np_batch: Dict[str, np.ndarray]):
        out = {}
        for k, v in np_batch.items():
            shd = self._batch_sharding.get(k)
            out[k] = jax.device_put(v, shd)
        return out

    # ------------------------------------------------------------------
    def resume_if_possible(self) -> bool:
        latest = self.ckpt.latest()
        if latest is None:
            return False
        state = {"params": self.params, "opt": self.opt_state}
        shardings = {"params": self.psh, "opt": self.osh}
        restored, meta = self.ckpt.restore(state, shardings=shardings)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.step = int(meta["step"])
        if "loader" in meta:
            self.loader.load_state_dict(meta["loader"])
        self.log(f"[trainer] resumed from step {self.step}")
        return True

    def _collective_feed(self, compiled_text: str):
        stats = ha.collective_bytes(compiled_text, multi_pod=False)
        self._hlo_bytes = {
            "remote": stats.remote_bytes,
            "local": stats.per_class_bytes.get("intra_group", 0.0),
        }

    # ------------------------------------------------------------------
    def run(self, steps: Optional[int] = None) -> Dict[str, Any]:
        steps = steps or self.tcfg.steps
        losses = []
        t_train0 = time.monotonic()
        while self.step < steps:
            if self.failure is not None:
                self.failure.check(self.step)
            block = self.loader.next()
            from repro.data.pipeline import make_batch
            batch = self._put_batch(make_batch(self.cfg, block))
            t0 = time.monotonic()
            if self.tcfg.compress_cross_pod:
                self.params, self.opt_state, metrics, self._ef = \
                    self._jit_step(self.params, self.opt_state, batch,
                                   self._ef)
            else:
                self.params, self.opt_state, metrics = self._jit_step(
                    self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            losses.append(loss)
            self.step += 1

            if self._hlo_bytes is None:
                try:
                    # pull collective constants from the compiled step once
                    args = (self.params, self.opt_state, batch)
                    if self.tcfg.compress_cross_pod:
                        args += (self._ef,)
                    txt = self._jit_step.lower(*args).compile().as_text()
                    self._collective_feed(txt)
                except Exception:   # noqa: BLE001
                    self._hlo_bytes = {"remote": 0.0, "local": 0.0}

            slow = self.straggler.observe(dt)
            self.counters.record_step(
                step_time=dt,
                remote_bytes=self._hlo_bytes["remote"] * (2 if slow else 1),
                local_bytes=self._hlo_bytes["local"])
            if self.scheduler is not None:
                # the unified control loop: advance host-side coroutines one
                # round, evaluate Algorithm 1, fire relayout handlers
                self.scheduler.tick()

            if self.step % self.tcfg.log_every == 0:
                self.log(f"[trainer] step {self.step} loss {loss:.4f} "
                         f"({dt*1e3:.0f} ms)")
            if self.step % self.tcfg.ckpt_every == 0 or self.step == steps:
                self.ckpt.save(
                    self.step,
                    {"params": self.params, "opt": self.opt_state},
                    metadata={"loader": self.loader.state_dict()},
                    blocking=not self.tcfg.async_ckpt)
        self.ckpt.wait()
        return {"losses": losses, "steps": self.step,
                "wall": time.monotonic() - t_train0,
                "straggler_events": list(self.straggler.events),
                "counters": self.counters.snapshot()}
