"""Elastic scaling: degraded meshes and batch re-fitting.

When a chiplet group (mesh row) dies, the survivors form the largest
rectangular sub-mesh excluding it; the checkpoint restores onto the new
mesh via reshard-on-load (checkpoint.manager).  Algorithm 2's wrap-around
arithmetic keeps shard->group affinity contiguous on the survivors.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np


def degraded_mesh(axis_sizes: Tuple[int, int], failed_rows: Sequence[int],
                  devices=None):
    """(data, model) mesh minus failed data-rows (chiplet groups).

    Returns (mesh, kept_rows).  The model axis is preserved (TP intact);
    data parallelism shrinks — the ARCAS compact/spread trade re-evaluates
    on the survivor topology.
    """
    import jax
    from jax.sharding import Mesh

    data, model = axis_sizes
    devices = list(jax.devices())[:data * model] if devices is None \
        else list(devices)
    arr = np.asarray(devices, dtype=object).reshape(data, model)
    kept = [r for r in range(data) if r not in set(failed_rows)]
    sub = arr[kept, :]
    return Mesh(sub, ("data", "model")), kept


def rebatch_for(global_batch: int, data_shards: int) -> int:
    """Largest batch <= global_batch divisible by the surviving shards."""
    return max(data_shards, (global_batch // data_shards) * data_shards)
