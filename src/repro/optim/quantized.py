"""8-bit AdamW: block-quantized moments (int8 + per-row f32 scales).

The f32 Adam moments of a 314B-parameter model are 2.5 TB — 9.8 GB/chip on
256 chips, which together with params/grads overflows a 16 GB v5e.  Storing
m as signed int8 (absmax row scaling) and sqrt(v) as unsigned int8 (max row
scaling; sqrt-space halves the dynamic range the 8 bits must cover) cuts
moment memory 4x at <1% step-direction error (validated against fp32 AdamW
trajectories in tests/test_substrates.py).

Rows = the last tensor dimension; scales are f32 per row.  All quantization
is deterministic round-to-nearest, and the dequant->update->requant round
trip happens in f32 inside the (sharded) update, so no extra collectives.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, global_norm, lr_schedule


def _quant_signed(x):
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant_signed(q, scale):
    return q.astype(jnp.float32) * scale


def _quant_unsigned(x):
    scale = jnp.max(x, axis=-1, keepdims=True) / 255.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), 0, 255).astype(jnp.uint8)
    return q, scale.astype(jnp.float32)


def _dequant_unsigned(q, scale):
    return q.astype(jnp.float32) * scale


def init_opt_state_8bit(params) -> Dict[str, Any]:
    def zq(p):
        return {"q": jnp.zeros(p.shape, jnp.int8),
                "scale": jnp.zeros(p.shape[:-1] + (1,), jnp.float32)}

    def zqu(p):
        return {"q": jnp.zeros(p.shape, jnp.uint8),
                "scale": jnp.zeros(p.shape[:-1] + (1,), jnp.float32)}

    return {
        "m": jax.tree.map(zq, params),
        "v": jax.tree.map(zqu, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw8bit_update(grads, state, params, cfg: AdamWConfig
                     ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    count = state["count"] + 1
    lr = lr_schedule(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, mq, vq):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * _dequant_signed(mq["q"], mq["scale"]) + (1 - cfg.b1) * g
        # v is stored in sqrt-space: uint8 linear quantization halves the
        # representable dynamic range, so small per-row second moments would
        # otherwise collapse to 0 and blow up the step direction
        v = cfg.b2 * jnp.square(_dequant_unsigned(vq["q"], vq["scale"])) + \
            (1 - cfg.b2) * jnp.square(g)
        step_dir = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step_dir + cfg.weight_decay * pf)
        nmq, nms = _quant_signed(m)
        nvq, nvs = _quant_unsigned(jnp.sqrt(v))
        return (pf.astype(p.dtype), {"q": nmq, "scale": nms},
                {"q": nvq, "scale": nvs})

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    is_state = lambda x: isinstance(x, dict) and "q" in x
    flat_m = jax.tree.leaves(state["m"], is_leaf=is_state)
    flat_v = jax.tree.leaves(state["v"], is_leaf=is_state)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
