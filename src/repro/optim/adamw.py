"""AdamW with warmup+cosine schedule, global-norm clipping, f32 moments.

Moments are kept in f32 regardless of param dtype; the update is computed in
f32 and cast back (bf16 params act as their own master copy — the memory
budget that lets grok-1-314B train on 256 chips).  ZeRO-1 sharding of the
moments is a *sharding* concern: see ``repro.launch.sharding.opt_specs``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.peak_lr * warm * frac


def init_opt_state(params) -> Dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state, params, cfg: AdamWConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = lr_schedule(cfg, count)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        step_dir = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step_dir + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
