from repro.compression.grad_compress import (
    CompressionState, init_compression, int8_compress_transform,
    topk_compress_transform)
