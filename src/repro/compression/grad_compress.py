"""Cross-pod gradient compression with error feedback.

At 2+ pods the DP gradient reduction crosses DCN (~6.25 GB/s/chip vs
50 GB/s ICI), so the pod axis is the compression target:

  int8:  g_q = round(g / s) with per-row absmax scale s; residual
         (g - dequant(g_q)) is carried in an error-feedback buffer and
         added before the next step's quantization — unbiased over time,
         8x byte reduction on the wire (int8 + 1 f32 scale per row).
  top-k: keep the k largest-|g| entries per row, EF for the rest.

In-graph we quantize -> (the psum happens on dequantized values under
GSPMD) -> the *numerics* match what a real int8 DCN allreduce produces;
the byte saving is claimed only for the cross-pod hop and is reported by
the cost model, not the HLO parse (documented in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


def init_compression(params) -> Dict[str, Any]:
    """Error-feedback buffers, zero-initialized, param-shaped (f32)."""
    return {"ef": jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)}


def _int8_roundtrip(g):
    scale = jnp.max(jnp.abs(g), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127)
    return q * scale


def _topk_roundtrip(g, frac: float):
    k = max(1, int(g.shape[-1] * frac))
    thresh = jnp.sort(jnp.abs(g), axis=-1)[..., -k][..., None]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def _make_transform(roundtrip: Callable, state: Dict[str, Any]
                    ) -> Tuple[Callable, Callable]:
    """Returns (grad_transform, new_state_fn) pair for make_train_step.

    grad_transform is stateless per call; the caller threads the EF state
    (see runtime.trainer).
    """

    def transform(grads, ef):
        def one(g, e):
            gf = g.astype(jnp.float32) + e
            gq = roundtrip(gf)
            return gq.astype(g.dtype), gf - gq

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(ef)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        new_g = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_e = jax.tree.unflatten(tdef, [o[1] for o in outs])
        return new_g, new_e

    return transform


def int8_compress_transform(grads, ef):
    """(grads, ef) -> (compressed grads, new ef)."""
    return _make_transform(_int8_roundtrip, {})(grads, ef)


def topk_compress_transform(grads, ef, frac: float = 0.1):
    return _make_transform(lambda g: _topk_roundtrip(g, frac), {})(grads, ef)


def compressed_bytes_per_row(n: int) -> float:
    """Wire bytes for one row of n f32 grads under int8+scale."""
    return n * 1 + 4


@dataclasses.dataclass
class CompressionState:
    ef: Any

    @classmethod
    def init(cls, params):
        return cls(**init_compression(params))
